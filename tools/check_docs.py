"""Docs gate for CI: link integrity, generated-docs staleness, coverage.

Four checks, all hard failures:

1. every *local* markdown link (``[text](path)``) in the repo's ``*.md``
   files resolves to an existing file (http/mailto/anchor links skipped);
2. the schedule autotuner, the pipelined emitter, the chain-DAG fusion
   layer, and the indirection-stream sparse layer stay documented:
   DESIGN.md keeps its ``## 9`` (autotuner), ``## 10`` (pipelined
   emission / ``buffer_depth``), ``## 11`` (chain DAGs / ``cut_edges``),
   and ``## 12`` (indirection streams / CSR sparse, citing arXiv
   2011.08070 + 2305.05559) sections + their §2 correspondence rows, the
   README its autotune quickstart, fused-DAG, and sparse coverage;
3. the committed ``EXPERIMENTS.md`` matches a fresh render from
   ``benchmarks/paper_tables.py`` — editing it by hand, or changing the
   models without regenerating it, fails the build;
4. every kernel in ``repro.kernels.registry`` appears (as `` `name` ``) in
   the README kernel table — registering a kernel without documenting it
   fails the build.

Run from anywhere::

    python tools/check_docs.py [--skip-experiments]

``--skip-experiments`` skips checks 3 and 4 (both import jax).
"""

from __future__ import annotations

import argparse
import difflib
import os
import re
import sys
from typing import List, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images' alt text is unnecessary; same syntax.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def _md_files() -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github")]
        for f in filenames:
            if f.endswith(".md"):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def check_links() -> List[Tuple[str, str]]:
    """All broken (file, target) local links across the repo's markdown."""
    broken = []
    for path in _md_files():
        with open(path) as f:
            text = f.read()
        base = os.path.dirname(path)
        for target in _LINK_RE.findall(text):
            if target.startswith(_SKIP_PREFIXES):
                continue
            clean = target.split("#", 1)[0]
            if not clean:
                continue
            if not os.path.exists(os.path.join(base, clean)):
                broken.append((os.path.relpath(path, ROOT), target))
    return broken


def check_experiments() -> List[str]:
    """Unified diff (empty = fresh) of committed vs regenerated docs."""
    sys.path[:0] = [os.path.join(ROOT, "src"), ROOT]
    from benchmarks.paper_tables import render_experiments

    fresh = render_experiments()
    committed_path = os.path.join(ROOT, "EXPERIMENTS.md")
    if not os.path.exists(committed_path):
        return ["EXPERIMENTS.md missing — run: PYTHONPATH=src python "
                "benchmarks/paper_tables.py --write-experiments"]
    with open(committed_path) as f:
        committed = f.read()
    if committed == fresh:
        return []
    return list(difflib.unified_diff(
        committed.splitlines(), fresh.splitlines(),
        fromfile="EXPERIMENTS.md (committed)",
        tofile="EXPERIMENTS.md (regenerated)", lineterm=""))


def check_autotune_docs() -> List[str]:
    """The autotuner must stay documented: DESIGN.md §9 + README quickstart.

    Pure-text check (no jax import): DESIGN.md needs a ``## 9`` section
    mentioning the autotuner and the §2 correspondence row pointing at
    ``core/autotune.py``; the README needs the autotune quickstart.
    """
    problems = []
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        design = f.read()
    if not re.search(r"^## 9\..*autotun", design,
                     re.MULTILINE | re.IGNORECASE):
        problems.append("DESIGN.md: missing '## 9.' autotuner section")
    if "core/autotune.py" not in design:
        problems.append(
            "DESIGN.md: §2 correspondence table has no core/autotune.py row")
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    if not re.search(r"^### Autotune quickstart", readme, re.MULTILINE):
        problems.append("README.md: missing '### Autotune quickstart'")
    if "--autotune-only" not in readme:
        problems.append(
            "README.md: autotune quickstart does not show the gated "
            "kernel_bench --autotune-only entry point")
    return problems


def check_pipeline_docs() -> List[str]:
    """The pipelined emitter must stay documented: DESIGN.md §10 + the
    ``Schedule.buffer_depth`` correspondence row, and the README must
    mention the depth knob (pure-text check, no jax import)."""
    problems = []
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        design = f.read()
    if not re.search(r"^## 10\..*[Pp]ipelined", design, re.MULTILINE):
        problems.append("DESIGN.md: missing '## 10.' pipelined-emission "
                        "section")
    if "Schedule.buffer_depth" not in design:
        problems.append("DESIGN.md: §2 correspondence table has no "
                        "Schedule.buffer_depth (FIFO depth) row")
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    if "buffer_depth" not in readme:
        problems.append("README.md: no mention of the tuned buffer_depth "
                        "(pipelined emission) knob")
    return problems


def check_dag_docs() -> List[str]:
    """Whole-program DAG fusion must stay documented: DESIGN.md §11 + its
    §2 correspondence row, and the README's fused-DAG coverage (pure-text
    check, no jax import)."""
    problems = []
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        design = f.read()
    if not re.search(r"^## 11\..*DAG", design, re.MULTILINE):
        problems.append("DESIGN.md: missing '## 11.' chain-DAG fusion "
                        "section")
    for needle, where in (("chain_dag", "DESIGN.md"),
                          ("ssr_dag_call", "DESIGN.md"),
                          ("Schedule.cut_edges", "DESIGN.md")):
        if needle not in design:
            problems.append(f"{where}: §2 correspondence / §11 does not "
                            f"mention {needle}")
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    if "cut_edges" not in readme:
        problems.append("README.md: no mention of the committed cut_edges "
                        "partition provenance")
    if "autotune_dag" not in readme:
        problems.append("README.md: no mention of the autotune_dag fusion "
                        "search")
    return problems


def check_sparse_docs() -> List[str]:
    """The indirection-stream sparse layer must stay documented: DESIGN.md
    §12 + the §2 correspondence rows citing the Indirection-SSR and Sparse
    SSR follow-ups, and the README's sparse kernel coverage (pure-text
    check, no jax import)."""
    problems = []
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        design = f.read()
    if not re.search(r"^## 12\..*[Ii]ndirection", design, re.MULTILINE):
        problems.append("DESIGN.md: missing '## 12.' indirection-streams "
                        "section")
    for needle in ("2011.08070", "2305.05559", "index_of",
                   "kernels/sparse.py", "eliminated_idx_instrs"):
        if needle not in design:
            problems.append(f"DESIGN.md: §2 correspondence / §12 does not "
                            f"mention {needle}")
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    if "spmv_nest" not in readme or "indirection stream" not in readme:
        problems.append("README.md: kernel table has no indirection-stream "
                        "(spmv_nest/spmm_nest) rows")
    if "sparse.py" not in readme:
        problems.append("README.md: architecture map does not mention "
                        "kernels/sparse.py")
    return problems


def check_halo_rescale_docs() -> List[str]:
    """The §13 waiver burn-down must stay documented: DESIGN.md §13 + the
    §2 correspondence rows for halo reads and the online-rescaled
    accumulator, and the README's migrated kernel-table rows (pure-text
    check, no jax import)."""
    problems = []
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        design = f.read()
    if not re.search(r"^## 13\..*[Hh]alo", design, re.MULTILINE):
        problems.append("DESIGN.md: missing '## 13.' halo/rescale section")
    for needle in ("MemRef.window", "acc_kind", "online_softmax",
                   "shifted twin streams", "WAIVER_HOLDOUTS"):
        if needle not in design:
            problems.append(f"DESIGN.md: §2 correspondence / §13 does not "
                            f"mention {needle}")
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for needle in ("stencil_nest", "stencil2d_nest", "attention_nest",
                   "gemv_nest"):
        if needle not in readme:
            problems.append(f"README.md: kernel table row for the migrated "
                            f"{needle} kernel is missing")
    for stale in ("waiver: halo overlap", "waiver: online-softmax rescale",
                  "waiver: whole-row MXU panels",
                  "waiver: geometry-reuse fusion"):
        if stale in readme:
            problems.append(f"README.md: stale waiver row {stale!r} — the "
                            "kernel is nest-lowered now (DESIGN.md §13)")
    return problems


def check_resilience_docs() -> List[str]:
    """The failure model must stay documented: DESIGN.md §14 (seam table,
    typed fallback set, degradation ladder, cache crash-safety contract)
    and the README's resilient-dispatch blurb with the REPRO_FAULTS /
    --chaos-smoke operator knobs (pure-text check, no jax import)."""
    problems = []
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        design = f.read()
    if not re.search(r"^## 14\..*[Dd]egradation", design, re.MULTILINE):
        problems.append("DESIGN.md: missing '## 14.' failure-model / "
                        "degradation-ladder section")
    for needle in ("cache.read", "cache.write", "lowering", "compile",
                   "measure", "fallback_error_types", "FallbackEvent",
                   "GENERATION", ".json.corrupt", "StragglerMonitor",
                   "REPRO_BASELINE_FALLBACK"):
        if needle not in design:
            problems.append(f"DESIGN.md: §14 does not mention {needle}")
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for needle in ("REPRO_FAULTS", "--chaos-smoke",
                   "REPRO_BASELINE_FALLBACK"):
        if needle not in readme:
            problems.append(f"README.md: resilient-dispatch blurb does not "
                            f"mention {needle}")
    return problems


def check_readme_kernels() -> List[str]:
    """Registry kernels missing from the README kernel table."""
    sys.path[:0] = [os.path.join(ROOT, "src"), ROOT]
    from repro.kernels import registry

    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    return [name for name in registry.names() if f"`{name}`" not in readme]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--skip-experiments", action="store_true",
                    help="only check markdown links (no jax import)")
    args = ap.parse_args(argv)

    ok = True
    broken = check_links()
    if broken:
        ok = False
        print("broken markdown links:")
        for path, target in broken:
            print(f"  {path}: ({target})")
    else:
        print(f"markdown links ok across {len(_md_files())} files")

    autotune_problems = check_autotune_docs()
    if autotune_problems:
        ok = False
        print("\nautotuner docs gate:")
        for p in autotune_problems:
            print(f"  {p}")
    else:
        print("autotuner docs present (DESIGN.md §9 + README quickstart)")

    pipeline_problems = check_pipeline_docs()
    if pipeline_problems:
        ok = False
        print("\npipelined-emission docs gate:")
        for p in pipeline_problems:
            print(f"  {p}")
    else:
        print("pipelined-emission docs present (DESIGN.md §10 + "
              "buffer_depth rows)")

    dag_problems = check_dag_docs()
    if dag_problems:
        ok = False
        print("\nchain-DAG docs gate:")
        for p in dag_problems:
            print(f"  {p}")
    else:
        print("chain-DAG docs present (DESIGN.md §11 + cut_edges rows)")

    sparse_problems = check_sparse_docs()
    if sparse_problems:
        ok = False
        print("\nindirection-stream docs gate:")
        for p in sparse_problems:
            print(f"  {p}")
    else:
        print("indirection-stream docs present (DESIGN.md §12 + "
              "sparse rows)")

    halo_problems = check_halo_rescale_docs()
    if halo_problems:
        ok = False
        print("\nhalo/rescale docs gate:")
        for p in halo_problems:
            print(f"  {p}")
    else:
        print("halo/rescale docs present (DESIGN.md §13 + migrated "
              "kernel rows)")

    resilience_problems = check_resilience_docs()
    if resilience_problems:
        ok = False
        print("\nresilience docs gate:")
        for p in resilience_problems:
            print(f"  {p}")
    else:
        print("resilience docs present (DESIGN.md §14 + README chaos "
              "knobs)")

    if not args.skip_experiments:
        diff = check_experiments()
        if diff:
            ok = False
            print("\nEXPERIMENTS.md is stale; regenerate with:\n"
                  "  PYTHONPATH=src python benchmarks/paper_tables.py "
                  "--write-experiments\n")
            print("\n".join(diff[:80]))
        else:
            print("EXPERIMENTS.md is fresh")

        missing = check_readme_kernels()
        if missing:
            ok = False
            print("\nregistry kernels missing from the README kernel "
                  f"table: {missing}\n  add a `name` row per kernel")
        else:
            print("README kernel table covers the registry")

    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
