"""Quickstart: Stream Semantic Registers in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import (contiguous, dot_product_nest, fig4_dot_product,
                        gather_stream, isa, ssr_region, ssrify)
from repro.kernels import ops, ref

print("=" * 64)
print("1. The paper's headline numbers (Fig. 4, exact)")
print("=" * 64)
base, ssr = fig4_dot_product(1000)
print(f"dot product over 1000 elements: {base} instructions without SSR, "
      f"{ssr} with SSR -> {base/ssr:.2f}x fewer\n")

print("=" * 64)
print("2. The compiler pass (paper §3.2): SSR-ify a loop nest")
print("=" * 64)
plan = ssrify(dot_product_nest(2048))
print(f"dot(2048): ssrified={plan.ssrified}, lanes={len(plan.allocations)}, "
      f"speedup={plan.speedup:.2f}x")
for a in plan.allocations:
    print(f"  lane {a.lane}: {a.ref.name} <- AGU bounds={a.spec.bounds} "
          f"strides={a.spec.strides}")
short = ssrify(dot_product_nest(4))
print(f"dot(4): ssrified={short.ssrified}  "
      f"(Eq. 3 break-even: 1-D nests need > 5 iterations)\n")

print("=" * 64)
print("3. Stream semantics = AGU address pattern (what ft0 'sees')")
print("=" * 64)
data = jnp.arange(16.0)
spec = contiguous(6, base=2)
print(f"read stream base=2 bound=6 stride=1 delivers: "
      f"{np.asarray(gather_stream(data, spec))}\n")

print("=" * 64)
print("4. The streamed Pallas kernel vs the oracle (ssrcfg on/off)")
print("=" * 64)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal(2048), jnp.float32)
y = jnp.asarray(rng.standard_normal(2048), jnp.float32)
with ssr_region():            # csrwi ssrcfg, 1
    streamed = ops.dot(x, y)  # -> streamed Pallas kernel
plain = ops.dot(x, y)         # ssrcfg=0 -> plain XLA
print(f"ssr={float(streamed):.4f}  xla={float(plain):.4f}  "
      f"|diff|={abs(float(streamed-plain)):.2e}\n")

print("=" * 64)
print("5. Where the speedup comes from (Table 2)")
print("=" * 64)
for r in isa.table2():
    print(f"{r.kernel:18s} {r.arith}: eta {r.base.eta:4.0%} -> "
          f"{r.ssr.eta:4.0%}, speedup {r.speedup:.2f}x")

print()
print("=" * 64)
print("6. The schedule autotuner: search -> prune -> measure -> persist")
print("=" * 64)
from repro.core import autotune
from repro.core.lowering import DEFAULT_SCHEDULE, Schedule

nest = dot_product_nest(2048)
operands = {"A": x, "B": y}
result = autotune.autotune(
    nest, lambda a, b: a * b, operands,
    candidates=[DEFAULT_SCHEDULE, Schedule(rows=16, lanes=128)],
    warmup=1, iters=2)
s = result.schedule
print(f"winner: {s.rows}x{s.lanes} blocks "
      f"({'default' if result.is_default else 'non-default'}), "
      f"tuned {result.tuned_us:.0f}us vs default {result.default_us:.0f}us"
      + ("  [cache hit]" if result.from_cache else ""))
again = autotune.autotune(nest, lambda a, b: a * b, operands,
                          candidates=[DEFAULT_SCHEDULE], iters=1)
print(f"second call: from_cache={again.from_cache} "
      f"(persisted under {autotune.default_cache_dir()})")
with ssr_region():
    tuned = ops.dot(x, y)     # registry dispatch now runs the winner
print(f"tuned dispatch agrees with XLA: "
      f"|diff|={abs(float(tuned - plain)):.2e}")
