"""Tour of the §4.2 kernel suite: streamed Pallas vs jnp oracle + the
instruction-level model behind each speedup.

Run:  PYTHONPATH=src python examples/ssr_kernels_tour.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import isa
from repro.kernels import ops, ref

rng = np.random.default_rng(1)
f32 = jnp.float32

x2048 = jnp.asarray(rng.standard_normal(2048), f32)
y2048 = jnp.asarray(rng.standard_normal(2048), f32)
x4096 = jnp.asarray(rng.standard_normal(4096), f32)
x1024 = jnp.asarray(rng.standard_normal(1024), f32)
xs = jnp.asarray(rng.standard_normal(1034), f32)
w11 = jnp.asarray(rng.standard_normal(11) * 0.2, f32)
g2d = jnp.asarray(rng.standard_normal((74, 74)), f32)
a64 = jnp.asarray(rng.standard_normal((64, 64)), f32)
v64 = jnp.asarray(rng.standard_normal(64), f32)
a32 = jnp.asarray(rng.standard_normal((32, 32)), f32)
b32 = jnp.asarray(rng.standard_normal((32, 32)), f32)

CASES = [
    ("reduction", lambda: (ops.dot(x2048, y2048, ssr=True),
                           ref.dot_ref(x2048, y2048))),
    ("scan", lambda: (ops.prefix_sum(x4096, ssr=True), ref.scan_ref(x4096))),
    ("stencil1d", lambda: (ops.stencil1d(xs, w11, ssr=True),
                           ref.stencil1d_ref(xs, w11))),
    ("stencil2d", lambda: (ops.stencil2d(g2d, w11, w11, ssr=True),
                           ref.stencil2d_ref(g2d, w11, w11))),
    ("gemv", lambda: (ops.gemv(a64, v64, ssr=True), ref.gemv_ref(a64, v64))),
    ("gemm", lambda: (ops.matmul(a32, b32, ssr=True),
                      ref.matmul_ref(a32, b32))),
    ("relu", lambda: (ops.relu(x1024, ssr=True), ref.relu_ref(x1024))),
    ("fft", lambda: (ops.fft(x2048, y2048, ssr=True)[0],
                     ref.fft_ref(x2048, y2048)[0])),
    ("bitonic", lambda: (ops.sort(x1024, ssr=True), ref.sort_ref(x1024))),
]

models = {k.name: k for k in isa.kernel_suite()}
models["fft"] = models.get("fft")

print(f"{'kernel':10s} {'max |err|':>12s} {'model speedup':>14s} "
      f"{'eta base->ssr':>16s}")
for name, case in CASES:
    got, want = case()
    err = float(jnp.max(jnp.abs(jnp.asarray(got, f32)
                                - jnp.asarray(want, f32))))
    m = models.get(name)
    if m is None:
        m = models.get("stencil1d")
    print(f"{name:10s} {err:12.2e} {m.speedup:13.2f}x "
          f"{m.eta_base:7.0%} -> {m.eta_ssr:5.0%}")
print("\nAll streamed kernels validated against the pure-jnp oracle "
      "(interpret mode; Mosaic on real TPUs).")
