"""End-to-end training example: a ~20M (default) or ~100M parameter dense
LM trained for a few hundred steps on the synthetic Markov stream, with
checkpointing and (optional) injected failure + automatic restart.

Run:  PYTHONPATH=src python examples/train_e2e.py [--preset 100m]
      [--steps 200] [--fail-at 57]
"""

import argparse
import sys
import tempfile
import time

import jax

sys.path.insert(0, "src")

from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.data import pipeline  # noqa: E402
from repro.launch import steps as step_lib  # noqa: E402
from repro.models import ModelConfig  # noqa: E402
from repro.models.config import ScanGroup, uniform_dense_groups  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime.fault import (FailureInjector, Supervisor)  # noqa: E402

PRESETS = {
    # ~20M: CPU-friendly "few hundred steps" demo
    "20m": dict(d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                layers=8, vocab=8192, batch=8, seq=128),
    # ~100M: the brief's end-to-end scale (slower on CPU)
    "100m": dict(d_model=512, num_heads=8, num_kv_heads=4, d_ff=2048,
                 layers=12, vocab=32768, batch=8, seq=256),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"train-e2e-{args.preset}", family="dense",
        d_model=p["d_model"], num_heads=p["num_heads"],
        num_kv_heads=p["num_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab"], groups=uniform_dense_groups(p["layers"]),
        remat=False, tie_embeddings=True)
    print(f"model: {cfg.name}  params ~{cfg.param_count()/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(
        learning_rate=adamw.warmup_cosine(3e-3, 20, args.steps))
    dcfg = pipeline.DataConfig(global_batch=p["batch"], seq_len=p["seq"])
    state = step_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    train = jax.jit(step_lib.make_train_step(cfg, opt_cfg, microbatches=1),
                    donate_argnums=(0, 1))

    losses = []

    def step_fn(st, step):
        batch = pipeline.make_batch(cfg, dcfg, step)
        params, opt, metrics = train(st["params"], st["opt"], batch)
        if step % 10 == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step:4d}  loss {loss:.4f}", flush=True)
        return {"params": params, "opt": opt}

    ckpt_dir = tempfile.mkdtemp(prefix="repro_e2e_")
    sup = Supervisor(
        ckpt=CheckpointManager(ckpt_dir, keep=2), checkpoint_every=25,
        injector=FailureInjector(
            fail_at_steps=(args.fail_at,) if args.fail_at else ()))
    t0 = time.time()
    sup.run(state, step_fn, args.steps)
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps*p['batch']*p['seq']/dt:,.0f} tok/s); "
          f"restarts={sup.restarts}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNED' if losses[-1] < losses[0] - 0.5 else 'check run'})")


if __name__ == "__main__":
    main()
