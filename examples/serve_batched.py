"""Batched-request serving example: prefill a batch of prompts against a
small model, then decode greedily with a shared jitted serve_step — the
paper-kind end-to-end inference driver.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.data import pipeline  # noqa: E402
from repro.launch import steps as step_lib  # noqa: E402
from repro.models import ModelConfig, init_params  # noqa: E402
from repro.models.config import uniform_dense_groups  # noqa: E402

CFG = ModelConfig(
    name="serve-demo", family="dense", d_model=256, num_heads=8,
    num_kv_heads=4, d_ff=1024, vocab_size=8192,
    groups=uniform_dense_groups(6), window=512, remat=False,
    tie_embeddings=True)

BATCH, PROMPT, GEN = 16, 96, 48


def main() -> None:
    print(f"model ~{CFG.param_count()/1e6:.1f}M params, SWA window "
          f"{CFG.window}; batch={BATCH} prompt={PROMPT} gen={GEN}")
    params = init_params(jax.random.PRNGKey(0), CFG)
    dcfg = pipeline.DataConfig(BATCH, PROMPT, seed=5)
    reqs = pipeline.make_batch(CFG, dcfg, 0)
    reqs.pop("labels")

    max_len = PROMPT + GEN + 1
    prefill = jax.jit(step_lib.make_prefill_step(CFG, cache_len=max_len))
    serve = jax.jit(step_lib.make_decode_step(CFG), donate_argnums=(1,))

    t0 = time.time()
    logits, caches = prefill(params, reqs)
    jax.block_until_ready(logits)
    print(f"prefill {BATCH}x{PROMPT} tokens: {(time.time()-t0)*1e3:.0f} ms")

    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [cur]
    t1 = time.time()
    for t in range(GEN - 1):
        pos = jnp.full((BATCH,), PROMPT + t, jnp.int32)
        logits, caches = serve(params, caches, cur, pos)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(cur)
    out = jax.block_until_ready(jnp.concatenate(generated, 1))
    dt = time.time() - t1
    print(f"decode: {GEN} steps in {dt*1e3:.0f} ms "
          f"-> {BATCH*GEN/dt:,.0f} tok/s aggregate, "
          f"{dt/GEN*1e3:.1f} ms/step")
    for b in range(3):
        print(f"  request {b}: ...{out[b, :12].tolist()}")


if __name__ == "__main__":
    main()
