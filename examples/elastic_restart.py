"""Elastic-scaling example: train on an 8-device mesh, checkpoint, then
resume on a 4-device mesh (half the fleet "failed") with the global batch
preserved via gradient accumulation.

Run:  PYTHONPATH=src python examples/elastic_restart.py
(spawns itself with XLA_FLAGS for 8 fake host devices)
"""

import os
import subprocess
import sys

INNER = """
import jax, numpy as onp, tempfile
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.launch import steps as SL
from repro.launch.mesh import make_host_mesh, describe
from repro.models import ModelConfig
from repro.models.config import uniform_dense_groups
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.activations import activation_mesh
from repro.runtime.elastic import plan_rescale, restore_on_mesh

cfg = ModelConfig(name="elastic", family="dense", d_model=128, num_heads=4,
                  num_kv_heads=2, d_ff=256, vocab_size=512,
                  groups=uniform_dense_groups(2), remat=False,
                  microbatches=1)
opt = adamw.AdamWConfig(learning_rate=1e-3)
dcfg = pipeline.DataConfig(global_batch=8, seq_len=32)

def make_train(mesh, micro):
    train = SL.make_train_step(cfg, opt, microbatches=micro)
    pspec = shd.param_spec_tree(jax.eval_shape(
        lambda: SL.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    )["params"], cfg, mesh)
    ospec = {"m": pspec, "v": pspec, "count": P()}
    fn = jax.jit(train,
                 in_shardings=(shd.named(mesh, pspec),
                               shd.named(mesh, ospec), None),
                 out_shardings=(shd.named(mesh, pspec),
                                shd.named(mesh, ospec), None))
    return fn, pspec, ospec

state = SL.init_train_state(jax.random.PRNGKey(0), cfg, opt)
big = make_host_mesh(data=4, model=2)
print("phase 1: training on", describe(big))
with big, activation_mesh(big):
    train, pspec, ospec = make_train(big, 1)
    for step in range(6):
        batch = pipeline.make_batch(cfg, dcfg, step)
        p, o, m = train(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        print(f"  step {step} loss {float(m['loss']):.4f}")

d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(6, state)
print("checkpoint saved at step 6 ->", d)

devs = onp.array(jax.devices())[:4]
small = Mesh(devs.reshape(2, 2), ("data", "model"))
plan = plan_rescale(cfg, dcfg.global_batch, big, small)
print("phase 2: resuming on", describe(small), "|", plan.note)
state2 = restore_on_mesh(mgr, 6, state, cfg, small)
with small, activation_mesh(small):
    train2, _, _ = make_train(small, plan.microbatches)
    for step in range(6, 10):
        batch = pipeline.make_batch(cfg, dcfg, step)
        p, o, m = train2(state2["params"], state2["opt"], batch)
        state2 = {"params": p, "opt": o}
        print(f"  step {step} loss {float(m['loss']):.4f}  "
              f"(devices={len(jax.tree.leaves(p)[0].sharding.device_set)})")
print("elastic restart OK: same stream, half the fleet")
"""


def main() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", INNER], env=env, text=True)
    raise SystemExit(out.returncode)


if __name__ == "__main__":
    main()
