"""Roofline analysis over the dry-run records (§Roofline deliverable).

Per (arch × shape × mesh) cell, derive the three terms from the compiled
artifact (all quantities per device — SPMD-partitioned HLO shapes are
shard-local):

    compute     t_c = dot_FLOPs / peak_FLOPs            (197 TF/s bf16, v5e)
    memory      t_m = bytes_out / HBM_bw                (819 GB/s)
    collective  t_x = collective_bytes / link_bw        (~50 GB/s/link ICI)

``bytes_out`` is the trip-adjusted sum of HLO op output bytes — an HBM
traffic *proxy* (upper bound: on TPU, fusion keeps much of it in
VMEM/registers; recorded as such).  The dominant term is the bottleneck the
§Perf loop iterates on.  MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE)
gives the "useful fraction" dot_FLOPs vs model FLOPs (catching remat /
redundant-compute waste — note remat intentionally recomputes ~1 extra
forward, so a healthy train cell sits near 4/3 overhead).

When a ``BENCH_kernels.json`` (schema 3) sits next to the dry-run records,
:func:`kernel_points` additionally reports the *measured* bandwidth-bound
kernel points from the pipelined-emission sweep: per (kernel × buffer
depth) the wall clock and its speedup over the synchronous default —
deeper FIFOs hide the fetch behind compute, shifting the bandwidth-bound
points left toward the compute ceiling without changing arithmetic
intensity.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (TPU v5e class)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (conservative: 1 link)

_PARAM_CACHE: Dict[str, float] = {}


def model_flops_per_step(arch: str, rec: dict) -> Optional[float]:
    """6·N·D with N = active params, D = tokens processed per step/call."""
    from repro import configs  # noqa: PLC0415

    if arch not in _PARAM_CACHE:
        cfg = configs.get(arch)
        _PARAM_CACHE[arch] = float(cfg.active_param_count())
    n_active = _PARAM_CACHE[arch]
    if rec["kind"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence per call
    return 2.0 * n_active * rec["global_batch"]


def hbm_bytes_model(rec: dict, chips: int) -> float:
    """Analytic per-device HBM traffic per step/call.

    The HLO Σ-output-bytes walk is an *upper bound* (fused elementwise and
    scan-step tensors stay in VMEM on TPU); the roofline memory term uses
    the standard coarse model instead:

      train:   3 passes (fwd, bwd, remat-fwd) over the local param shard
               per microbatch, + optimizer read/write (params, grads,
               2 moments, accumulator), + saved boundary activations;
      prefill: one param-shard pass + the KV-cache write (= output bytes);
      decode:  one param-shard pass + cache read/write (≈ argument bytes
               beyond the params, twice).
    """
    from repro import configs  # noqa: PLC0415

    cfg = configs.get(rec["arch"])
    p_shard = cfg.param_count() * 2 / chips          # bf16 storage
    if rec["kind"] == "train":
        u = rec.get("microbatches", 1)
        mom = 2 * jnp_bytes(cfg.optimizer_dtype)
        acc = jnp_bytes(cfg.grad_accum_dtype)
        opt_rw = p_shard / 2 * (2 * mom + 2 * acc + 2 * 2 + 2)
        act = rec["memory"].get("temp_bytes", 0) * 0.25  # boundary saves
        return 3 * u * p_shard + opt_rw + act
    if rec["kind"] == "prefill":
        return p_shard + rec["memory"]["output_bytes"]
    cache = max(rec["memory"]["argument_bytes"] - p_shard, 0)
    return p_shard + 2 * cache


def jnp_bytes(dtype_name: str) -> int:
    return {"bfloat16": 2, "float32": 4}.get(dtype_name, 4)


def analyze_record(key: str, rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    h = rec["hlo"]
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    t_c = h["dot_flops_per_device"] / PEAK_FLOPS
    t_m = hbm_bytes_model(rec, chips) / HBM_BW
    t_m_upper = h["bytes_out_per_device"] / HBM_BW
    t_x = h["collective_bytes_per_device"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops_per_step(rec["arch"], rec)
    hlo_total = h["dot_flops_per_device"] * chips
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: compute time as share of the serial-sum bound
    frac = t_c / max(sum(terms.values()), 1e-30)
    return {
        "key": key, "arch": rec["arch"], "shape": rec["shape"],
        "mesh": rec["mesh"], "kind": rec["kind"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "t_memory_upper_s": t_m_upper,
        "dominant": dominant,
        "roofline_fraction": frac,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_flop_ratio": useful,
        "peak_gib": rec["memory"]["peak_per_device_gib"],
        "collective_counts": h.get("collective_counts", {}),
        "tag": rec.get("tag", ""),
    }


def load(path: str = "dryrun_results.json") -> List[dict]:
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        row = analyze_record(key, rec)
        if row:
            rows.append(row)
    return rows


def advice(row: dict) -> str:
    """One sentence on what would move the dominant term down."""
    d = row["dominant"]
    if d == "collective":
        if "moe" in row["arch"] or row["arch"].startswith(("deepseek", "dbrx",
                                                           "jamba")):
            return ("shard_map expert-parallel all-to-all dispatch replaces "
                    "XLA's replicated scatter (see §Perf hillclimb)")
        return ("amortise FSDP all-gathers: fewer microbatches / gather once "
                "per step; overlap via latency-hiding scheduler")
    if d == "memory":
        return ("fuse/stream operands (SSR kernels), raise arithmetic "
                "intensity per HBM byte; decode: batch more sequences")
    return "compute-bound: at the roofline; larger tiles / bf16 throughput"


def table(rows: List[dict], mesh: str = "pod16x16") -> str:
    out = [f"{'arch':18s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'domin':>6s} {'frac':>6s} {'useful':>7s} "
           f"{'GiB':>7s}"]
    for r in rows:
        if r["mesh"] != mesh or r["tag"]:
            continue
        out.append(
            f"{r['arch']:18s} {r['shape']:12s} {r['t_compute_s']:9.3f} "
            f"{r['t_memory_s']:9.3f} {r['t_collective_s']:9.3f} "
            f"{r['dominant'][:6]:>6s} {r['roofline_fraction']:6.1%} "
            f"{r['useful_flop_ratio']:7.2f} {r['peak_gib']:7.2f}")
    return "\n".join(out)


def kernel_points(path: str = "BENCH_kernels.json") -> List[dict]:
    """Measured bandwidth-bound points from the pipelined-emission sweep.

    Reads the schema-3 ``pipeline`` group rows (gemv/stencil1d at each
    raced buffer depth) and pairs every pipelined row with its synchronous
    baseline: one point per (kernel × depth), carrying the wall clock and
    the latency-hiding speedup.  Missing/old-schema files return ``[]`` —
    the dry-run roofline stands alone.
    """
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema", 0) < 3:
        return []
    sync: Dict[str, dict] = {}
    piped: Dict[str, dict] = {}
    for r in doc.get("results", []):
        if r.get("group") != "pipeline":
            continue
        kern = r["name"].split("/")[1]
        (sync if r["variant"] == "sync" else piped)[kern] = r
    points = []
    for kern, row in sorted(piped.items()):
        base = sync.get(kern)
        if base is None:
            continue
        points.append({
            "kernel": kern, "buffer_depth": row.get("buffer_depth", 2),
            "us": row["value"], "sync_us": base["value"],
            "speedup": base["value"] / row["value"] if row["value"] else 0.0,
            "tuned": bool(row.get("tuned")),
        })
    return points


def kernel_table(points: List[dict]) -> str:
    out = [f"{'kernel':12s} {'depth':>5s} {'us/call':>10s} "
           f"{'sync us':>10s} {'speedup':>8s}"]
    for p in points:
        out.append(f"{p['kernel']:12s} {p['buffer_depth']:5d} "
                   f"{p['us']:10.1f} {p['sync_us']:10.1f} "
                   f"{p['speedup']:7.2f}x")
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rows = load(path)
    print("=== single-pod (16x16 = 256 chips) ===")
    print(table(rows, "pod16x16"))
    print()
    print("=== multi-pod (2x16x16 = 512 chips) ===")
    print(table(rows, "pod2x16x16"))
    print()
    print("=== dominant-term advice ===")
    for r in rows:
        if r["mesh"] == "pod16x16" and not r["tag"]:
            print(f"{r['arch']}/{r['shape']}: [{r['dominant']}] {advice(r)}")
    points = kernel_points(os.path.join(os.path.dirname(path) or ".",
                                        "BENCH_kernels.json"))
    if points:
        print()
        print("=== measured kernel points (pipelined emission, "
              "latency-hiding shift) ===")
        print(kernel_table(points))


if __name__ == "__main__":
    main()
