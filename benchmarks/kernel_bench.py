"""Wall-clock microbenchmarks of the kernel suite (CPU harness).

On this CPU container, Pallas interpret mode measures the *interpreter*, not
TPU silicon, so the honest comparison is: XLA-compiled reference path
(μs/call, real) + static stream-analysis (bytes streamed, FIFO reuse, VMEM
footprint — the quantities that decide TPU speed).  On a real TPU this file
runs unchanged with ``interpret=False`` to time Mosaic kernels.
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

RNG = np.random.default_rng(0)


def _time(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # μs


def bench_reference_paths() -> List[Tuple[str, float, str]]:
    """Time the jitted XLA reference path per paper kernel (problem sizes
    as in §4.2)."""
    rows = []
    x = jnp.asarray(RNG.standard_normal(2048), jnp.float32)
    y = jnp.asarray(RNG.standard_normal(2048), jnp.float32)
    s4096 = jnp.asarray(RNG.standard_normal(4096), jnp.float32)
    r1024 = jnp.asarray(RNG.standard_normal(1024), jnp.float32)
    xs = jnp.asarray(RNG.standard_normal(1024 + 10), jnp.float32)
    w11 = jnp.asarray(RNG.standard_normal(11) * 0.1, jnp.float32)
    g2d = jnp.asarray(RNG.standard_normal((74, 74)), jnp.float32)
    a64 = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
    v64 = jnp.asarray(RNG.standard_normal(64), jnp.float32)
    a32 = jnp.asarray(RNG.standard_normal((32, 32)), jnp.float32)
    b32 = jnp.asarray(RNG.standard_normal((32, 32)), jnp.float32)

    cases = [
        ("reduction/2048", jax.jit(ref.dot_ref), (x, y)),
        ("scan/4096", jax.jit(ref.scan_ref), (s4096,)),
        ("relu/1024", jax.jit(ref.relu_ref), (r1024,)),
        ("stencil1d/1024", jax.jit(ref.stencil1d_ref), (xs, w11)),
        ("stencil2d/64x64", jax.jit(ref.stencil2d_ref), (g2d, w11, w11)),
        ("gemv/64", jax.jit(ref.gemv_ref), (a64, v64)),
        ("gemm/32", jax.jit(ref.matmul_ref), (a32, b32)),
        ("fft/2048", jax.jit(lambda r, i: ref.fft_ref(r, i)), (x, y)),
        ("sort/1024", jax.jit(ref.sort_ref), (r1024,)),
    ]
    print("\n== kernel reference path timings (XLA:CPU, μs/call) ==")
    for name, fn, args in cases:
        us = _time(fn, *args)
        print(f"{name:18s} {us:10.1f} μs")
        rows.append((f"kernel_ref/{name}", us, "xla_cpu us/call"))
    return rows


def bench_stream_reports() -> List[Tuple[str, float, str]]:
    """Static stream analysis of the production matmul (FIFO reuse etc.)."""
    from repro.core import BlockStream, Direction, ssr_pallas
    from jax.experimental.pallas import tpu as pltpu

    rows = []
    print("\n== stream-analysis of ssr_matmul tiles ==")
    for (m, n, k, bm, bn, bk) in [(512, 512, 512, 128, 128, 128),
                                  (1024, 1024, 1024, 256, 256, 256)]:
        def body(a_ref, b_ref, o_ref, acc_ref):  # noqa: ANN001
            pass  # analysis only

        grid = (m // bm, n // bn, k // bk)
        fn = ssr_pallas(
            body, grid=grid,
            in_streams=[
                BlockStream((bm, bk), lambda i, j, kk: (i, kk), name="A"),
                BlockStream((bk, bn), lambda i, j, kk: (kk, j), name="B"),
            ],
            out_streams=[BlockStream((bm, bn), lambda i, j, kk: (i, j),
                                     Direction.WRITE, name="C")],
            out_shapes=[jax.ShapeDtypeStruct((m, n), jnp.bfloat16)],
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            validate=True,
        )
        rep = fn.report(dtypes=[jnp.bfloat16, jnp.bfloat16, jnp.bfloat16])
        ai = 2 * m * n * k / rep.hbm_bytes_unique
        print(f"matmul {m}x{n}x{k} tiles ({bm},{bn},{bk}): "
              f"VMEM {rep.vmem_bytes / 2**20:.1f} MiB, "
              f"streamed {rep.hbm_bytes_streamed / 2**20:.0f} MiB, "
              f"unique {rep.hbm_bytes_unique / 2**20:.0f} MiB, "
              f"reuse {rep.reuse_factor:.1f}x, AI {ai:.0f} flop/byte")
        rows.append((f"stream/matmul{m}", rep.reuse_factor,
                     f"vmem {rep.vmem_bytes} streamed {rep.hbm_bytes_streamed}"))
    return rows
