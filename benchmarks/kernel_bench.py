"""Wall-clock microbenchmarks of the kernel suite (CPU harness).

On this CPU container, Pallas interpret mode measures the *interpreter*, not
TPU silicon, so the honest comparison is: XLA-compiled reference path
(μs/call, real) + static stream-analysis (bytes streamed, FIFO reuse, VMEM
footprint — the quantities that decide TPU speed).  On a real TPU this file
runs unchanged with ``interpret=False`` to time Mosaic kernels.

Two kernel sets are enumerated with zero edits here:

* every ``@register_kernel`` entry with an ``example`` factory is timed
  (``ref`` path) and smoke-run (``ssr`` path);
* every fused (stream-chained) variant from ``kernels.chained.fused_cases``
  is raced against its unfused two-kernel composition — interleaved
  best-of-N of the real call path, plus the compiled-HLO audit that the
  intermediate buffer is gone.  Numeric disagreement beyond the case's
  tolerance is a hard failure (exit 1): a fast wrong kernel is not a win.

Run as a script to persist ``BENCH_kernels.json`` (schema below), the
machine-readable perf trajectory tracked across PRs::

    PYTHONPATH=src python benchmarks/kernel_bench.py [--quick] [--out PATH]

Schema (version 6): ``{"schema": 6, "generated_unix": float, "quick": bool,
"results": [{"name", "group", "variant", "value", "units", "rows",
"lanes", "grid", "tuned", "buffer_depth", ...}, ...]}`` — every row
carries schedule provenance (the block geometry that produced it, the data
mover's FIFO depth, and whether it came from the autotuner).  The
``autotune`` group races tuned-vs-default schedules across every
NestKernel family head — the §13 halo (stencil1d/stencil2d) and
online-rescale (attention) migrations included — and is gated: tuned may
never be slower than default beyond noise, and — in full (non ``--quick``)
runs, where iteration counts rise above CI-box noise — at least one kernel
must win with a non-default schedule.  The ``pipeline`` group is the
bandwidth-bound buffer-depth sweep (large-stride gemv + stencil1d +
causal attention): the
autotuned pipelined schedule races the synchronous depth-2 default under a
≤ 1e-5 agreement gate, and a full run must find a depth > 2 winner.  The
``dag`` group (v4) runs the whole-program fusion search of
``autotune_dag`` over every ``kernels.dag`` DagCase (layernorm,
softmax_xent, mlp_block) and races the committed graph cut against both
endpoints — all-fused and all-unfused; dag rows additionally carry
``cut_edges`` (the materialised edge indices) and ``fused_stages`` (the
largest fused component's stage count).  The ``sparse`` group (v5) gates
the CSR indirection-stream kernels (spmv, spmm): streamed-vs-baseline
agreement ≤ 1e-5, Eq. (1)–(3) model speedup > 1, and a non-zero count of
eliminated index-handling instructions; sparse rows carry problem
provenance — ``nnz`` and ``density`` of the CSR operand — alongside
``eliminated_idx_instrs``.  The ``chaos`` group (v6, run via
``--chaos-smoke``) injects one fault per resilience seam (cache read,
lowering, compile) into a dispatch with a committed tuned schedule and
gates the degradation ladder: the degraded result must agree with the
healthy one ≤ 1e-5 and the steady-state post-fault path must stay within
a bounded overhead of the healthy tuned path.

Each run also appends one summary line to ``BENCH_history.jsonl`` (date,
git sha, per-kernel speedups, committed dag cuts, and a ``degraded``
resilience summary — zero in healthy runs) — the cheap longitudinal
record raced across PRs without diffing full artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowering import DEFAULT_SCHEDULE, Schedule
from repro.kernels import registry
from repro.kernels.chained import fused_cases

RNG = np.random.default_rng(0)

#: v2: every row carries schedule provenance — the block geometry that
#: produced it (``rows``/``lanes``), the grid it launched (``None`` where
#: no Pallas grid is involved, e.g. pure-model rows) and a ``tuned`` flag
#: (True when the schedule came from the autotuner, not the default).
#: v3: adds ``buffer_depth`` — the data mover's FIFO depth the row ran
#: under (2 = synchronous Pallas double-buffer, > 2 = explicit N-deep DMA
#: rotation) — and the gated ``pipeline`` group.
#: v4: adds the gated ``dag`` group (whole-program fusion search); dag
#: rows carry ``cut_edges`` (materialised edge indices of the committed
#: partition) and ``fused_stages`` (largest fused component's stage
#: count) alongside the schedule provenance fields.
#: v5: adds the gated ``sparse`` group (CSR indirection streams); sparse
#: rows carry ``nnz``/``density`` problem provenance and the model rows
#: additionally ``eliminated_idx_instrs`` — the per-nnz index loads +
#: pointer arithmetic the indirect AGU removes from the hot loop.
#: v6: adds the gated ``chaos`` group (``--chaos-smoke``): per-seam
#: degraded-vs-healthy agreement and steady-state overhead rows, and the
#: history line's ``degraded`` resilience summary (fallback/degraded
#: dispatch counters + structured fallback-event count).
BENCH_SCHEMA = 6


def _row(name: str, group: str, variant: str, value: float, units: str,
         **extras) -> Dict:
    row = {"name": name, "group": group, "variant": variant,
           "value": float(value), "units": units,
           # schedule provenance defaults: the untuned default geometry
           "rows": DEFAULT_SCHEDULE.rows, "lanes": DEFAULT_SCHEDULE.lanes,
           "grid": None, "tuned": False,
           "buffer_depth": DEFAULT_SCHEDULE.buffer_depth}
    row.update(extras)
    return row


def _sched_extras(sched: Schedule, grid=None, *, tuned: bool) -> Dict:
    """Provenance fields for a row that ran under ``sched``."""
    return {"rows": sched.rows, "lanes": sched.lanes,
            "grid": list(grid) if grid is not None else None,
            "tuned": bool(tuned), "buffer_depth": sched.buffer_depth}


def _time(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-N μs/call (min over iters absorbs scheduler noise)."""
    for _ in range(warmup):
        jax.block_until_ready(jax.tree.leaves(fn(*args)))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # μs


def bench_reference_paths(iters: int = 5) -> List[Dict]:
    """Best-of-N of the jitted XLA reference path of every registered
    kernel (problem sizes as in §4.2, from each entry's example factory)."""
    rows = []
    print("\n== kernel reference path timings (XLA:CPU, best-of-N μs/call) ==")
    for entry in registry.entries():
        if entry.example is None:
            continue
        args, kwargs = entry.example(RNG)
        if entry.name in SPARSE_GATED:
            # CSR refs validate + densify host-side (the ELL width is
            # data-dependent), so they cannot be traced — time them eagerly.
            fn = lambda *a, _e=entry, _kw=kwargs: _e.ref(*a, **_kw)
        else:
            fn = jax.jit(lambda *a, _e=entry, _kw=kwargs: _e.ref(*a, **_kw))
        us = _time(fn, *args, iters=iters)
        print(f"{entry.name:16s} {entry.problem:26s} {us:10.1f} μs")
        rows.append(_row(f"kernel_ref/{entry.name}", "kernel_ref", "ref",
                         us, "us/call", iters=iters))
    return rows


def smoke_ssr_paths() -> List[Dict]:
    """One interpret-mode call per registered streamed kernel (CI smoke)."""
    rows = []
    print("\n== kernel ssr-path smoke (Pallas interpret) ==")
    for entry in registry.entries():
        if entry.example is None:
            continue
        args, kwargs = entry.example(RNG)
        t0 = time.perf_counter()
        jax.block_until_ready(
            jax.tree.leaves(entry.ssr(*args, **kwargs)))
        ms = (time.perf_counter() - t0) * 1e3
        print(f"{entry.name:16s} ok ({ms:7.1f} ms incl. trace)")
        rows.append(_row(f"kernel_ssr_smoke/{entry.name}", "kernel_ssr_smoke",
                         "ssr", ms, "interpret ms"))
    return rows


def bench_stream_reports() -> List[Dict]:
    """Static stream analysis of the production matmul (FIFO reuse etc.)."""
    from repro.core import BlockStream, Direction, ssr_pallas
    from jax.experimental.pallas import tpu as pltpu

    rows = []
    print("\n== stream-analysis of ssr_matmul tiles ==")
    for (m, n, k, bm, bn, bk) in [(512, 512, 512, 128, 128, 128),
                                  (1024, 1024, 1024, 256, 256, 256)]:
        def body(a_ref, b_ref, o_ref, acc_ref):  # noqa: ANN001
            pass  # analysis only

        grid = (m // bm, n // bn, k // bk)
        fn = ssr_pallas(
            body, grid=grid,
            in_streams=[
                BlockStream((bm, bk), lambda i, j, kk: (i, kk), name="A"),
                BlockStream((bk, bn), lambda i, j, kk: (kk, j), name="B"),
            ],
            out_streams=[BlockStream((bm, bn), lambda i, j, kk: (i, j),
                                     Direction.WRITE, name="C")],
            out_shapes=[jax.ShapeDtypeStruct((m, n), jnp.bfloat16)],
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            validate=True,
        )
        rep = fn.report(dtypes=[jnp.bfloat16, jnp.bfloat16, jnp.bfloat16])
        ai = 2 * m * n * k / rep.hbm_bytes_unique
        print(f"matmul {m}x{n}x{k} tiles ({bm},{bn},{bk}): "
              f"VMEM {rep.vmem_bytes / 2**20:.1f} MiB, "
              f"streamed {rep.hbm_bytes_streamed / 2**20:.0f} MiB, "
              f"unique {rep.hbm_bytes_unique / 2**20:.0f} MiB, "
              f"reuse {rep.reuse_factor:.1f}x, AI {ai:.0f} flop/byte")
        rows.append(_row(f"stream/matmul{m}", "stream", "ssr",
                         rep.reuse_factor, "reuse_factor",
                         vmem_bytes=rep.vmem_bytes,
                         hbm_bytes_streamed=rep.hbm_bytes_streamed))
    return rows


# --------------------------------------------------------------------------
# Compiled-nest kernels: ssr-vs-baseline agreement + cost-model gate
# --------------------------------------------------------------------------

#: Numeric agreement required between a compiled-nest kernel's streamed
#: and baseline engines (same problem, same dtype — only delivery differs).
NEST_AGREEMENT_TOL = 1e-5


def _nest_models():
    """(kernel name, cost-model LoopNest) for the compiled-nest gate."""
    from repro.core import compiler
    from repro.kernels.stencil import TAPS

    return [("gemm", compiler.gemm_nest(32, 32, 32)),
            ("stencil1d", compiler.stencil_nest(1024, TAPS)),
            ("gemv", compiler.gemv_nest(64, 64))]


def bench_nest_gate() -> List[Dict]:
    """Gate the registry's compiled-nest kernels (gemm, stencil1d).

    Two hard requirements per kernel, mirrored in ``validate_bench_json``:
    the streamed engine must agree with the baseline engine within
    ``NEST_AGREEMENT_TOL`` (a fast wrong kernel is not a win), and the
    Eq. (1)–(3) model must predict a speedup > 1 for the paper-size nest
    (otherwise streaming it is pointless and the registry entry is wrong).
    """
    from repro.core.lowering import plan_stats

    rows = []
    print("\n== compiled-nest gate: ssr vs baseline + cost model ==")
    for name, nest in _nest_models():
        entry = registry.get(name)
        args, kwargs = entry.example(RNG)
        ssr_out = entry.ssr(*args, **kwargs)
        base_out = entry.baseline(*args, **kwargs)
        diff = max(float(jnp.max(jnp.abs(jnp.asarray(g) - jnp.asarray(w))))
                   for g, w in zip(jax.tree.leaves(ssr_out),
                                   jax.tree.leaves(base_out)))
        if diff > NEST_AGREEMENT_TOL:
            print(f"FAIL {name}: ssr disagrees with baseline by {diff:.2e} "
                  f"> {NEST_AGREEMENT_TOL}", file=sys.stderr)
            raise SystemExit(1)
        # score the configuration the registry actually executes: every
        # affine ref streamed (auto lanes), not the 2-mover default the
        # nest-output path cannot even lower
        from repro.core.nest_analysis import auto_lanes

        stats = plan_stats(nest, num_lanes=auto_lanes(nest))
        speedup = stats.n_base / stats.n_ssr
        if not (stats.ssrified and speedup > 1.0):
            print(f"FAIL {name}: Eq. (3) model speedup {speedup:.2f} <= 1",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"{name:12s} agreement {diff:.1e}  model speedup "
              f"{speedup:4.2f}x (N {stats.n_base} -> {stats.n_ssr})")
        rows.append(_row(f"nest/{name}", "nest", "agreement", diff,
                         "max_abs_diff"))
        rows.append(_row(f"nest/{name}", "nest", "model", speedup,
                         "model_speedup", n_base=stats.n_base,
                         n_ssr=stats.n_ssr))
    return rows


# --------------------------------------------------------------------------
# CSR indirection streams: sparse gate (agreement + eliminated-instr model)
# --------------------------------------------------------------------------

#: The CSR kernels the sparse gate covers — the indirection-stream path
#: (Indirection-SSR / Sparse-SSR follow-ups to the base paper).
SPARSE_GATED = ("spmv", "spmm")


def _sparse_cases(quick: bool):
    """(name, args, nest, nnz, density) per gated sparse kernel."""
    from repro.core import compiler
    from repro.kernels import sparse as sp

    cases = []
    m, n, c = (32, 48, 16) if quick else (96, 128, 32)
    for name, density in (("spmv", 0.15), ("spmm", 0.15)):
        data, indices, indptr = sp.random_csr(RNG, m, n, density)
        if name == "spmv":
            x = RNG.standard_normal(n).astype(np.float32)
            args = (data, indices, indptr, x)
        else:
            x = RNG.standard_normal((n, c)).astype(np.float32)
            args = (data, indices, indptr, x)
        vals, cidx, rows_m, k = sp.csr_to_ell(data, indices, indptr, n)
        if name == "spmv":
            nest = compiler.spmv_nest(rows_m, k)
        else:
            pitch = -(-c // sp._TABLE_PITCH) * sp._TABLE_PITCH
            nest = compiler.spmm_nest(rows_m, c, k, pitch)
        cases.append((name, args, nest, int(data.size),
                      float(data.size) / float(m * n)))
    return cases


def bench_sparse(quick: bool = False) -> List[Dict]:
    """Gate the CSR indirection-stream kernels (spmv, spmm).

    Hard failures (exit 1), mirrored in ``validate_sparse_rows``:

    * the streamed gather engine disagrees with the explicit-``jnp.take``
      baseline beyond ``NEST_AGREEMENT_TOL`` (a fast wrong gather is not
      a win);
    * the Eq. (1)–(3) model — extended with the per-nnz index loads +
      pointer arithmetic the indirect AGU eliminates — predicts speedup
      ≤ 1, or eliminates zero index-handling instructions (then the
      indirect ref never reached the streamer and the lowering is wrong).
    """
    from repro.core.lowering import plan_stats
    from repro.core.nest_analysis import auto_lanes

    rows: List[Dict] = []
    print("\n== CSR sparse gate: ssr vs baseline + indirection model ==")
    for name, args, nest, nnz, density in _sparse_cases(quick):
        entry = registry.get(name)
        ssr_out = np.asarray(entry.ssr(*args))
        base_out = np.asarray(entry.baseline(*args))
        diff = float(np.max(np.abs(ssr_out - base_out))) if ssr_out.size \
            else 0.0
        if diff > NEST_AGREEMENT_TOL:
            print(f"FAIL {name}: ssr disagrees with baseline by {diff:.2e} "
                  f"> {NEST_AGREEMENT_TOL}", file=sys.stderr)
            raise SystemExit(1)
        stats = plan_stats(nest, num_lanes=auto_lanes(nest))
        speedup = stats.n_base / stats.n_ssr
        if not (stats.ssrified and speedup > 1.0):
            print(f"FAIL {name}: Eq. (3) model speedup {speedup:.2f} <= 1",
                  file=sys.stderr)
            raise SystemExit(1)
        if stats.eliminated_idx_instrs <= 0:
            print(f"FAIL {name}: indirect ref eliminated no index-handling "
                  "instructions — the gather never reached the streamer",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"{name:12s} nnz {nnz:6d} (density {density:5.3f})  "
              f"agreement {diff:.1e}  model speedup {speedup:4.2f}x  "
              f"idx instrs eliminated {stats.eliminated_idx_instrs}")
        rows.append(_row(f"sparse/{name}", "sparse", "agreement", diff,
                         "max_abs_diff", nnz=nnz, density=density))
        rows.append(_row(f"sparse/{name}", "sparse", "model", speedup,
                         "model_speedup", nnz=nnz, density=density,
                         n_base=stats.n_base, n_ssr=stats.n_ssr,
                         eliminated_idx_instrs=stats.eliminated_idx_instrs))
    return rows


def validate_sparse_rows(results: Sequence[Dict]) -> None:
    """The sparse acceptance gate, re-applied to persisted rows.

    Every gated CSR kernel must have agreement + model rows; every sparse
    row must carry the v5 problem provenance (integer ``nnz``, ``density``
    in [0, 1]); agreement must hold to ``NEST_AGREEMENT_TOL``; and the
    model row must record a speedup > 1 with a positive
    ``eliminated_idx_instrs`` count.
    """
    by_kernel: Dict[str, Dict[str, Dict]] = {}
    for r in results:
        if r.get("group") == "sparse":
            if not isinstance(r.get("nnz"), int) or r["nnz"] < 0:
                raise ValueError(f"sparse row missing integer nnz: {r}")
            d = r.get("density")
            if not isinstance(d, (int, float)) or not 0.0 <= d <= 1.0:
                raise ValueError(f"sparse row density outside [0, 1]: {r}")
            by_kernel.setdefault(r["name"].split("/")[1], {})[r["variant"]] = r
    for kern in SPARSE_GATED:
        pair = by_kernel.get(kern)
        if not pair or "agreement" not in pair or "model" not in pair:
            raise ValueError(f"no sparse gate rows for {kern!r}")
        if pair["agreement"]["value"] > NEST_AGREEMENT_TOL:
            raise ValueError(
                f"{kern}: ssr-vs-baseline disagreement "
                f"{pair['agreement']['value']} > {NEST_AGREEMENT_TOL}")
        model = pair["model"]
        if model["value"] <= 1.0:
            raise ValueError(f"{kern}: model speedup {model['value']} <= 1")
        if not isinstance(model.get("eliminated_idx_instrs"), int) \
                or model["eliminated_idx_instrs"] <= 0:
            raise ValueError(
                f"{kern}: model row must record a positive "
                "eliminated_idx_instrs count")


# --------------------------------------------------------------------------
# Schedule autotuner sweep: tuned-vs-default gate + provenance rows
# --------------------------------------------------------------------------

#: The kernels the autotune gate covers (the CI ``autotune-smoke`` job):
#: every ``ssr_call``-routed NestKernel family head, incl. the §13
#: halo-read (stencil1d/stencil2d) and online-rescaled-accumulator
#: (attention) lowerings.
TUNE_GATED = ("reduction", "relu", "gemm", "stencil1d", "stencil2d",
              "gemv", "attention")

#: Wall-clock tolerance of the tuned-never-slower gate: the tuner measures
#: then the gate *re-races* winner vs default interleaved, so a winner that
#: only won by scheduler noise may regress a little — but not this much.
TUNE_GATE_TOL = 1.15


def _autotune_cases(quick: bool):
    """(name, nest, operands, mode, candidates, call, grid_of) per kernel.

    ``operands``/``mode`` replicate exactly what ``NestKernel`` passes to
    ``autotune.lookup``, so the committed winners are the ones transparent
    dispatch later finds.  Every gated kernel — the §13 halo/rescale
    migrations included — searches the standard lowering-derived candidate
    set; illegal geometries (e.g. a tile too narrow for a halo window) are
    auto-filtered by the legality walk.
    """
    from repro.core import autotune, compiler
    from repro.kernels.stencil import TAPS

    cases = []

    def add(name, nest, operands, mode, candidates=None, grid_of=None):
        entry = registry.get(name)
        args, kwargs = entry.example(RNG)

        def call(sched, _e=entry, _a=args, _k=kwargs):
            return _e.ssr(*_a, schedule=sched, **_k)

        if grid_of is None:
            def grid_of(sched, _nest=nest):
                try:
                    return autotune._lower_candidate(_nest, sched).grid
                except Exception:
                    return None
        cases.append((name, nest, operands, mode, candidates, call, grid_of))

    (x, y), _ = registry.get("reduction").example(RNG)
    add("reduction", compiler.dot_product_nest(x.shape[0]),
        {"A": x, "B": y}, "reduce")

    (xr,), _ = registry.get("relu").example(RNG)
    add("relu", compiler.elementwise_nest(xr.shape[0]), {"X": xr}, "map")

    (a, b), _ = registry.get("gemm").example(RNG)
    add("gemm", compiler.gemm_nest(a.shape[0], b.shape[1], a.shape[1]),
        {"A": a, "B": b}, "reduce")

    (xs, ws), _ = registry.get("stencil1d").example(RNG)
    add("stencil1d", compiler.stencil_nest(xs.shape[0] - (TAPS - 1), TAPS),
        {"x": xs, "w": ws}, "reduce")

    (x2, wx2, wy2), _ = registry.get("stencil2d").example(RNG)
    h2, wd2 = x2.shape[0] - (TAPS - 1), x2.shape[1] - (TAPS - 1)
    add("stencil2d", compiler.stencil2d_nest(h2, wd2, TAPS),
        {"x": x2, "wx": wx2, "wy": wy2}, "reduce")

    (ag, xg), _ = registry.get("gemv").example(RNG)
    add("gemv", compiler.gemv_nest(*ag.shape), {"A": ag, "x": xg}, "reduce")

    (q, k, v), _ = registry.get("attention").example(RNG)
    add("attention", compiler.attention_nest(q.shape[0], k.shape[0],
                                             q.shape[1]),
        {"Q": q, "K": k, "V": v}, "reduce")
    return cases


def bench_autotune(quick: bool = False) -> List[Dict]:
    """Run the schedule search per gated kernel; gate tuned ≥ default.

    Hard failures (exit 1), mirrored in ``validate_autotune_rows``:

    * the tuned schedule's output disagrees with the default schedule's
      beyond the entry tolerance (a fast wrong schedule is not a win);
    * the tuned schedule re-races slower than ``TUNE_GATE_TOL`` × default
      on any gated kernel;
    * no kernel picked a measurably faster non-default schedule — the
      whole point of the search.
    """
    from repro.core import autotune

    rows: List[Dict] = []
    iters = 3 if quick else 7
    nondefault_wins = 0
    print(f"\n== schedule autotune sweep (best-of-{iters} μs/call) ==")
    for name, nest, operands, mode, cands, call, grid_of \
            in _autotune_cases(quick):
        entry = registry.get(name)
        if cands is None:
            cands = autotune.candidate_schedules(nest, quick=quick)
        res = autotune.autotune(
            nest, None, operands, mode=mode, out_dtype="float32",
            call=call, candidates=cands, top_k=4 if quick else 8,
            warmup=1, iters=iters, force=True)

        tuned_out = call(res.schedule)
        default_out = call(DEFAULT_SCHEDULE)
        for g, w in zip(jax.tree.leaves(tuned_out),
                        jax.tree.leaves(default_out)):
            if not np.allclose(np.asarray(g), np.asarray(w), **entry.tol):
                # drop the committed winner before failing: a schedule
                # that changes the answer must never stay in the
                # persistent cache for transparent dispatch to pick up
                autotune.global_cache().invalidate(res.key)
                print(f"FAIL {name}: tuned schedule disagrees with default "
                      f"beyond tol {entry.tol} (cache entry invalidated)",
                      file=sys.stderr)
                raise SystemExit(1)

        # Final interleaved race, winner vs default: the screening pass
        # (round-robin best-of-N inside the tuner) picks a candidate, the
        # race validates it — and its verdict is what gets committed.  A
        # screening pick that loses the race is replaced by the default,
        # so the persisted schedule is never slower than the default *as
        # measured here*.  A default winner has nothing to race: both
        # thunks would be the same cached pipeline, pure jitter.
        import dataclasses as _dc

        nondefault = not res.is_default
        if nondefault:
            tf, td = _interleaved_best(lambda: call(res.schedule),
                                       lambda: call(DEFAULT_SCHEDULE),
                                       (), {}, warmup=2, iters=max(7, iters))
            if tf > td:
                print(f"  {name}: screening winner lost the final race "
                      f"({tf:.1f} vs {td:.1f} μs) — committing default")
                autotune.global_cache().put(res.key, DEFAULT_SCHEDULE, meta={
                    "tuned_us": td, "default_us": td,
                    "candidates": res.candidates, "raced_back": True})
                res = _dc.replace(res, schedule=DEFAULT_SCHEDULE,
                                  tuned_us=td, default_us=td)
                tf, nondefault = td, False
        else:
            tf = td = _time(lambda: call(res.schedule), iters=max(5, iters))
        if tf > td * TUNE_GATE_TOL:   # tripwire: unreachable by design
            print(f"FAIL {name}: tuned schedule {tf:.1f} μs is slower than "
                  f"default {td:.1f} μs × {TUNE_GATE_TOL}", file=sys.stderr)
            raise SystemExit(1)
        if nondefault and tf < td:
            nondefault_wins += 1
        s = res.schedule
        grid = grid_of(s)
        print(f"{name:12s} tuned ({s.rows}x{s.lanes}"
              + (f", order={s.axis_order}" if s.axis_order else "")
              + f") {tf:10.1f} μs  default {td:10.1f} μs  "
              f"speedup {td / tf:4.2f}x  candidates {res.candidates}")
        rows.append(_row(f"autotune/{name}", "autotune", "tuned", tf,
                         "us/call", speedup=td / tf,
                         candidates=res.candidates,
                         measured=res.measured, nondefault=nondefault,
                         cache_key=res.key,
                         # tuned = "came from the autotuner, not the
                         # default" — a default winner is not tuned
                         **_sched_extras(s, grid, tuned=nondefault)))
        rows.append(_row(f"autotune/{name}", "autotune", "default", td,
                         "us/call",
                         **_sched_extras(DEFAULT_SCHEDULE,
                                         grid_of(DEFAULT_SCHEDULE),
                                         tuned=False)))
    if nondefault_wins == 0:
        # Whether a non-default geometry wins is a measurement, not an
        # invariant: on a noisy box with --quick iteration counts every
        # final race can (correctly) fall back to the default.  The full
        # run gates it hard — that is the artifact committed per PR; the
        # CI smoke gates only the robust half (tuned never slower,
        # outputs agree).
        if not quick:
            print("FAIL autotune: no kernel picked a measurably faster "
                  "non-default schedule", file=sys.stderr)
            raise SystemExit(1)
        print("WARN autotune: no non-default winner in this --quick run "
              "(noise-dominated); the full run gates this hard")
    print(f"non-default winners: {nondefault_wins}/{len(TUNE_GATED)}")
    return rows


def validate_autotune_rows(results: Sequence[Dict],
                           require_nondefault: bool = True) -> None:
    """The autotune acceptance gate, re-applied to persisted rows.

    ``require_nondefault=False`` (quick/CI-smoke runs) keeps only the
    robust half of the gate — tuned never slower than default — because a
    non-default win is a measurement, not an invariant (see
    :func:`bench_autotune`).
    """
    by_kernel: Dict[str, Dict[str, Dict]] = {}
    for r in results:
        if r.get("group") == "autotune":
            by_kernel.setdefault(r["name"].split("/")[1], {})[r["variant"]] = r
    for kern in TUNE_GATED:
        pair = by_kernel.get(kern)
        if not pair or "tuned" not in pair or "default" not in pair:
            raise ValueError(f"no autotune rows for {kern!r}")
        if pair["tuned"]["value"] > pair["default"]["value"] * TUNE_GATE_TOL:
            raise ValueError(
                f"{kern}: tuned {pair['tuned']['value']} slower than "
                f"default {pair['default']['value']} x {TUNE_GATE_TOL}")
    if require_nondefault and not any(
            p["tuned"].get("nondefault") and
            p["tuned"]["value"] < p["default"]["value"]
            for p in by_kernel.values() if "tuned" in p
            and "default" in p):
        raise ValueError("no kernel won with a non-default schedule")


# --------------------------------------------------------------------------
# Pipelined emission sweep: buffer-depth race on bandwidth-bound kernels
# --------------------------------------------------------------------------

#: Numeric agreement gate of the pipeline sweep: the pipelined schedule
#: must match the synchronous default to ≤ 1e-5 — tighter than the entry
#: tolerances because only operand *delivery* changes, never arithmetic.
PIPE_AGREEMENT_TOL = 1e-5

#: The kernels the pipeline gate covers: the bandwidth-bound entries
#: (GEMV streams the whole matrix once per call; the stencil is ~1 fmadd
#: per byte; attention's kv walk streams K and V once per query tile),
#: where hiding the fetch behind compute is the whole game.
PIPE_GATED = ("gemv", "stencil1d", "attention")


def _pipeline_cases(quick: bool):
    """(name, nest, operands, mode, candidates, call, grid, tol) per kernel.

    Large-stride shapes — bigger than the §4.2 example sizes — so the
    per-step fetch the rotation hides is resolvable above timing noise.
    Candidates cross the depth choices with each kernel's native geometry
    knob (the stencil's block width); depth 2 is always among them, so the
    sweep races the synchronous default by construction.  ``mode`` is what
    ``NestKernel`` passes to the schedule-cache lookup, so the committed
    winners are the ones transparent dispatch later finds.
    """
    from repro.core import compiler
    from repro.kernels.attention import ssr_flash_attention
    from repro.kernels.gemv import ssr_gemv
    from repro.kernels.stencil import TAPS, ssr_stencil1d

    depths = (2, 3) if quick else (2, 3, 4)
    tol = {"rtol": PIPE_AGREEMENT_TOL, "atol": PIPE_AGREEMENT_TOL}
    cases = []

    m, n = (64, 1024) if quick else (256, 4096)
    a = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    xv = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    cases.append((
        "gemv", compiler.gemv_nest(m, n), {"A": a, "x": xv}, "reduce",
        [Schedule(buffer_depth=d) for d in depths],
        lambda s, _a=a, _x=xv: ssr_gemv(_a, _x, schedule=s),
        (m // 8,), tol))

    n_st = (1 << 14) if quick else (1 << 16)
    xs = jnp.asarray(RNG.standard_normal(n_st + TAPS - 1), jnp.float32)
    ws = jnp.asarray(RNG.standard_normal(TAPS) * 0.3, jnp.float32)
    widths = (128, 512) if quick else (128, 512, 1024)
    cases.append((
        "stencil1d", compiler.stencil_nest(n_st, TAPS),
        {"x": xs, "w": ws}, "reduce",
        [Schedule(lanes=w, buffer_depth=d)
         for w in widths for d in depths],
        lambda s, _x=xs, _w=ws: ssr_stencil1d(_x, _w, schedule=s),
        None, tol))

    sq = 256 if quick else 1024
    q = jnp.asarray(RNG.standard_normal((sq, 64)), jnp.float32)
    kk = jnp.asarray(RNG.standard_normal((sq, 64)), jnp.float32)
    vv = jnp.asarray(RNG.standard_normal((sq, 64)), jnp.float32)
    cases.append((
        "attention", compiler.attention_nest(sq, sq, 64),
        {"Q": q, "K": kk, "V": vv}, "reduce",
        [Schedule(buffer_depth=d) for d in depths],
        lambda s, _q=q, _k=kk, _v=vv: ssr_flash_attention(
            _q, _k, _v, causal=True, schedule=s),
        None, tol))
    return cases


def bench_pipeline(quick: bool = False) -> List[Dict]:
    """Race the autotuned pipelined schedule vs the synchronous default.

    Hard failures (exit 1), mirrored in ``validate_pipeline_rows``:

    * the pipelined winner's output disagrees with the synchronous
      depth-2 default beyond ``PIPE_AGREEMENT_TOL`` (delivery must never
      change the numbers);
    * the committed winner re-races slower than ``TUNE_GATE_TOL`` ×
      default on any gated kernel (never-slower is structural: a race
      loser is replaced by the default before commit);
    * in full runs, no kernel won with ``buffer_depth > 2`` — the
      latency-hiding claim this sweep exists to gate.
    """
    import dataclasses as _dc

    from repro.core import autotune

    rows: List[Dict] = []
    iters = 3 if quick else 7
    deep_wins = 0
    print(f"\n== pipelined emission sweep (best-of-{iters} μs/call) ==")
    for name, nest, operands, mode, cands, call, grid, tol \
            in _pipeline_cases(quick):
        res = autotune.autotune(
            nest, None, operands, mode=mode, out_dtype="float32",
            call=call, candidates=cands, top_k=len(cands),
            warmup=1, iters=iters, force=True)

        tuned_out = call(res.schedule)
        sync_out = call(DEFAULT_SCHEDULE)
        for g, w in zip(jax.tree.leaves(tuned_out),
                        jax.tree.leaves(sync_out)):
            if not np.allclose(np.asarray(g), np.asarray(w), **tol):
                autotune.global_cache().invalidate(res.key)
                print(f"FAIL {name}: pipelined schedule disagrees with the "
                      f"synchronous default beyond {PIPE_AGREEMENT_TOL} "
                      "(cache entry invalidated)", file=sys.stderr)
                raise SystemExit(1)

        # Final interleaved race vs the synchronous default — same
        # commit-the-race-verdict contract as bench_autotune: a screening
        # winner that loses here is replaced by the default in the cache,
        # so the persisted schedule is never slower as measured.
        pipelined = res.schedule.buffer_depth > 2
        if res.schedule != DEFAULT_SCHEDULE:
            tf, td = _interleaved_best(lambda: call(res.schedule),
                                       lambda: call(DEFAULT_SCHEDULE),
                                       (), {}, warmup=2, iters=max(7, iters))
            if tf > td:
                print(f"  {name}: pipelined winner lost the final race "
                      f"({tf:.1f} vs {td:.1f} μs) — committing default")
                autotune.global_cache().put(res.key, DEFAULT_SCHEDULE, meta={
                    "tuned_us": td, "default_us": td,
                    "candidates": res.candidates, "raced_back": True})
                res = _dc.replace(res, schedule=DEFAULT_SCHEDULE,
                                  tuned_us=td, default_us=td)
                tf, pipelined = td, False
        else:
            tf = td = _time(lambda: call(res.schedule), iters=max(5, iters))
        if tf > td * TUNE_GATE_TOL:   # tripwire: unreachable by design
            print(f"FAIL {name}: pipelined {tf:.1f} μs slower than "
                  f"sync default {td:.1f} μs × {TUNE_GATE_TOL}",
                  file=sys.stderr)
            raise SystemExit(1)
        if pipelined and tf < td:
            deep_wins += 1
        s = res.schedule
        print(f"{name:12s} depth={s.buffer_depth} lanes={s.lanes} "
              f"{tf:10.1f} μs  sync default {td:10.1f} μs  "
              f"speedup {td / tf:5.2f}x  candidates {res.candidates}")
        rows.append(_row(f"pipeline/{name}", "pipeline", "pipelined", tf,
                         "us/call", speedup=td / tf,
                         candidates=res.candidates, cache_key=res.key,
                         agreement_tol=PIPE_AGREEMENT_TOL,
                         **_sched_extras(s, grid, tuned=pipelined)))
        rows.append(_row(f"pipeline/{name}", "pipeline", "sync", td,
                         "us/call",
                         **_sched_extras(DEFAULT_SCHEDULE, grid,
                                         tuned=False)))
    if deep_wins == 0:
        if not quick:
            print("FAIL pipeline: no bandwidth-bound kernel won with "
                  "buffer_depth > 2", file=sys.stderr)
            raise SystemExit(1)
        print("WARN pipeline: no depth > 2 winner in this --quick run "
              "(noise-dominated); the full run gates this hard")
    print(f"pipelined winners: {deep_wins}/{len(PIPE_GATED)}")
    return rows


def validate_pipeline_rows(results: Sequence[Dict],
                           require_deep: bool = True) -> None:
    """The pipeline acceptance gate, re-applied to persisted rows.

    ``require_deep=False`` (quick/CI-smoke runs) keeps only the robust
    half — pipelined never slower than the synchronous default; a full
    artifact must additionally record a ``buffer_depth > 2`` winner.
    """
    by_kernel: Dict[str, Dict[str, Dict]] = {}
    for r in results:
        if r.get("group") == "pipeline":
            by_kernel.setdefault(r["name"].split("/")[1], {})[r["variant"]] = r
    for kern in PIPE_GATED:
        pair = by_kernel.get(kern)
        if not pair or "pipelined" not in pair or "sync" not in pair:
            raise ValueError(f"no pipeline rows for {kern!r}")
        if pair["pipelined"]["value"] > pair["sync"]["value"] * TUNE_GATE_TOL:
            raise ValueError(
                f"{kern}: pipelined {pair['pipelined']['value']} slower "
                f"than sync {pair['sync']['value']} x {TUNE_GATE_TOL}")
        if pair["sync"].get("buffer_depth") != 2:
            raise ValueError(f"{kern}: sync row must record depth 2")
    if require_deep and not any(
            p["pipelined"].get("buffer_depth", 2) > 2 and
            p["pipelined"]["value"] < p["sync"]["value"]
            for p in by_kernel.values()
            if "pipelined" in p and "sync" in p):
        raise ValueError("no kernel won with buffer_depth > 2")


# --------------------------------------------------------------------------
# Fused (stream-chained) variants vs their unfused compositions
# --------------------------------------------------------------------------

# Bench problem sizes, chosen so the quantity chaining eliminates (the
# intermediate HBM round-trip + the second kernel dispatch) is resolvable
# above CPU timing noise.  gemv_relu uses the paper's §4.2 GEMV size.
_FUSED_BENCH_ARGS: Dict[str, Callable[[bool], Tuple[tuple, dict]]] = {
    "gemv_relu": lambda quick: (
        (jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32),
         jnp.asarray(RNG.standard_normal(64), jnp.float32)), {}),
    "stencil1d_relu": lambda quick: (
        (jnp.asarray(RNG.standard_normal((2048 if quick else 16384) + 10),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(11) * 0.3, jnp.float32)), {}),
    "sum_sq_diff": lambda quick: (
        (jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32)), {}),
    "axpy_dot": lambda quick: (
        (jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32)), {"alpha": 0.5}),
}


def _interleaved_best(f: Callable, u: Callable, args: tuple, kwargs: dict,
                      warmup: int, iters: int) -> Tuple[float, float]:
    """Race two callables back-to-back so drift hits both equally."""
    for _ in range(warmup):
        jax.block_until_ready(jax.tree.leaves(f(*args, **kwargs)))
        jax.block_until_ready(jax.tree.leaves(u(*args, **kwargs)))
    bf = bu = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(f(*args, **kwargs)))
        bf = min(bf, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(u(*args, **kwargs)))
        bu = min(bu, time.perf_counter() - t0)
    return bf * 1e6, bu * 1e6


def bench_fused(quick: bool = False, check_hlo: bool = True) -> List[Dict]:
    """Fused single kernel vs unfused two-kernel composition, best-of-N.

    Numeric disagreement beyond the case tolerance raises ``SystemExit`` —
    the benchmark doubles as the CI agreement gate.  When ``check_hlo`` is
    set, the compiled-HLO fusion audit (intermediate buffer counts) is also
    recorded per case.
    """
    from repro.launch.hlo_analysis import check_fusion

    rows = []
    warmup, iters = (1, 3) if quick else (2, 9)
    print("\n== fused (stream-chained) vs unfused composition "
          f"(interpret, best-of-{iters} μs/call) ==")
    for case in fused_cases():
        bench_args = _FUSED_BENCH_ARGS.get(case.name)
        # a case without a tuned bench size still benches at its example
        # size — new FusedCases land here with zero edits
        args, kwargs = (bench_args(quick) if bench_args
                        else case.example(RNG))

        fused_out = case.fused(*args, **kwargs)
        unfused_out = case.unfused(*args, **kwargs)
        for g, w in zip(jax.tree.leaves(fused_out),
                        jax.tree.leaves(unfused_out)):
            if not np.allclose(np.asarray(g), np.asarray(w), **case.tol):
                print(f"FAIL {case.name}: fused disagrees with unfused "
                      f"beyond tol {case.tol}", file=sys.stderr)
                raise SystemExit(1)

        tf, tu = _interleaved_best(case.fused, case.unfused, args, kwargs,
                                   warmup, iters)
        speedup = tu / tf
        extras: Dict = {"iters": iters}
        if check_hlo:
            dtype, dims = case.inter_type(*args, **kwargs)
            chk = check_fusion(case.fused, case.unfused, args, kwargs,
                               dtype, dims)
            extras.update(
                intermediate=f"{dtype}{list(dims)}",
                fused_buffers=chk.fused_buffers,
                unfused_buffers=chk.unfused_buffers,
                intermediate_eliminated=chk.intermediate_eliminated)
        print(f"{case.name:16s} fused {tf:10.1f} μs  unfused {tu:10.1f} μs  "
              f"speedup {speedup:4.2f}x"
              + (f"  intermediate_eliminated={extras.get('intermediate_eliminated')}"
                 if check_hlo else ""))
        rows.append(_row(f"fused/{case.name}", "fused", "fused",
                         tf, "us/call", **extras))
        rows.append(_row(f"fused/{case.name}", "fused", "unfused",
                         tu, "us/call", speedup=speedup, **extras))
    return rows


# --------------------------------------------------------------------------
# Fused DAGs: cost-model-guided cut search + committed-partition gate
# --------------------------------------------------------------------------

#: The DAG kernels the fusion-search gate covers (the registry's
#: ``kernels.dag`` cases — each a 3-stage graph with a multi-consumer
#: intermediate).
DAG_GATED = ("layernorm", "softmax_xent", "mlp_block")


def _dag_fused_stages(dag, cut: Sequence[int]) -> int:
    """Largest fused component's stage count under ``cut`` (3 = the whole
    diamond in one kernel, 1 = fully unfused)."""
    from repro.core.lowering import _dag_components

    comps = _dag_components(dag, frozenset(int(i) for i in cut))
    return max(len(c) for c in comps)


def _interleaved3(a: Callable, b: Callable, c: Callable,
                  warmup: int, iters: int) -> Tuple[float, float, float]:
    """Three-way interleaved best-of-N (μs) so drift hits all equally."""
    for _ in range(warmup):
        for fn in (a, b, c):
            jax.block_until_ready(jax.tree.leaves(fn()))
    best = [float("inf")] * 3
    for _ in range(iters):
        for i, fn in enumerate((a, b, c)):
            t0 = time.perf_counter()
            jax.block_until_ready(jax.tree.leaves(fn()))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best[0] * 1e6, best[1] * 1e6, best[2] * 1e6


def bench_dag(quick: bool = False, check_hlo: bool = True) -> List[Dict]:
    """Whole-program fusion search per DagCase; gate the committed cut.

    Per case: (a) fused / unfused / reference outputs must agree within
    the case tolerance (a fast wrong partition is not a win); (b) the
    compiled-HLO audit must show the fused graph materialises no more
    intermediate-shaped buffers than the unfused composition, with fewer
    bytes written; (c) ``autotune_dag`` searches the legal graph cuts and
    the committed cut is re-raced three-way against both endpoints —
    all-fused (``()``) and all-unfused (every edge materialised).  A
    committed cut that loses the race is replaced by the winning endpoint
    in the schedule cache (same race-back contract as ``bench_autotune``),
    so the persisted partition is never slower than either endpoint as
    measured; ``TUNE_GATE_TOL`` is the tripwire.
    """
    import dataclasses as _dc

    from repro.core import autotune
    from repro.core.lowering import _dag_for
    from repro.kernels.dag import dag_cases
    from repro.launch.hlo_analysis import check_dag_fusion

    rows: List[Dict] = []
    iters = 3 if quick else 7
    print(f"\n== fused-DAG cut search (interpret, best-of-{iters} μs/call) ==")
    for case in dag_cases():
        args, kwargs = case.example(RNG)
        nests, bodies, operands, mode, uniforms = case.spec(*args, **kwargs)
        dag = _dag_for(tuple(nests), None)
        full = tuple(range(len(dag.edges)))

        def run(cut, _c=case, _a=args, _k=kwargs):
            sched = _dc.replace(DEFAULT_SCHEDULE, cut_edges=tuple(cut))
            return _c.fused(*_a, schedule=sched, **_k)

        fused_out = run(())
        unfused_out = case.unfused(*args, **kwargs)
        ref_out = case.ref(*args, **kwargs)
        for label, other in (("unfused", unfused_out), ("ref", ref_out)):
            for g, w in zip(jax.tree.leaves(fused_out),
                            jax.tree.leaves(other)):
                if not np.allclose(np.asarray(g), np.asarray(w),
                                   **case.tol):
                    print(f"FAIL {case.name}: fused DAG disagrees with "
                          f"{label} beyond tol {case.tol}", file=sys.stderr)
                    raise SystemExit(1)

        extras: Dict = {"iters": iters, "edges": len(dag.edges)}
        if check_hlo:
            chk = check_dag_fusion(
                lambda *a, _c=case, **k: _c.fused(
                    *a, schedule=DEFAULT_SCHEDULE, **k),
                case.unfused, args, kwargs,
                case.inters(*args, **kwargs))
            if not chk.intermediates_eliminated:
                print(f"FAIL {case.name}: fused HLO still materialises the "
                      f"intermediates (buffers {chk.fused_buffers} vs "
                      f"{chk.unfused_buffers}, bytes {chk.fused_bytes_out} "
                      f"vs {chk.unfused_bytes_out})", file=sys.stderr)
                raise SystemExit(1)
            extras.update(fused_buffers=chk.fused_buffers,
                          unfused_buffers=chk.unfused_buffers,
                          intermediates_eliminated=True,
                          bytes_saved=chk.bytes_saved)

        res = autotune.autotune_dag(
            nests, bodies, operands, mode=mode, out_dtype="float32",
            uniforms=uniforms, top_k=4 if quick else 8,
            warmup=1, iters=iters, force=True)
        committed = tuple(res.schedule.cut_edges or ())

        t_cut, t_fused, t_unfused = _interleaved3(
            lambda: run(committed), lambda: run(()), lambda: run(full),
            warmup=2, iters=max(7, iters))
        if t_cut > min(t_fused, t_unfused) and committed not in ((), full):
            better = () if t_fused <= t_unfused else full
            t_best = min(t_fused, t_unfused)
            print(f"  {case.name}: committed cut {list(committed)} lost the "
                  f"final race ({t_cut:.1f} vs {t_best:.1f} μs) — "
                  f"committing endpoint {list(better)}")
            sched = _dc.replace(DEFAULT_SCHEDULE, cut_edges=better)
            autotune.global_cache().put(res.key, sched, meta={
                "tuned_us": t_best, "default_us": t_fused,
                "candidates": res.candidates, "raced_back": True,
                "cut_edges": list(better)})
            res = _dc.replace(res, schedule=sched, tuned_us=t_best)
            committed, t_cut = better, t_best
        elif committed == ():
            t_cut = t_fused
        elif committed == full:
            t_cut = t_unfused
        if t_cut > min(t_fused, t_unfused) * TUNE_GATE_TOL:  # tripwire
            print(f"FAIL {case.name}: committed cut {list(committed)} "
                  f"{t_cut:.1f} μs is slower than the best endpoint "
                  f"{min(t_fused, t_unfused):.1f} μs × {TUNE_GATE_TOL}",
                  file=sys.stderr)
            raise SystemExit(1)

        print(f"{case.name:14s} cut={list(committed)!s:10s} "
              f"{t_cut:10.1f} μs  all-fused {t_fused:10.1f} μs  "
              f"unfused {t_unfused:10.1f} μs  "
              f"vs-unfused {t_unfused / t_cut:4.2f}x  "
              f"candidates {res.candidates}")
        rows.append(_row(f"dag/{case.name}", "dag", "cut", t_cut,
                         "us/call", speedup=t_unfused / t_cut,
                         candidates=res.candidates,
                         measured=res.measured, cache_key=res.key,
                         cut_edges=list(committed),
                         fused_stages=_dag_fused_stages(dag, committed),
                         tuned=committed not in ((), full), **extras))
        rows.append(_row(f"dag/{case.name}", "dag", "fused", t_fused,
                         "us/call", cut_edges=[],
                         fused_stages=_dag_fused_stages(dag, ()), **extras))
        rows.append(_row(f"dag/{case.name}", "dag", "unfused", t_unfused,
                         "us/call", cut_edges=list(full),
                         fused_stages=1, **extras))
    return rows


def validate_dag_rows(results: Sequence[Dict]) -> None:
    """The dag acceptance gate, re-applied to persisted rows.

    Every gated kernel must have cut/fused/unfused rows; every dag row
    must carry the v4 partition provenance (``cut_edges`` list +
    ``fused_stages``); and the committed cut may never be slower than the
    better endpoint beyond ``TUNE_GATE_TOL`` (never-slower is structural:
    a race loser is replaced by an endpoint before commit).
    """
    by_kernel: Dict[str, Dict[str, Dict]] = {}
    for r in results:
        if r.get("group") == "dag":
            if not isinstance(r.get("cut_edges"), list):
                raise ValueError(f"dag row missing cut_edges list: {r}")
            if not isinstance(r.get("fused_stages"), int):
                raise ValueError(f"dag row missing fused_stages: {r}")
            by_kernel.setdefault(r["name"].split("/")[1], {})[r["variant"]] = r
    for kern in DAG_GATED:
        trio = by_kernel.get(kern)
        if not trio or {"cut", "fused", "unfused"} - set(trio):
            raise ValueError(f"no complete dag rows for {kern!r}")
        best = min(trio["fused"]["value"], trio["unfused"]["value"])
        if trio["cut"]["value"] > best * TUNE_GATE_TOL:
            raise ValueError(
                f"{kern}: committed cut {trio['cut']['value']} slower than "
                f"best endpoint {best} x {TUNE_GATE_TOL}")
        if trio["fused"]["cut_edges"]:
            raise ValueError(f"{kern}: all-fused row must record cut_edges "
                             "[]")
        if trio["unfused"]["fused_stages"] != 1:
            raise ValueError(f"{kern}: unfused row must record "
                             "fused_stages 1")


# --------------------------------------------------------------------------
# Machine-readable output: BENCH_kernels.json
# --------------------------------------------------------------------------


def write_bench_json(rows: Sequence[Dict], path: str, quick: bool,
                     **extra) -> None:
    """Persist one BENCH_*.json document (shared with cluster_bench)."""
    doc = {"schema": BENCH_SCHEMA, "generated_unix": time.time(),
           "quick": bool(quick), "results": list(rows), **extra}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"\nwrote {len(rows)} results to {path}")


def validate_bench_json(path: str) -> None:
    """Schema gate for CI: malformed output fails loudly."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"bad schema: {doc.get('schema')!r}")
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        raise ValueError("results must be a non-empty list")
    for row in results:
        # schema 3+: every row carries schedule provenance, FIFO depth
        # included
        for field in ("name", "group", "variant", "value", "units",
                      "rows", "lanes", "grid", "tuned", "buffer_depth"):
            if field not in row:
                raise ValueError(f"row missing {field!r}: {row}")
        if not isinstance(row["value"], (int, float)):
            raise ValueError(f"non-numeric value: {row}")
    groups = {r["group"] for r in results}
    if "fused" not in groups:
        raise ValueError(f"no fused results recorded (groups: {groups})")
    if "autotune" not in groups:
        raise ValueError(f"no autotune results recorded (groups: {groups})")
    if "pipeline" not in groups:
        raise ValueError(f"no pipeline results recorded (groups: {groups})")
    if "dag" not in groups:
        raise ValueError(f"no dag results recorded (groups: {groups})")
    if "sparse" not in groups:
        raise ValueError(f"no sparse results recorded (groups: {groups})")
    validate_autotune_rows(results, require_nondefault=not doc.get("quick"))
    validate_pipeline_rows(results, require_deep=not doc.get("quick"))
    validate_dag_rows(results)
    validate_sparse_rows(results)
    # compiled-nest gate: gemm/stencil1d must be present, numerically in
    # agreement, and model-profitable
    nest_rows = {(r["name"].split("/")[1], r["variant"]): r
                 for r in results if r["group"] == "nest"}
    for kern in ("gemm", "stencil1d", "gemv"):
        agree = nest_rows.get((kern, "agreement"))
        model = nest_rows.get((kern, "model"))
        if agree is None or model is None:
            raise ValueError(f"no nest gate rows for {kern!r}")
        if agree["value"] > NEST_AGREEMENT_TOL:
            raise ValueError(f"{kern}: ssr-vs-baseline disagreement "
                             f"{agree['value']} > {NEST_AGREEMENT_TOL}")
        if model["value"] <= 1.0:
            raise ValueError(f"{kern}: model speedup {model['value']} <= 1")


def validate_autotune_json(path: str) -> None:
    """Schema + autotune + pipeline gates for the standalone
    ``--autotune-only`` run (the CI ``autotune-smoke`` job)."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"bad schema: {doc.get('schema')!r}")
    results = doc.get("results") or []
    for row in results:
        for field in ("name", "group", "variant", "value", "units",
                      "rows", "lanes", "grid", "tuned", "buffer_depth"):
            if field not in row:
                raise ValueError(f"row missing {field!r}: {row}")
    validate_autotune_rows(results, require_nondefault=not doc.get("quick"))
    validate_pipeline_rows(results, require_deep=not doc.get("quick"))


# --------------------------------------------------------------------------
# Chaos smoke: degraded dispatch must stay correct and bounded
# --------------------------------------------------------------------------

#: The dispatch seams the chaos smoke injects into.  ``cache.write`` and
#: ``measure`` are autotune-side seams with no dispatch-path effect, so
#: the exhaustive sweep for those lives in ``tests/test_resilience.py``.
CHAOS_SEAMS = ("cache.read", "lowering", "compile")
CHAOS_AGREEMENT_TOL = 1e-5
#: Steady-state post-fault dispatch (default schedule after quarantine, or
#: tuned again after a transient cache-read miss) vs the healthy tuned
#: path.  Generous: both are jitted XLA paths, the bound only has to catch
#: a degradation ladder that re-lowers or re-compiles on every call.
CHAOS_OVERHEAD_X = 25.0


def bench_chaos(quick: bool = False) -> List[Dict]:
    """Inject one fault per dispatch seam and gate the degradation ladder.

    For each seam in :data:`CHAOS_SEAMS`: commit a tuned schedule, arm a
    one-shot fault, dispatch, and require (hard failures, exit 1):

    * the faulted dispatch still returns — degraded, never dead — and its
      result agrees with the healthy tuned result ≤ 1e-5;
    * the fault actually fired and was absorbed by the matching ladder
      rung (lookup fallback for ``cache.read``; quarantine + default
      re-dispatch for ``lowering``/``compile``), visible in
      ``DISPATCH_STATS`` and the structured fallback log;
    * after the fault drains, steady-state dispatch stays within
      :data:`CHAOS_OVERHEAD_X` of the healthy tuned path — degradation
      may not leave the dispatcher re-lowering forever.
    """
    from repro.core import autotune, compiler, lowering, resilience
    from repro.kernels import frontend

    n = 2048 if quick else 8192
    nest = compiler.dot_product_nest(n)
    x = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    y = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    operands = {"A": x, "B": y}

    def call():
        return lowering.ssr_call(nest, lambda a, b: a * b, operands,
                                 mode="reduce")

    tuned = Schedule(rows=16)
    cache = autotune.global_cache()
    key = autotune.cache_key(nest, operands, mode="reduce",
                             out_dtype="float32")
    iters = 3 if quick else 5

    resilience.reset()
    lowering.reset_dispatch_stats()
    frontend.reset_dispatch_stats()
    cache.put(key, tuned)
    lowering.clear_caches()
    healthy = np.asarray(call())
    t_healthy = _time(call, warmup=1, iters=iters)

    rows: List[Dict] = []
    print("\n== chaos smoke: one injected fault per dispatch seam ==")
    for seam in CHAOS_SEAMS:
        # restore a healthy tuned entry and cold kernel caches so the
        # seam is actually on this dispatch's path
        cache.invalidate(key)
        cache.put(key, tuned)
        lowering.clear_caches()
        before = dict(lowering.DISPATCH_STATS)
        n_events = len(resilience.fallback_events())
        with resilience.inject_faults(seam) as specs:
            degraded_out = np.asarray(call())
        stats = lowering.DISPATCH_STATS
        # relative: different schedules reduce in different orders, so the
        # honest float32 agreement scale is the result's own magnitude
        diff = float(np.max(np.abs(degraded_out - healthy))
                     / max(1.0, float(np.max(np.abs(healthy)))))
        counter = "fallbacks" if seam == "cache.read" else "degraded"
        if specs[0].fired != 1:
            print(f"FAIL chaos/{seam}: fault never fired — the seam is "
                  "not on the dispatch path", file=sys.stderr)
            raise SystemExit(1)
        if stats[counter] != before[counter] + 1:
            print(f"FAIL chaos/{seam}: {counter!r} counter did not "
                  f"advance ({before[counter]} -> {stats[counter]})",
                  file=sys.stderr)
            raise SystemExit(1)
        if len(resilience.fallback_events()) <= n_events:
            print(f"FAIL chaos/{seam}: no structured FallbackEvent "
                  "recorded", file=sys.stderr)
            raise SystemExit(1)
        if diff > CHAOS_AGREEMENT_TOL:
            print(f"FAIL chaos/{seam}: degraded result disagrees with "
                  f"healthy by {diff:.2e} > {CHAOS_AGREEMENT_TOL}",
                  file=sys.stderr)
            raise SystemExit(1)
        # fault drained (one-shot): steady state must be bounded
        t_degraded = _time(call, warmup=1, iters=iters)
        overhead = t_degraded / t_healthy
        if overhead > CHAOS_OVERHEAD_X:
            print(f"FAIL chaos/{seam}: steady-state degraded dispatch "
                  f"{overhead:.1f}x healthy > {CHAOS_OVERHEAD_X}x",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"chaos/{seam:12s} agreement {diff:.1e}  steady-state "
              f"overhead {overhead:4.2f}x  ladder rung {counter}")
        rows.append(_row(f"chaos/{seam}", "chaos", "agreement", diff,
                         "max_rel_diff", seam=seam, ladder=counter))
        rows.append(_row(f"chaos/{seam}", "chaos", "overhead", overhead,
                         "x_healthy", seam=seam, ladder=counter))
    return rows


def validate_chaos_rows(results: Sequence[Dict]) -> None:
    """The chaos acceptance gate, re-applied to persisted rows."""
    chaos = {(r["name"].split("/")[1], r["variant"]): r
             for r in results if r.get("group") == "chaos"}
    for seam in CHAOS_SEAMS:
        agree = chaos.get((seam, "agreement"))
        over = chaos.get((seam, "overhead"))
        if agree is None or over is None:
            raise ValueError(f"no chaos rows for seam {seam!r}")
        if agree["value"] > CHAOS_AGREEMENT_TOL:
            raise ValueError(
                f"chaos/{seam}: degraded disagreement {agree['value']} > "
                f"{CHAOS_AGREEMENT_TOL}")
        if over["value"] > CHAOS_OVERHEAD_X:
            raise ValueError(
                f"chaos/{seam}: steady-state overhead {over['value']} > "
                f"{CHAOS_OVERHEAD_X}")


# --------------------------------------------------------------------------
# Longitudinal record: BENCH_history.jsonl (one summary line per run)
# --------------------------------------------------------------------------


def _git_sha() -> str:
    """Short sha of the bench's repo, ``"unknown"`` outside a checkout."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def append_bench_history(rows: Sequence[Dict], path: str,
                         quick: bool) -> Dict:
    """Append one JSONL summary line for this run and return it.

    The full ``BENCH_kernels.json`` artifact is overwritten per run; the
    history file accumulates, one line per run, the handful of numbers a
    perf-trajectory review actually reads — per-kernel speedups of the
    raced groups and the graph cuts the fusion search committed — keyed by
    date and git sha.  Kept as JSONL so appends are atomic-ish and old
    lines never need rewriting.
    """
    speedups = {r["name"]: round(float(r["speedup"]), 4)
                for r in rows
                if isinstance(r.get("speedup"), (int, float))}
    dag_cuts = {r["name"].split("/")[1]: r["cut_edges"]
                for r in rows
                if r.get("group") == "dag" and r.get("variant") == "cut"}
    sparse = {r["name"].split("/")[1]: {
                  "nnz": r["nnz"], "density": r["density"],
                  "eliminated_idx_instrs": r["eliminated_idx_instrs"]}
              for r in rows
              if r.get("group") == "sparse" and r.get("variant") == "model"}
    # v6: resilience summary — how often this run's dispatches degraded.
    # Zero across the board in a healthy run; non-zero under --chaos-smoke
    # or an ambient REPRO_FAULTS matrix, where it records that the
    # degradation ladder (not a crash) absorbed the faults.
    from repro.core import lowering as _lowering
    from repro.core import resilience as _resilience
    from repro.kernels import frontend as _frontend
    degraded = {
        "fallbacks": int(_lowering.DISPATCH_STATS["fallbacks"]
                         + _frontend.DISPATCH_STATS["fallbacks"]),
        "degraded": int(_lowering.DISPATCH_STATS["degraded"]
                        + _frontend.DISPATCH_STATS["degraded"]),
        "events": len(_resilience.fallback_events()),
    }
    entry = {
        "schema": BENCH_SCHEMA,
        "date": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": _git_sha(),
        "quick": bool(quick),
        "rows": len(rows),
        "groups": sorted({r["group"] for r in rows}),
        "speedups": speedups,
        "dag_cuts": dag_cuts,
        "sparse": sparse,
        "degraded": degraded,
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    print(f"appended run summary to {path} ({len(speedups)} speedups, "
          f"{len(dag_cuts)} dag cuts, {len(sparse)} sparse gates, "
          f"{degraded['degraded']} degraded dispatches)")
    return entry


def validate_bench_history(path: str) -> int:
    """Validate every line of the history file; return the line count.

    Each line must be a self-contained JSON object with the summary
    fields — a truncated append or a hand-edit that breaks one line fails
    loudly here rather than corrupting the trajectory silently.
    """
    count = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"{path}:{lineno}: not valid JSON ({e})") from None
            for field, typ in (("schema", int), ("date", str),
                               ("git_sha", str), ("quick", bool),
                               ("rows", int), ("groups", list),
                               ("speedups", dict), ("dag_cuts", dict)):
                if not isinstance(entry.get(field), typ):
                    raise ValueError(
                        f"{path}:{lineno}: missing/mistyped {field!r}")
            if not (1 <= entry["schema"] <= BENCH_SCHEMA):
                raise ValueError(
                    f"{path}:{lineno}: schema {entry['schema']} outside "
                    f"1..{BENCH_SCHEMA}")
            for name, val in entry["speedups"].items():
                if not isinstance(val, (int, float)):
                    raise ValueError(
                        f"{path}:{lineno}: non-numeric speedup {name!r}")
            for kern, cut in entry["dag_cuts"].items():
                if not isinstance(cut, list):
                    raise ValueError(
                        f"{path}:{lineno}: dag cut for {kern!r} is not a "
                        "list")
            # v5 lines carry the sparse-gate summary; older lines (1–4)
            # legitimately lack it, so the field is optional-but-typed
            if "sparse" in entry:
                if not isinstance(entry["sparse"], dict):
                    raise ValueError(
                        f"{path}:{lineno}: sparse summary is not a dict")
                for kern, info in entry["sparse"].items():
                    if not isinstance(info, dict) or not isinstance(
                            info.get("nnz"), int):
                        raise ValueError(
                            f"{path}:{lineno}: sparse summary for {kern!r} "
                            "missing integer nnz")
            # v6 lines must carry the resilience summary; older lines
            # (1–5) legitimately lack it, so below v6 it is
            # optional-but-typed
            if entry["schema"] >= 6 and "degraded" not in entry:
                raise ValueError(
                    f"{path}:{lineno}: schema-{entry['schema']} line "
                    "missing 'degraded' resilience summary")
            if "degraded" in entry:
                deg = entry["degraded"]
                if not isinstance(deg, dict):
                    raise ValueError(
                        f"{path}:{lineno}: degraded summary is not a dict")
                for field in ("fallbacks", "degraded", "events"):
                    if not isinstance(deg.get(field), int):
                        raise ValueError(
                            f"{path}:{lineno}: degraded summary "
                            f"missing/mistyped integer {field!r}")
            count += 1
    if count == 0:
        raise ValueError(f"{path}: empty history")
    return count


def isolate_schedule_cache() -> None:
    """Point the schedule cache at a fresh tempdir unless the operator
    opted into a shared one.

    Two reasons: (a) determinism — a warm user-global cache would make the
    non-autotune rows (smoke, nest gate) silently run tuned geometry that
    their schedule-provenance fields could not honestly describe, and make
    results differ between the first and later runs on one machine;
    (b) hygiene — a benchmark should not mutate user-global state as a
    side effect.  Set ``REPRO_SCHEDULE_CACHE`` explicitly to tune into
    (and read from) a persistent cache, e.g. the default
    ``~/.cache/repro-ssr`` that registry dispatch consults.
    """
    if not os.environ.get("REPRO_SCHEDULE_CACHE"):
        tmp = tempfile.mkdtemp(prefix="repro-sched-bench-")
        os.environ["REPRO_SCHEDULE_CACHE"] = tmp
        print(f"schedule cache isolated at {tmp} "
              "(set REPRO_SCHEDULE_CACHE to persist winners)")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small sizes + few iters (CI smoke)")
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="output JSON path (default: %(default)s)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compiled-HLO fusion audit")
    ap.add_argument("--autotune-only", action="store_true",
                    help="run only the schedule-autotune sweep + gate "
                         "(the CI autotune-smoke job)")
    ap.add_argument("--dag-only", action="store_true",
                    help="run only the fused-DAG cut search + gate "
                         "(the CI bench-smoke dag leg)")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="run only the fault-injection chaos gate: one "
                         "injected fault per dispatch seam, degraded "
                         "result must agree with healthy and stay within "
                         "bounded overhead (the CI chaos-smoke job)")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="per-run summary JSONL (default: %(default)s); "
                         "'' disables")
    args = ap.parse_args(argv)
    isolate_schedule_cache()

    if args.chaos_smoke:
        rows = bench_chaos(quick=args.quick)
        write_bench_json(rows, args.out, args.quick, subset="chaos")
        validate_chaos_rows(rows)
        if args.history:
            append_bench_history(rows, args.history, args.quick)
            validate_bench_history(args.history)
        return 0

    if args.autotune_only:
        rows = bench_autotune(quick=args.quick)
        rows += bench_pipeline(quick=args.quick)
        write_bench_json(rows, args.out, args.quick, subset="autotune")
        validate_autotune_json(args.out)
        return 0

    if args.dag_only:
        rows = bench_dag(quick=args.quick, check_hlo=not args.no_hlo)
        write_bench_json(rows, args.out, args.quick, subset="dag")
        validate_dag_rows(rows)
        if args.history:
            append_bench_history(rows, args.history, args.quick)
            validate_bench_history(args.history)
        return 0

    rows: List[Dict] = []
    rows += bench_reference_paths(iters=2 if args.quick else 5)
    rows += smoke_ssr_paths()
    rows += bench_stream_reports()
    rows += bench_nest_gate()
    rows += bench_sparse(quick=args.quick)
    rows += bench_autotune(quick=args.quick)
    rows += bench_pipeline(quick=args.quick)
    rows += bench_fused(quick=args.quick, check_hlo=not args.no_hlo)
    rows += bench_dag(quick=args.quick, check_hlo=not args.no_hlo)
    write_bench_json(rows, args.out, args.quick)
    validate_bench_json(args.out)
    if args.history:
        append_bench_history(rows, args.history, args.quick)
        validate_bench_history(args.history)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
