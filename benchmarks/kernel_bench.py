"""Wall-clock microbenchmarks of the kernel suite (CPU harness).

On this CPU container, Pallas interpret mode measures the *interpreter*, not
TPU silicon, so the honest comparison is: XLA-compiled reference path
(μs/call, real) + static stream-analysis (bytes streamed, FIFO reuse, VMEM
footprint — the quantities that decide TPU speed).  On a real TPU this file
runs unchanged with ``interpret=False`` to time Mosaic kernels.

Two kernel sets are enumerated with zero edits here:

* every ``@register_kernel`` entry with an ``example`` factory is timed
  (``ref`` path) and smoke-run (``ssr`` path);
* every fused (stream-chained) variant from ``kernels.chained.fused_cases``
  is raced against its unfused two-kernel composition — interleaved
  best-of-N of the real call path, plus the compiled-HLO audit that the
  intermediate buffer is gone.  Numeric disagreement beyond the case's
  tolerance is a hard failure (exit 1): a fast wrong kernel is not a win.

Run as a script to persist ``BENCH_kernels.json`` (schema below), the
machine-readable perf trajectory tracked across PRs::

    PYTHONPATH=src python benchmarks/kernel_bench.py [--quick] [--out PATH]

Schema (version 1): ``{"schema": 1, "generated_unix": float, "quick": bool,
"results": [{"name", "group", "variant", "value", "units", ...}, ...]}``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.chained import fused_cases

RNG = np.random.default_rng(0)

BENCH_SCHEMA = 1


def _row(name: str, group: str, variant: str, value: float, units: str,
         **extras) -> Dict:
    row = {"name": name, "group": group, "variant": variant,
           "value": float(value), "units": units}
    row.update(extras)
    return row


def _time(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-N μs/call (min over iters absorbs scheduler noise)."""
    for _ in range(warmup):
        jax.block_until_ready(jax.tree.leaves(fn(*args)))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # μs


def bench_reference_paths(iters: int = 5) -> List[Dict]:
    """Best-of-N of the jitted XLA reference path of every registered
    kernel (problem sizes as in §4.2, from each entry's example factory)."""
    rows = []
    print("\n== kernel reference path timings (XLA:CPU, best-of-N μs/call) ==")
    for entry in registry.entries():
        if entry.example is None:
            continue
        args, kwargs = entry.example(RNG)
        fn = jax.jit(lambda *a, _e=entry, _kw=kwargs: _e.ref(*a, **_kw))
        us = _time(fn, *args, iters=iters)
        print(f"{entry.name:16s} {entry.problem:26s} {us:10.1f} μs")
        rows.append(_row(f"kernel_ref/{entry.name}", "kernel_ref", "ref",
                         us, "us/call", iters=iters))
    return rows


def smoke_ssr_paths() -> List[Dict]:
    """One interpret-mode call per registered streamed kernel (CI smoke)."""
    rows = []
    print("\n== kernel ssr-path smoke (Pallas interpret) ==")
    for entry in registry.entries():
        if entry.example is None:
            continue
        args, kwargs = entry.example(RNG)
        t0 = time.perf_counter()
        jax.block_until_ready(
            jax.tree.leaves(entry.ssr(*args, **kwargs)))
        ms = (time.perf_counter() - t0) * 1e3
        print(f"{entry.name:16s} ok ({ms:7.1f} ms incl. trace)")
        rows.append(_row(f"kernel_ssr_smoke/{entry.name}", "kernel_ssr_smoke",
                         "ssr", ms, "interpret ms"))
    return rows


def bench_stream_reports() -> List[Dict]:
    """Static stream analysis of the production matmul (FIFO reuse etc.)."""
    from repro.core import BlockStream, Direction, ssr_pallas
    from jax.experimental.pallas import tpu as pltpu

    rows = []
    print("\n== stream-analysis of ssr_matmul tiles ==")
    for (m, n, k, bm, bn, bk) in [(512, 512, 512, 128, 128, 128),
                                  (1024, 1024, 1024, 256, 256, 256)]:
        def body(a_ref, b_ref, o_ref, acc_ref):  # noqa: ANN001
            pass  # analysis only

        grid = (m // bm, n // bn, k // bk)
        fn = ssr_pallas(
            body, grid=grid,
            in_streams=[
                BlockStream((bm, bk), lambda i, j, kk: (i, kk), name="A"),
                BlockStream((bk, bn), lambda i, j, kk: (kk, j), name="B"),
            ],
            out_streams=[BlockStream((bm, bn), lambda i, j, kk: (i, j),
                                     Direction.WRITE, name="C")],
            out_shapes=[jax.ShapeDtypeStruct((m, n), jnp.bfloat16)],
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            validate=True,
        )
        rep = fn.report(dtypes=[jnp.bfloat16, jnp.bfloat16, jnp.bfloat16])
        ai = 2 * m * n * k / rep.hbm_bytes_unique
        print(f"matmul {m}x{n}x{k} tiles ({bm},{bn},{bk}): "
              f"VMEM {rep.vmem_bytes / 2**20:.1f} MiB, "
              f"streamed {rep.hbm_bytes_streamed / 2**20:.0f} MiB, "
              f"unique {rep.hbm_bytes_unique / 2**20:.0f} MiB, "
              f"reuse {rep.reuse_factor:.1f}x, AI {ai:.0f} flop/byte")
        rows.append(_row(f"stream/matmul{m}", "stream", "ssr",
                         rep.reuse_factor, "reuse_factor",
                         vmem_bytes=rep.vmem_bytes,
                         hbm_bytes_streamed=rep.hbm_bytes_streamed))
    return rows


# --------------------------------------------------------------------------
# Compiled-nest kernels: ssr-vs-baseline agreement + cost-model gate
# --------------------------------------------------------------------------

#: Numeric agreement required between a compiled-nest kernel's streamed
#: and baseline engines (same problem, same dtype — only delivery differs).
NEST_AGREEMENT_TOL = 1e-5


def _nest_models():
    """(kernel name, cost-model LoopNest) for the compiled-nest gate."""
    from repro.core import compiler
    from repro.kernels.stencil import TAPS

    return [("gemm", compiler.gemm_nest(32, 32, 32)),
            ("stencil1d", compiler.stencil_nest(1024, TAPS))]


def bench_nest_gate() -> List[Dict]:
    """Gate the registry's compiled-nest kernels (gemm, stencil1d).

    Two hard requirements per kernel, mirrored in ``validate_bench_json``:
    the streamed engine must agree with the baseline engine within
    ``NEST_AGREEMENT_TOL`` (a fast wrong kernel is not a win), and the
    Eq. (1)–(3) model must predict a speedup > 1 for the paper-size nest
    (otherwise streaming it is pointless and the registry entry is wrong).
    """
    from repro.core.lowering import plan_stats

    rows = []
    print("\n== compiled-nest gate: ssr vs baseline + cost model ==")
    for name, nest in _nest_models():
        entry = registry.get(name)
        args, kwargs = entry.example(RNG)
        ssr_out = entry.ssr(*args, **kwargs)
        base_out = entry.baseline(*args, **kwargs)
        diff = max(float(jnp.max(jnp.abs(jnp.asarray(g) - jnp.asarray(w))))
                   for g, w in zip(jax.tree.leaves(ssr_out),
                                   jax.tree.leaves(base_out)))
        if diff > NEST_AGREEMENT_TOL:
            print(f"FAIL {name}: ssr disagrees with baseline by {diff:.2e} "
                  f"> {NEST_AGREEMENT_TOL}", file=sys.stderr)
            raise SystemExit(1)
        # score the configuration the registry actually executes: every
        # affine ref streamed (auto lanes), not the 2-mover default the
        # nest-output path cannot even lower
        from repro.core.nest_analysis import auto_lanes

        stats = plan_stats(nest, num_lanes=auto_lanes(nest))
        speedup = stats.n_base / stats.n_ssr
        if not (stats.ssrified and speedup > 1.0):
            print(f"FAIL {name}: Eq. (3) model speedup {speedup:.2f} <= 1",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"{name:12s} agreement {diff:.1e}  model speedup "
              f"{speedup:4.2f}x (N {stats.n_base} -> {stats.n_ssr})")
        rows.append(_row(f"nest/{name}", "nest", "agreement", diff,
                         "max_abs_diff"))
        rows.append(_row(f"nest/{name}", "nest", "model", speedup,
                         "model_speedup", n_base=stats.n_base,
                         n_ssr=stats.n_ssr))
    return rows


# --------------------------------------------------------------------------
# Fused (stream-chained) variants vs their unfused compositions
# --------------------------------------------------------------------------

# Bench problem sizes, chosen so the quantity chaining eliminates (the
# intermediate HBM round-trip + the second kernel dispatch) is resolvable
# above CPU timing noise.  gemv_relu uses the paper's §4.2 GEMV size.
_FUSED_BENCH_ARGS: Dict[str, Callable[[bool], Tuple[tuple, dict]]] = {
    "gemv_relu": lambda quick: (
        (jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32),
         jnp.asarray(RNG.standard_normal(64), jnp.float32)), {}),
    "stencil1d_relu": lambda quick: (
        (jnp.asarray(RNG.standard_normal((2048 if quick else 16384) + 10),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(11) * 0.3, jnp.float32)), {}),
    "sum_sq_diff": lambda quick: (
        (jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32)), {}),
    "axpy_dot": lambda quick: (
        (jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32),
         jnp.asarray(RNG.standard_normal(16384 if quick else 262144),
                     jnp.float32)), {"alpha": 0.5}),
}


def _interleaved_best(f: Callable, u: Callable, args: tuple, kwargs: dict,
                      warmup: int, iters: int) -> Tuple[float, float]:
    """Race two callables back-to-back so drift hits both equally."""
    for _ in range(warmup):
        jax.block_until_ready(jax.tree.leaves(f(*args, **kwargs)))
        jax.block_until_ready(jax.tree.leaves(u(*args, **kwargs)))
    bf = bu = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(f(*args, **kwargs)))
        bf = min(bf, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(u(*args, **kwargs)))
        bu = min(bu, time.perf_counter() - t0)
    return bf * 1e6, bu * 1e6


def bench_fused(quick: bool = False, check_hlo: bool = True) -> List[Dict]:
    """Fused single kernel vs unfused two-kernel composition, best-of-N.

    Numeric disagreement beyond the case tolerance raises ``SystemExit`` —
    the benchmark doubles as the CI agreement gate.  When ``check_hlo`` is
    set, the compiled-HLO fusion audit (intermediate buffer counts) is also
    recorded per case.
    """
    from repro.launch.hlo_analysis import check_fusion

    rows = []
    warmup, iters = (1, 3) if quick else (2, 9)
    print("\n== fused (stream-chained) vs unfused composition "
          f"(interpret, best-of-{iters} μs/call) ==")
    for case in fused_cases():
        bench_args = _FUSED_BENCH_ARGS.get(case.name)
        # a case without a tuned bench size still benches at its example
        # size — new FusedCases land here with zero edits
        args, kwargs = (bench_args(quick) if bench_args
                        else case.example(RNG))

        fused_out = case.fused(*args, **kwargs)
        unfused_out = case.unfused(*args, **kwargs)
        for g, w in zip(jax.tree.leaves(fused_out),
                        jax.tree.leaves(unfused_out)):
            if not np.allclose(np.asarray(g), np.asarray(w), **case.tol):
                print(f"FAIL {case.name}: fused disagrees with unfused "
                      f"beyond tol {case.tol}", file=sys.stderr)
                raise SystemExit(1)

        tf, tu = _interleaved_best(case.fused, case.unfused, args, kwargs,
                                   warmup, iters)
        speedup = tu / tf
        extras: Dict = {"iters": iters}
        if check_hlo:
            dtype, dims = case.inter_type(*args, **kwargs)
            chk = check_fusion(case.fused, case.unfused, args, kwargs,
                               dtype, dims)
            extras.update(
                intermediate=f"{dtype}{list(dims)}",
                fused_buffers=chk.fused_buffers,
                unfused_buffers=chk.unfused_buffers,
                intermediate_eliminated=chk.intermediate_eliminated)
        print(f"{case.name:16s} fused {tf:10.1f} μs  unfused {tu:10.1f} μs  "
              f"speedup {speedup:4.2f}x"
              + (f"  intermediate_eliminated={extras.get('intermediate_eliminated')}"
                 if check_hlo else ""))
        rows.append(_row(f"fused/{case.name}", "fused", "fused",
                         tf, "us/call", **extras))
        rows.append(_row(f"fused/{case.name}", "fused", "unfused",
                         tu, "us/call", speedup=speedup, **extras))
    return rows


# --------------------------------------------------------------------------
# Machine-readable output: BENCH_kernels.json
# --------------------------------------------------------------------------


def write_bench_json(rows: Sequence[Dict], path: str, quick: bool,
                     **extra) -> None:
    """Persist one BENCH_*.json document (shared with cluster_bench)."""
    doc = {"schema": BENCH_SCHEMA, "generated_unix": time.time(),
           "quick": bool(quick), "results": list(rows), **extra}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"\nwrote {len(rows)} results to {path}")


def validate_bench_json(path: str) -> None:
    """Schema gate for CI: malformed output fails loudly."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"bad schema: {doc.get('schema')!r}")
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        raise ValueError("results must be a non-empty list")
    for row in results:
        for field in ("name", "group", "variant", "value", "units"):
            if field not in row:
                raise ValueError(f"row missing {field!r}: {row}")
        if not isinstance(row["value"], (int, float)):
            raise ValueError(f"non-numeric value: {row}")
    groups = {r["group"] for r in results}
    if "fused" not in groups:
        raise ValueError(f"no fused results recorded (groups: {groups})")
    # compiled-nest gate: gemm/stencil1d must be present, numerically in
    # agreement, and model-profitable
    nest_rows = {(r["name"].split("/")[1], r["variant"]): r
                 for r in results if r["group"] == "nest"}
    for kern in ("gemm", "stencil1d"):
        agree = nest_rows.get((kern, "agreement"))
        model = nest_rows.get((kern, "model"))
        if agree is None or model is None:
            raise ValueError(f"no nest gate rows for {kern!r}")
        if agree["value"] > NEST_AGREEMENT_TOL:
            raise ValueError(f"{kern}: ssr-vs-baseline disagreement "
                             f"{agree['value']} > {NEST_AGREEMENT_TOL}")
        if model["value"] <= 1.0:
            raise ValueError(f"{kern}: model speedup {model['value']} <= 1")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small sizes + few iters (CI smoke)")
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="output JSON path (default: %(default)s)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compiled-HLO fusion audit")
    args = ap.parse_args(argv)

    rows: List[Dict] = []
    rows += bench_reference_paths(iters=2 if args.quick else 5)
    rows += smoke_ssr_paths()
    rows += bench_stream_reports()
    rows += bench_nest_gate()
    rows += bench_fused(quick=args.quick, check_hlo=not args.no_hlo)
    write_bench_json(rows, args.out, args.quick)
    validate_bench_json(args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
