"""Wall-clock microbenchmarks of the kernel suite (CPU harness).

On this CPU container, Pallas interpret mode measures the *interpreter*, not
TPU silicon, so the honest comparison is: XLA-compiled reference path
(μs/call, real) + static stream-analysis (bytes streamed, FIFO reuse, VMEM
footprint — the quantities that decide TPU speed).  On a real TPU this file
runs unchanged with ``interpret=False`` to time Mosaic kernels.

The kernel set is *enumerated from the registry*: every ``@register_kernel``
entry with an ``example`` factory is timed (``ref`` path) and smoke-run
(``ssr`` path), so a newly registered kernel lands in this benchmark with
zero edits here.
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry

RNG = np.random.default_rng(0)


def _time(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # μs


def bench_reference_paths() -> List[Tuple[str, float, str]]:
    """Time the jitted XLA reference path of every registered kernel
    (problem sizes as in §4.2, from each entry's example factory)."""
    rows = []
    print("\n== kernel reference path timings (XLA:CPU, μs/call) ==")
    for entry in registry.entries():
        if entry.example is None:
            continue
        args, kwargs = entry.example(RNG)
        fn = jax.jit(lambda *a, _e=entry, _kw=kwargs: _e.ref(*a, **_kw))
        us = _time(fn, *args)
        print(f"{entry.name:12s} {entry.problem:26s} {us:10.1f} μs")
        rows.append((f"kernel_ref/{entry.name}", us, "xla_cpu us/call"))
    return rows


def smoke_ssr_paths() -> List[Tuple[str, float, str]]:
    """One interpret-mode call per registered streamed kernel (CI smoke)."""
    rows = []
    print("\n== kernel ssr-path smoke (Pallas interpret) ==")
    for entry in registry.entries():
        if entry.example is None:
            continue
        args, kwargs = entry.example(RNG)
        t0 = time.perf_counter()
        jax.block_until_ready(
            jax.tree.leaves(entry.ssr(*args, **kwargs)))
        ms = (time.perf_counter() - t0) * 1e3
        print(f"{entry.name:12s} ok ({ms:7.1f} ms incl. trace)")
        rows.append((f"kernel_ssr_smoke/{entry.name}", ms, "interpret ms"))
    return rows


def bench_stream_reports() -> List[Tuple[str, float, str]]:
    """Static stream analysis of the production matmul (FIFO reuse etc.)."""
    from repro.core import BlockStream, Direction, ssr_pallas
    from jax.experimental.pallas import tpu as pltpu

    rows = []
    print("\n== stream-analysis of ssr_matmul tiles ==")
    for (m, n, k, bm, bn, bk) in [(512, 512, 512, 128, 128, 128),
                                  (1024, 1024, 1024, 256, 256, 256)]:
        def body(a_ref, b_ref, o_ref, acc_ref):  # noqa: ANN001
            pass  # analysis only

        grid = (m // bm, n // bn, k // bk)
        fn = ssr_pallas(
            body, grid=grid,
            in_streams=[
                BlockStream((bm, bk), lambda i, j, kk: (i, kk), name="A"),
                BlockStream((bk, bn), lambda i, j, kk: (kk, j), name="B"),
            ],
            out_streams=[BlockStream((bm, bn), lambda i, j, kk: (i, j),
                                     Direction.WRITE, name="C")],
            out_shapes=[jax.ShapeDtypeStruct((m, n), jnp.bfloat16)],
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            validate=True,
        )
        rep = fn.report(dtypes=[jnp.bfloat16, jnp.bfloat16, jnp.bfloat16])
        ai = 2 * m * n * k / rep.hbm_bytes_unique
        print(f"matmul {m}x{n}x{k} tiles ({bm},{bn},{bk}): "
              f"VMEM {rep.vmem_bytes / 2**20:.1f} MiB, "
              f"streamed {rep.hbm_bytes_streamed / 2**20:.0f} MiB, "
              f"unique {rep.hbm_bytes_unique / 2**20:.0f} MiB, "
              f"reuse {rep.reuse_factor:.1f}x, AI {ai:.0f} flop/byte")
        rows.append((f"stream/matmul{m}", rep.reuse_factor,
                     f"vmem {rep.vmem_bytes} streamed {rep.hbm_bytes_streamed}"))
    return rows
