"""Benchmark entry point: one function per paper table/figure + kernel
timings + (if present) the dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV after the human-readable tables.
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    from benchmarks import kernel_bench, paper_tables

    rows = []
    for fn in paper_tables.ALL:
        rows.extend(fn())
    # kernel_bench rows are structured dicts (BENCH_kernels.json schema);
    # flatten them into the CSV triple this report prints.
    for row in (kernel_bench.bench_reference_paths()
                + kernel_bench.smoke_ssr_paths()
                + kernel_bench.bench_stream_reports()
                + kernel_bench.bench_fused()):
        rows.append((f"{row['name']}/{row['variant']}", row["value"],
                     row["units"]))

    if os.path.exists("dryrun_results.json"):
        from benchmarks import roofline
        print("\n== roofline (from dry-run records) ==")
        rf = roofline.load("dryrun_results.json")
        print(roofline.table(rf, "pod16x16"))
        for r in rf:
            if r["mesh"] == "pod16x16":
                rows.append((f"roofline/{r['arch']}/{r['shape']}",
                             r["roofline_fraction"],
                             f"dominant={r['dominant']}"))

    print("\nname,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
