"""One-shot human-readable benchmark report over the whole suite.

Everything here is registry-driven: one function per paper table/figure
(``paper_tables.ALL``), every ``@register_kernel`` entry's reference
timing + streamed-path smoke, the stream-analysis reports, the
compiled-nest gate (gemm/stencil ssr-vs-baseline agreement + Eq. (1)–(3)
model speedup), the fused-vs-unfused race, and — if dry-run records exist —
the roofline summary.  Adding a kernel to the registry adds it to this
report with zero edits.

``benchmarks/kernel_bench.py`` is the machine-readable twin (writes +
validates ``BENCH_kernels.json``); this entry point just prints the
``name,us_per_call,derived`` CSV for eyeballing and logs.
"""

from __future__ import annotations

import os


def main() -> None:
    from benchmarks import kernel_bench, paper_tables

    rows = []
    for fn in paper_tables.ALL:
        rows.extend(fn())
    # kernel_bench rows are structured dicts (BENCH_kernels.json schema);
    # flatten them into the CSV triple this report prints.
    for row in (kernel_bench.bench_reference_paths()
                + kernel_bench.smoke_ssr_paths()
                + kernel_bench.bench_stream_reports()
                + kernel_bench.bench_nest_gate()
                + kernel_bench.bench_fused()):
        rows.append((f"{row['name']}/{row['variant']}", row["value"],
                     row["units"]))

    if os.path.exists("dryrun_results.json"):
        from benchmarks import roofline
        print("\n== roofline (from dry-run records) ==")
        rf = roofline.load("dryrun_results.json")
        print(roofline.table(rf, "pod16x16"))
        for r in rf:
            if r["mesh"] == "pod16x16":
                rows.append((f"roofline/{r['arch']}/{r['shape']}",
                             r["roofline_fraction"],
                             f"dominant={r['dominant']}"))

    print("\nname,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
