"""One benchmark per paper table/figure (§4/§5), from the exact ISA model.

Each function returns a list of CSV rows ``(name, value, derived)`` and
prints a human-readable table.  These are the *reproduction* artifacts: the
asserted numbers live in tests/test_isa_model.py; here they are emitted for
EXPERIMENTS.md::

    PYTHONPATH=src python benchmarks/paper_tables.py --write-experiments

regenerates the committed ``EXPERIMENTS.md`` (CI fails if it is stale —
``tools/check_docs.py``).  The rendering is deterministic: every number is
closed-form from :mod:`repro.core.isa`/:mod:`repro.core.compiler` except
the one executed-kernel check, whose value is normalised to its asserted
bound before writing.
"""

from __future__ import annotations

import argparse
from typing import List, Optional, Sequence, Tuple

from repro.core import compiler, isa


def tab2_isa() -> List[Tuple[str, float, str]]:
    """Table 2: hot-loop N, η, speedup across ISA variants."""
    rows = []
    print("== Table 2: ISA-level hot-loop impact ==")
    print(f"{'kernel':18s} {'arith':6s} {'U':>2s} {'N_base':>6s} "
          f"{'η_base':>7s} {'N_ssr':>6s} {'η_ssr':>6s} {'S':>5s}")
    for r in isa.table2():
        print(f"{r.kernel:18s} {r.arith:6s} {r.unroll:2d} {r.base.n:6d} "
              f"{r.base.eta:7.0%} {r.ssr.n:6d} {r.ssr.eta:6.0%} "
              f"{r.speedup:5.2f}")
        rows.append((f"tab2/{r.kernel}/{r.arith}", r.speedup,
                     f"eta {r.base.eta:.2f}->{r.ssr.eta:.2f}"))
    return rows


def fig4_counts() -> List[Tuple[str, float, str]]:
    base, ssr = isa.fig4_dot_product(1000)
    print(f"\n== Fig 4: dot product N=1000 -> base {base}, ssr {ssr} ==")
    return [("fig4/dot1000", base / ssr, f"{base} vs {ssr} instructions")]


def fig6_amortization() -> List[Tuple[str, float, str]]:
    """Fig. 6: η for reductions over l^d hypercubes + Eq. 3 break-evens."""
    rows = []
    print("\n== Fig 6: utilization of d-dim reductions (SSR) ==")
    print(f"{'l':>6s} " + " ".join(f"d={d:>8d}" for d in (1, 2, 3, 4)))
    for l in (2, 4, 8, 16, 64, 256, 1024):
        etas = [isa.utilization_reduction(l, d) if l ** d < 2 ** 40 else
                float("nan") for d in (1, 2, 3, 4)]
        print(f"{l:6d} " + " ".join(f"{e:9.1%}" for e in etas))
        rows.append((f"fig6/l{l}", etas[0], "eta at d=1"))
    sides = [isa.min_side_length(d) for d in (1, 2, 3, 4)]
    print(f"break-even sides (Eq.3): {sides} (paper: >5,>4,>1,>1 iters)")
    rows.append(("fig6/breakeven", float(sides[0]), str(sides)))
    return rows


def fig7_kernel_speedup() -> List[Tuple[str, float, str]]:
    print("\n== Fig 7: per-kernel SSR speedup (trace model) ==")
    rows = []
    for k in isa.kernel_suite():
        print(f"{k.name:10s} {k.problem:24s} S={k.speedup:5.2f}")
        rows.append((f"fig7/{k.name}", k.speedup, k.problem))
    band = [k.speedup for k in isa.kernel_suite()]
    print(f"band: {min(band):.2f}x .. {max(band):.2f}x "
          f"(paper: 2.0x..3.7x)")
    return rows


def fig8_utilization() -> List[Tuple[str, float, str]]:
    print("\n== Fig 8: useful ALU/FPU utilization per kernel ==")
    rows = []
    for k in isa.kernel_suite():
        print(f"{k.name:10s} base {k.eta_base:6.1%} -> ssr {k.eta_ssr:6.1%}")
        rows.append((f"fig8/{k.name}", k.eta_ssr,
                     f"base {k.eta_base:.3f}"))
    return rows


def fig11_cluster() -> List[Tuple[str, float, str]]:
    """Fig. 11: SSR-cluster size matching a 6-core baseline cluster."""
    print("\n== Fig 11: cluster equivalence (Amdahl model) ==")
    rows = []
    for speed, label in ((3.0, "3x-kernels"), (2.0, "2x-kernels")):
        n = isa.equivalent_cores(6, ssr_speedup=speed)
        t6 = isa.cluster_time(6, False)
        tn = isa.cluster_time(n, True, ssr_speedup=speed)
        print(f"{label}: {n} SSR cores match 6 baseline cores "
              f"(T={tn:.4f} vs {t6:.4f})")
        rows.append((f"fig11/{label}", float(n), f"T {tn:.4f} vs {t6:.4f}"))
    s1 = isa.cluster_time(1, False) / isa.cluster_time(1, True)
    s6 = isa.cluster_time(6, False) / isa.cluster_time(6, True)
    print(f"speedup 1 core: {s1:.2f}x; 6 cores: {s6:.2f}x "
          f"(paper: 3x -> 2.2x)")
    rows.append(("fig11/amdahl_drop", s6, f"single-core {s1:.2f}"))
    return rows


def tab3_cores() -> List[Tuple[str, float, str]]:
    """Table 3: utilization-limit classes on long reductions."""
    print("\n== Table 3: utilization classes ==")
    cases = [
        ("RI5CY+SSR", 1, True), ("RI5CY", 1, False), ("Ariane", 1, False),
        ("Rocket", 1, False), ("BOOM", 2, False), ("SweRV", 2, False),
        ("Ara(vector)", 1, True), ("Hwacha(vector)", 1, True),
    ]
    rows = []
    for name, width, streaming in cases:
        lim = isa.utilization_class(width, streaming)
        print(f"{name:16s} issue={width} streaming={streaming}: "
              f"util limit {lim:.0%}")
        rows.append((f"tab3/{name}", lim, f"issue{width}"))
    return rows


def tab5_compiler() -> List[Tuple[str, float, str]]:
    """§5.5: automated pass vs manual SSR mapping on a reduction.

    Beyond the instruction-count comparison, this now *executes* the
    compiled plan: the dot-product nest goes through ``ssrify()`` +
    ``lower_plan()`` + ``ssr_call()`` and runs as a Pallas kernel — the
    paper's "transparent to the programmer" claim, end to end.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.core import ssr_call

    print("\n== §5.5: LLVM-pass analogue vs manual mapping ==")
    n = 2048
    manual = compiler.ssrify(compiler.dot_product_nest(n))
    # the paper's prototype pass loses ~5% to sub-optimal instruction
    # selection during SSR configuration: model as extra setup instructions
    auto_overhead = max(1, int(0.05 * manual.n_ssr))
    auto_n = manual.n_ssr + auto_overhead
    s_manual = manual.n_base / manual.n_ssr
    s_auto = manual.n_base / auto_n
    print(f"manual: S={s_manual:.2f}; auto pass: S={s_auto:.2f} "
          f"(paper measured 2.1x vs 2.0x incl. memory contention)")
    print(f"gap: {100 * (1 - s_auto / s_manual):.1f}% (paper: ~5%)")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = ssr_call(compiler.dot_product_nest(n),
                   lambda a, b: jnp.sum(a * b), {"A": x, "B": y})
    want = float(jnp.dot(x, y))
    err = abs(float(got) - want) / max(abs(want), 1e-9)
    print(f"compiled plan executed via lower_plan/ssr_call: "
          f"{float(got):+.4f} vs oracle {want:+.4f} (rel err {err:.1e})")

    # the flagship 3-level nest, end to end through the same pipeline: the
    # paper's marquee §4.2 kernel no longer needs a hand-written schedule
    import jax

    m = nn = kk = 32
    a = jnp.asarray(rng.standard_normal((m, kk)) / np.sqrt(kk), jnp.float32)
    b = jnp.asarray(rng.standard_normal((kk, nn)), jnp.float32)
    got_c = ssr_call(
        compiler.gemm_nest(m, nn, kk),
        lambda ab, bb: jax.lax.dot_general(
            ab, bb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),
        {"A": a, "B": b})
    want_c = jnp.dot(a, b, preferred_element_type=jnp.float32)
    gerr = float(jnp.max(jnp.abs(got_c - want_c))) \
        / max(float(jnp.max(jnp.abs(want_c))), 1e-9)
    # score the executed configuration: every affine ref streamed
    gnest = compiler.gemm_nest(m, nn, kk)
    gplan = compiler.ssrify(
        gnest, num_lanes=compiler.nest_analysis.auto_lanes(gnest))
    print(f"gemm nest executed via lower_nest/ssr_call: max rel err "
          f"{gerr:.1e}; model speedup {gplan.n_base / gplan.n_ssr:.2f}x")
    return [("tab5/manual", s_manual, f"N={manual.n_ssr}"),
            ("tab5/auto", s_auto, f"N={auto_n}"),
            ("tab5/ssr_call_relerr", err, f"dot n={n} executed"),
            ("tab5/gemm_call_relerr", gerr,
             f"gemm {m}x{nn}x{kk} executed; model "
             f"S={gplan.n_base / gplan.n_ssr:.2f}")]


def tab_registry() -> List[Tuple[str, float, str]]:
    """Registry coverage: executable variants per kernel, cross-referenced
    against the §4.2 analytic suite (Fig. 7/8 models)."""
    from repro.kernels import registry

    print("\n== kernel registry: executable variant coverage ==")
    modeled = {k.name for k in isa.kernel_suite()}
    rows = []
    for entry in registry.entries():
        variants = ",".join(sorted({**entry.variants(),
                                    **entry.cluster_variants()}))
        in_model = "yes" if entry.name in modeled else "no"
        print(f"{entry.name:12s} {entry.problem:26s} variants=[{variants}] "
              f"fig7-model={in_model}")
        n_var = len(entry.variants()) + len(entry.cluster_variants())
        rows.append((f"registry/{entry.name}", float(n_var),
                     f"variants {variants}; modeled {in_model}"))
    return rows


def tab_cluster() -> List[Tuple[str, float, str]]:
    """§5.3–5.5 on the explicit per-core model (`compiler.cluster_cost`).

    Unlike :func:`fig11_cluster` (the paper's calibrated Amdahl fit), these
    numbers come from the same Eq. (1)–(3) accounting the execution layer
    shards: per-core instruction counts on ceil tiles plus a log2 combine
    tree.  The speedup-vs-cores and iso-performance curves here are what
    ``benchmarks/cluster_bench.py`` re-emits next to measured agreement.
    """
    print("\n== cluster model: speedup vs cores (dot, n=2048) ==")
    rows = []
    nest = compiler.dot_product_nest(2048)
    for c in (1, 2, 4, 8):
        rep = compiler.cluster_cost(nest, c)
        print(f"C={c}: N_cluster={rep.n_cluster:5d}  S={rep.speedup:5.2f}  "
              f"eta={rep.eta_cluster:6.1%}  fetches={rep.total_fetches}")
        rows.append((f"cluster/dot2048/C{c}", rep.speedup,
                     f"N {rep.n_cluster}; eta {rep.eta_cluster:.3f}"))
    for base_c in (4, 6, 8):
        iso = compiler.iso_performance_cores(nest, base_c)
        print(f"iso-performance: {iso} SSR cores match {base_c} baseline "
              f"cores ({base_c / iso:.1f}x fewer; paper: 3x)")
        rows.append((f"cluster/iso/base{base_c}", float(iso),
                     f"{base_c / iso:.2f}x fewer cores"))
    return rows


ALL = [tab2_isa, fig4_counts, fig6_amortization, fig7_kernel_speedup,
       fig8_utilization, fig11_cluster, tab3_cores, tab5_compiler,
       tab_registry, tab_cluster]

# Section headers for EXPERIMENTS.md, one per ALL entry (same order).
SECTIONS = [
    ("Table 2 — ISA-level hot-loop impact",
     "Instruction count N, useful utilization η, and speedup S per hot "
     "loop, across {standard RV32, +hardware loops, +post-increment} × "
     "{int32, fp32} (paper Table 2, reproduced exactly)."),
    ("Fig. 4 — dot product instruction counts",
     "The running example at N=1000: 3001 baseline vs 1012 SSR executed "
     "instructions."),
    ("Fig. 6 — amortization of d-dimensional reductions",
     "η over l^d hypercubes and the Eq. (3) break-even side lengths."),
    ("Fig. 7 — per-kernel SSR speedup",
     "Steady-state trace model of the §4.2 kernel suite; the paper's band "
     "is 2.0x–3.7x."),
    ("Fig. 8 — useful ALU/FPU utilization per kernel",
     "Baseline vs SSR utilization from the same schedules."),
    ("Fig. 11 — cluster equivalence (Amdahl model)",
     "SSR-cluster sizes matching a 6-core baseline cluster; σ calibrated "
     "to the paper's 2.2x six-core point."),
    ("Table 3 — utilization-limit classes",
     "Issue-width/streaming utilization ceilings on long reductions "
     "(§5.6.1)."),
    ("§5.5 — compiler pass vs manual mapping",
     "Automated SSR-ification overhead, plus the compiled plans *executed* "
     "end to end: the Fig. 4 dot product through lower_plan/ssr_call and "
     "the 3-level GEMM nest — contraction accumulator, permuted B layout, "
     "repeat-register A panel — through lower_nest/ssr_call."),
    ("Kernel registry coverage",
     "Executable ssr/baseline/ref variants per kernel, cross-referenced "
     "against the Fig. 7/8 analytic suite."),
    ("§5.3–5.5 — per-core cluster model",
     "Speedup vs cores and iso-performance core counts from the explicit "
     "Eq. (1)–(3) per-core model that `parallel/cluster.py` executes; the "
     "full sweep (with measured agreement) lands in BENCH_cluster.json "
     "via benchmarks/cluster_bench.py."),
]


def _stable_value(name: str, value: float) -> str:
    """Deterministic rendering: executed-kernel errors become their
    asserted bound (the raw float varies across BLAS/jax builds)."""
    if "relerr" in name:
        if not value < 1e-5:
            raise AssertionError(
                f"{name}: executed plan diverged from oracle ({value})")
        return "< 1e-05"
    return f"{value:.6g}"


def render_experiments() -> str:
    """EXPERIMENTS.md content: one section per paper table/figure."""
    assert len(SECTIONS) == len(ALL)
    out = [
        "# EXPERIMENTS — reproduced tables and figures",
        "",
        "Generated by `PYTHONPATH=src python benchmarks/paper_tables.py "
        "--write-experiments`.",
        "**Do not edit by hand** — CI regenerates this file and fails if "
        "it is stale.",
        "",
        "Every number is derived from the exact ISA model "
        "(`src/repro/core/isa.py`) or the compiler cost model "
        "(`src/repro/core/compiler.py`); the same quantities are asserted "
        "in `tests/test_isa_model.py`.  Wall-clock and agreement numbers "
        "for the executable kernels live in [BENCH_kernels.json]"
        "(BENCH_kernels.json) and [BENCH_cluster.json](BENCH_cluster.json).",
        "",
    ]
    for (title, blurb), fn in zip(SECTIONS, ALL):
        rows = fn()
        out += [f"## {title}", "", blurb, "",
                "| metric | value | notes |", "|---|---|---|"]
        for name, value, derived in rows:
            out.append(f"| `{name}` | {_stable_value(name, value)} "
                       f"| {derived} |")
        out.append("")
    return "\n".join(out) + "\n"


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write-experiments", action="store_true",
                    help="render EXPERIMENTS.md instead of just printing")
    ap.add_argument("--out", default="EXPERIMENTS.md",
                    help="output path (default: %(default)s)")
    args = ap.parse_args(argv)
    if args.write_experiments:
        text = render_experiments()
        with open(args.out, "w") as f:
            f.write(text)
        print(f"\nwrote {args.out} ({len(text.splitlines())} lines)")
        return 0
    for fn in ALL:
        fn()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
