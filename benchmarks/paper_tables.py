"""One benchmark per paper table/figure (§4/§5), from the exact ISA model.

Each function returns a list of CSV rows ``(name, value, derived)`` and
prints a human-readable table.  These are the *reproduction* artifacts: the
asserted numbers live in tests/test_isa_model.py; here they are emitted for
EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core import compiler, isa


def tab2_isa() -> List[Tuple[str, float, str]]:
    """Table 2: hot-loop N, η, speedup across ISA variants."""
    rows = []
    print("== Table 2: ISA-level hot-loop impact ==")
    print(f"{'kernel':18s} {'arith':6s} {'U':>2s} {'N_base':>6s} "
          f"{'η_base':>7s} {'N_ssr':>6s} {'η_ssr':>6s} {'S':>5s}")
    for r in isa.table2():
        print(f"{r.kernel:18s} {r.arith:6s} {r.unroll:2d} {r.base.n:6d} "
              f"{r.base.eta:7.0%} {r.ssr.n:6d} {r.ssr.eta:6.0%} "
              f"{r.speedup:5.2f}")
        rows.append((f"tab2/{r.kernel}/{r.arith}", r.speedup,
                     f"eta {r.base.eta:.2f}->{r.ssr.eta:.2f}"))
    return rows


def fig4_counts() -> List[Tuple[str, float, str]]:
    base, ssr = isa.fig4_dot_product(1000)
    print(f"\n== Fig 4: dot product N=1000 -> base {base}, ssr {ssr} ==")
    return [("fig4/dot1000", base / ssr, f"{base} vs {ssr} instructions")]


def fig6_amortization() -> List[Tuple[str, float, str]]:
    """Fig. 6: η for reductions over l^d hypercubes + Eq. 3 break-evens."""
    rows = []
    print("\n== Fig 6: utilization of d-dim reductions (SSR) ==")
    print(f"{'l':>6s} " + " ".join(f"d={d:>8d}" for d in (1, 2, 3, 4)))
    for l in (2, 4, 8, 16, 64, 256, 1024):
        etas = [isa.utilization_reduction(l, d) if l ** d < 2 ** 40 else
                float("nan") for d in (1, 2, 3, 4)]
        print(f"{l:6d} " + " ".join(f"{e:9.1%}" for e in etas))
        rows.append((f"fig6/l{l}", etas[0], "eta at d=1"))
    sides = [isa.min_side_length(d) for d in (1, 2, 3, 4)]
    print(f"break-even sides (Eq.3): {sides} (paper: >5,>4,>1,>1 iters)")
    rows.append(("fig6/breakeven", float(sides[0]), str(sides)))
    return rows


def fig7_kernel_speedup() -> List[Tuple[str, float, str]]:
    print("\n== Fig 7: per-kernel SSR speedup (trace model) ==")
    rows = []
    for k in isa.kernel_suite():
        print(f"{k.name:10s} {k.problem:24s} S={k.speedup:5.2f}")
        rows.append((f"fig7/{k.name}", k.speedup, k.problem))
    band = [k.speedup for k in isa.kernel_suite()]
    print(f"band: {min(band):.2f}x .. {max(band):.2f}x "
          f"(paper: 2.0x..3.7x)")
    return rows


def fig8_utilization() -> List[Tuple[str, float, str]]:
    print("\n== Fig 8: useful ALU/FPU utilization per kernel ==")
    rows = []
    for k in isa.kernel_suite():
        print(f"{k.name:10s} base {k.eta_base:6.1%} -> ssr {k.eta_ssr:6.1%}")
        rows.append((f"fig8/{k.name}", k.eta_ssr,
                     f"base {k.eta_base:.3f}"))
    return rows


def fig11_cluster() -> List[Tuple[str, float, str]]:
    """Fig. 11: SSR-cluster size matching a 6-core baseline cluster."""
    print("\n== Fig 11: cluster equivalence (Amdahl model) ==")
    rows = []
    for speed, label in ((3.0, "3x-kernels"), (2.0, "2x-kernels")):
        n = isa.equivalent_cores(6, ssr_speedup=speed)
        t6 = isa.cluster_time(6, False)
        tn = isa.cluster_time(n, True, ssr_speedup=speed)
        print(f"{label}: {n} SSR cores match 6 baseline cores "
              f"(T={tn:.4f} vs {t6:.4f})")
        rows.append((f"fig11/{label}", float(n), f"T {tn:.4f} vs {t6:.4f}"))
    s1 = isa.cluster_time(1, False) / isa.cluster_time(1, True)
    s6 = isa.cluster_time(6, False) / isa.cluster_time(6, True)
    print(f"speedup 1 core: {s1:.2f}x; 6 cores: {s6:.2f}x "
          f"(paper: 3x -> 2.2x)")
    rows.append(("fig11/amdahl_drop", s6, f"single-core {s1:.2f}"))
    return rows


def tab3_cores() -> List[Tuple[str, float, str]]:
    """Table 3: utilization-limit classes on long reductions."""
    print("\n== Table 3: utilization classes ==")
    cases = [
        ("RI5CY+SSR", 1, True), ("RI5CY", 1, False), ("Ariane", 1, False),
        ("Rocket", 1, False), ("BOOM", 2, False), ("SweRV", 2, False),
        ("Ara(vector)", 1, True), ("Hwacha(vector)", 1, True),
    ]
    rows = []
    for name, width, streaming in cases:
        lim = isa.utilization_class(width, streaming)
        print(f"{name:16s} issue={width} streaming={streaming}: "
              f"util limit {lim:.0%}")
        rows.append((f"tab3/{name}", lim, f"issue{width}"))
    return rows


def tab5_compiler() -> List[Tuple[str, float, str]]:
    """§5.5: automated pass vs manual SSR mapping on a reduction.

    Beyond the instruction-count comparison, this now *executes* the
    compiled plan: the dot-product nest goes through ``ssrify()`` +
    ``lower_plan()`` + ``ssr_call()`` and runs as a Pallas kernel — the
    paper's "transparent to the programmer" claim, end to end.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.core import ssr_call

    print("\n== §5.5: LLVM-pass analogue vs manual mapping ==")
    n = 2048
    manual = compiler.ssrify(compiler.dot_product_nest(n))
    # the paper's prototype pass loses ~5% to sub-optimal instruction
    # selection during SSR configuration: model as extra setup instructions
    auto_overhead = max(1, int(0.05 * manual.n_ssr))
    auto_n = manual.n_ssr + auto_overhead
    s_manual = manual.n_base / manual.n_ssr
    s_auto = manual.n_base / auto_n
    print(f"manual: S={s_manual:.2f}; auto pass: S={s_auto:.2f} "
          f"(paper measured 2.1x vs 2.0x incl. memory contention)")
    print(f"gap: {100 * (1 - s_auto / s_manual):.1f}% (paper: ~5%)")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = ssr_call(compiler.dot_product_nest(n),
                   lambda a, b: jnp.sum(a * b), {"A": x, "B": y})
    want = float(jnp.dot(x, y))
    err = abs(float(got) - want) / max(abs(want), 1e-9)
    print(f"compiled plan executed via lower_plan/ssr_call: "
          f"{float(got):+.4f} vs oracle {want:+.4f} (rel err {err:.1e})")
    return [("tab5/manual", s_manual, f"N={manual.n_ssr}"),
            ("tab5/auto", s_auto, f"N={auto_n}"),
            ("tab5/ssr_call_relerr", err, f"dot n={n} executed")]


def tab_registry() -> List[Tuple[str, float, str]]:
    """Registry coverage: executable variants per kernel, cross-referenced
    against the §4.2 analytic suite (Fig. 7/8 models)."""
    from repro.kernels import registry

    print("\n== kernel registry: executable variant coverage ==")
    modeled = {k.name for k in isa.kernel_suite()}
    rows = []
    for entry in registry.entries():
        variants = ",".join(sorted(entry.variants()))
        in_model = "yes" if entry.name in modeled else "no"
        print(f"{entry.name:12s} {entry.problem:26s} variants=[{variants}] "
              f"fig7-model={in_model}")
        rows.append((f"registry/{entry.name}", float(len(entry.variants())),
                     f"variants {variants}; modeled {in_model}"))
    return rows


ALL = [tab2_isa, fig4_counts, fig6_amortization, fig7_kernel_speedup,
       fig8_utilization, fig11_cluster, tab3_cores, tab5_compiler,
       tab_registry]
