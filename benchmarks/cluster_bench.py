"""Cluster sweep: registry kernels sharded over C ∈ {1,2,4,8} cores (§V).

Reproduces the paper's multi-core claims on the ``parallel/cluster.py``
layer (§5.3–5.5 / Fig. 10–11):

* **speedup vs cores** — the architectural speedup of the C-core cluster
  from the explicit per-core Eq. (1)–(3) model
  (:func:`repro.core.compiler.cluster_cost`).  Instruction/cycle accounting
  is technology-independent (§5.1), so these are the *reproduction*
  numbers, and the validation gate requires them to increase with C;
* **iso-performance core counts** — smallest SSR cluster matching a C-core
  baseline cluster (the "3× fewer cores" claim);
* **numeric agreement** — every clustered kernel is *executed* on a forced
  C-device host mesh and must match its single-core streamed output within
  1e-5 (hard failure otherwise: a fast wrong cluster is not a win);
* **locality audit** — the compiled HLO of a reduce-mode cluster call may
  contain exactly one ``all-reduce`` (the shared-TCDM psum) and a map-mode
  call none at all;
* **wall clock** — best-of-N μs/call per C, recorded but *not* gated: on
  this CPU container Pallas interpret mode times the interpreter, and the
  forced host devices share one machine.  On a real multi-chip backend
  this file runs unchanged.

Run from the repo root (forces 8 host devices before importing jax)::

    python benchmarks/cluster_bench.py [--quick] [--out BENCH_cluster.json]

Schema (version 2, shared with ``kernel_bench``): ``{"schema": 2,
"generated_unix": float, "quick": bool, "cores": [...], "results":
[{"name", "group", "variant", "value", "units", "rows", "lanes", "grid",
"tuned", ...}, ...]}`` — executed rows carry the per-core schedule the
cluster layer actually dispatched (autotuned or default).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Sequence

# Must run before any jax import: the cluster sweep needs C host devices.
_DEVICES = int(os.environ.get("REPRO_CLUSTER_DEVICES", "8"))
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_DEVICES}").strip()

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.kernel_bench import (BENCH_SCHEMA, _row, _time,
                                     isolate_schedule_cache,
                                     write_bench_json)  # noqa: E402
from repro.core import compiler  # noqa: E402
from repro.core.compiler import (cluster_cost,
                                 iso_performance_cores)  # noqa: E402
from repro.kernels import registry  # noqa: E402

RNG = np.random.default_rng(0)

AGREEMENT_TOL = 1e-5
CORES_SWEEP = (1, 2, 4, 8)


def _normal(n: int) -> jnp.ndarray:
    """Unit-scale inputs: reduce outputs stay O(1), so the 1e-5 absolute
    agreement gate measures the cluster split, not the operand magnitude."""
    return jnp.asarray(RNG.standard_normal(n) / np.sqrt(n), jnp.float32)


def _model_nests(quick: bool):
    """(name, nest-or-chain) for the cost model — no device arrays."""
    from repro.kernels.chained import _chain_nests
    from repro.kernels.stencil import TAPS

    n = 8192 if not quick else 2048
    m = 512 if not quick else 128
    return [
        ("reduction", compiler.dot_product_nest(n)),
        ("relu", compiler.elementwise_nest(n)),
        # the real compiled nest (§13 migration): A row-panel walk +
        # x repeat stream + the revisited output accumulator ref
        ("gemv", compiler.gemv_nest(m, 64)),
        ("gemm", compiler.gemm_nest(m, 64, 64)),
        ("stencil1d", compiler.stencil_nest(n, TAPS)),
        ("sum_sq_diff", _chain_nests(n, consumer_reads_w=False)),
        ("axpy_dot", _chain_nests(n, consumer_reads_w=True)),
    ]


def _bench_cases(quick: bool):
    """(name, args, kwargs, nest-or-chain): executable inputs per kernel."""
    from repro.kernels.stencil import TAPS

    n = 8192 if not quick else 2048
    m = 512 if not quick else 128
    inputs = {
        "reduction": ((_normal(n), _normal(n)), {}),
        "relu": ((_normal(n),), {}),
        "gemv": ((jnp.asarray(RNG.standard_normal((m, 64)) / 8.0,
                              jnp.float32), _normal(64) * 8.0), {}),
        "gemm": ((jnp.asarray(RNG.standard_normal((m, 64)) / 8.0,
                              jnp.float32),
                  jnp.asarray(RNG.standard_normal((64, 64)) / 8.0,
                              jnp.float32)), {}),
        "stencil1d": ((jnp.asarray(RNG.standard_normal(n + TAPS - 1) / 4.0,
                                   jnp.float32),
                       jnp.asarray(RNG.standard_normal(TAPS) * 0.3,
                                   jnp.float32)), {}),
        "sum_sq_diff": ((_normal(n), _normal(n)), {}),
        "axpy_dot": ((_normal(n), _normal(n), _normal(n)), {"alpha": 0.5}),
    }
    return [(name, *inputs[name], nests)
            for name, nests in _model_nests(quick)]


def _max_abs_diff(a, b) -> float:
    return max(float(jnp.max(jnp.abs(jnp.asarray(x) - jnp.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _dispatch_provenance() -> Dict:
    """Schedule provenance of the last executed cluster call (schema 3).

    ``cluster_call``/``cluster_chain_call`` record the per-core schedule
    they actually dispatched (tuned from the autotuner cache, or default)
    in ``parallel.cluster.LAST_DISPATCH``; kernels routed through other
    shims (``cluster_kernel``/``cluster_kernel2d``) leave it empty and
    keep the default-provenance row fields.
    """
    from repro.parallel.cluster import LAST_DISPATCH

    if not LAST_DISPATCH:
        return {}
    sched = LAST_DISPATCH["schedule"]
    # "grid" in schema 2 means the launched Pallas grid; the cluster layer
    # records the per-core *iteration-space* tile, which is a different
    # quantity — keep the shared field None and expose the tile separately
    # so cross-file consumers never read bounds as grid dimensions.
    return {"rows": sched.rows, "lanes": sched.lanes,
            "grid": None,
            "tile_bounds": list(LAST_DISPATCH["tile_bounds"]),
            "tuned": bool(LAST_DISPATCH["tuned"]),
            "buffer_depth": sched.buffer_depth}


def sweep(quick: bool = False) -> List[Dict]:
    """Agreement + wall clock + cost model across the core sweep.

    Model rows cover the full ``CORES_SWEEP`` unconditionally (the cost
    model needs no devices — its monotonicity gate must hold on any host);
    execution (agreement/wall-clock) rows only for core counts the
    machine can actually place.
    """
    runnable = [c for c in CORES_SWEEP if c <= len(jax.devices())]
    rows: List[Dict] = []
    print(f"\n== cluster sweep: model C={list(CORES_SWEEP)}, executed "
          f"C={runnable} ({len(jax.devices())} devices) ==")
    for name, args, kwargs, nests in _bench_cases(quick):
        entry = registry.get(name)
        assert entry.cluster is not None, name
        single = entry.ssr(*args, **kwargs)
        line = f"{name:14s}"
        for c in CORES_SWEEP:
            rep = cluster_cost(nests, c)
            rows.append(_row(
                f"cluster/{name}/C{c}", "cluster_model", "model",
                rep.speedup, "model_speedup", cores=c,
                n_cluster=rep.n_cluster, n_single=rep.n_single,
                eta_cluster=rep.eta_cluster,
                total_fetches=rep.total_fetches,
                bytes_moved=rep.bytes_moved))
            line += f"  C{c}: S={rep.speedup:4.2f} η={rep.eta_cluster:.2f}"
            if c not in runnable:
                continue
            from repro.parallel.cluster import LAST_DISPATCH

            LAST_DISPATCH.clear()
            out = entry.cluster(*args, cores=c, **kwargs)
            prov = _dispatch_provenance()
            diff = _max_abs_diff(out, single)
            if diff > AGREEMENT_TOL:
                print(f"\nFAIL {name} C={c}: sharded output differs from "
                      f"single-core by {diff:.2e} > {AGREEMENT_TOL}",
                      file=sys.stderr)
                raise SystemExit(1)
            us = _time(lambda *a, _c=c: entry.cluster(*a, cores=_c, **kwargs),
                       *args, iters=2 if quick else 5)
            rows.append(_row(f"cluster/{name}/C{c}", "cluster_agreement",
                             "cluster", diff, "max_abs_diff", cores=c,
                             **prov))
            rows.append(_row(f"cluster/{name}/C{c}", "cluster_wall",
                             "cluster", us, "us/call", cores=c, **prov))
            line += f" Δ={diff:.0e}"
        print(line)
    return rows


def iso_curve() -> List[Dict]:
    """§5.5 iso-performance: SSR cores matching a C-core baseline cluster."""
    rows: List[Dict] = []
    print("\n== iso-performance (SSR cores matching baseline cluster) ==")
    for name, nests in _model_nests(quick=True):
        pts = []
        for base_c in (2, 4, 6, 8):
            iso = iso_performance_cores(nests, base_c)
            pts.append((base_c, iso))
            rows.append(_row(f"iso/{name}/base{base_c}", "cluster_iso",
                             "model", float(iso), "ssr_cores",
                             baseline_cores=base_c,
                             ratio=base_c / iso))
        print(f"{name:14s} " + "  ".join(
            f"{b} base -> {i} ssr ({b / i:.1f}x fewer)" for b, i in pts))
    return rows


def locality_audit() -> List[Dict]:
    """Compiled-HLO audit: one psum for reduces, none for maps (§5.3)."""
    from repro.launch.hlo_analysis import check_cluster_locality

    rows: List[Dict] = []
    c = min(4, len(jax.devices()))
    if c < 2:
        # cores=1 degenerates to the meshless single-core path: there is
        # no collective to audit, so the check is vacuous here.
        print("\n== HLO locality audit skipped (needs >= 2 devices) ==")
        return rows
    print(f"\n== HLO locality audit (C={c}) ==")
    cases = [("reduction", "reduce"), ("relu", "map"),
             ("sum_sq_diff", "reduce")]
    for name, mode in cases:
        entry = registry.get(name)
        args, kwargs = entry.example(RNG)
        chk = check_cluster_locality(
            lambda *a: entry.cluster(*a, cores=c, **kwargs), args,
            mode=mode, world=c)
        print(f"{name:14s} mode={mode:6s} collectives={chk.counts} "
              f"ok={chk.ok}")
        if not chk.ok:
            print(f"\nFAIL {name}: per-core intermediates leaked off core "
                  f"(collectives {chk.counts})", file=sys.stderr)
            raise SystemExit(1)
        rows.append(_row(f"locality/{name}", "cluster_locality", "cluster",
                         1.0, "ok", mode=mode, cores=c,
                         collectives=chk.counts))
    return rows


# --------------------------------------------------------------------------
# Machine-readable output: BENCH_cluster.json
# --------------------------------------------------------------------------


def validate_cluster_json(path: str) -> None:
    """Schema + acceptance gate for CI.

    Fails unless (a) the schema holds, (b) every agreement row is within
    tolerance, (c) the model speedup increases with C for at least three
    kernels, and (d) every locality row passed.
    """
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"bad schema: {doc.get('schema')!r}")
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        raise ValueError("results must be a non-empty list")
    for row in results:
        # schema 3: every row carries schedule provenance, FIFO depth
        # included (cluster rows record the dispatched per-core schedule's
        # depth via LAST_DISPATCH)
        for field in ("name", "group", "variant", "value", "units",
                      "rows", "lanes", "grid", "tuned", "buffer_depth"):
            if field not in row:
                raise ValueError(f"row missing {field!r}: {row}")
    for row in results:
        if row["group"] == "cluster_agreement" \
                and row["value"] > AGREEMENT_TOL:
            raise ValueError(f"agreement beyond {AGREEMENT_TOL}: {row}")
        if row["group"] == "cluster_locality" and row["value"] != 1.0:
            raise ValueError(f"locality audit failed: {row}")
    by_kernel: Dict[str, List] = {}
    for row in results:
        if row["group"] == "cluster_model":
            kern = row["name"].split("/")[1]
            by_kernel.setdefault(kern, []).append(
                (row["cores"], row["value"]))
    # the compiled-nest kernels must ride the sweep (gemm: the 2-D split)
    for required in ("gemm", "stencil1d"):
        if required not in by_kernel:
            raise ValueError(
                f"{required!r} missing from the cluster sweep "
                f"(kernels: {sorted(by_kernel)})")
    increasing = 0
    for kern, pts in by_kernel.items():
        pts.sort()
        if len(pts) >= 3 and all(b > a for (_, a), (_, b)
                                 in zip(pts, pts[1:])):
            increasing += 1
    if increasing < 3:
        raise ValueError(
            f"need >= 3 kernels with speedup increasing in C, got "
            f"{increasing} (kernels: {sorted(by_kernel)})")
    if not any(r["group"] == "cluster_iso" for r in results):
        raise ValueError("no iso-performance results recorded")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small sizes + few iters (CI smoke)")
    ap.add_argument("--out", default="BENCH_cluster.json",
                    help="output JSON path (default: %(default)s)")
    args = ap.parse_args(argv)
    # deterministic provenance: executed rows resolve per-core schedules
    # from the cache, so the sweep isolates it unless explicitly shared
    isolate_schedule_cache()

    rows: List[Dict] = []
    rows += sweep(quick=args.quick)
    rows += iso_curve()
    rows += locality_audit()
    write_bench_json(rows, args.out, args.quick, cores=list(CORES_SWEEP))
    validate_cluster_json(args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
