"""Activation sharding constraints (ambient, divisibility-guarded).

XLA's SPMD propagation cannot infer shardings for loop-carried state created
with ``jnp.zeros`` inside ``lax.scan`` bodies; it falls back to *replicated*,
which silently materialises full-global-batch tensors per device (observed:
40 GiB/device on a 125M model).  Models therefore pin their activations and
scan carries with :func:`constrain`, which resolves symbolic axis groups
against the ambient mesh:

    constrain(h, BATCH, None, MODEL)   # (B over dp axes, S, D over model)

Outside a mesh context (single-device smoke tests) it is a no-op; every axis
is divisibility-guarded so tiny configs on big meshes degrade to replication
per-dim instead of erroring.
"""

from __future__ import annotations

import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH = "__batch__"    # data-parallel axes ('pod','data')
MODEL = "__model__"    # tensor-parallel axis
BOTH = "__both__"      # all axes (sequence sharding for B=1 cells)

_state = threading.local()


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_activation_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


class activation_mesh:
    """Context manager pinning the ambient mesh for constraints."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_activation_mesh()
        set_activation_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_activation_mesh(self.prev)


def _axes_for(symbol, mesh: Mesh):
    if symbol == BATCH:
        return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if symbol == MODEL:
        return ("model",) if "model" in mesh.axis_names else ()
    if symbol == BOTH:
        return tuple(mesh.axis_names)
    if symbol is None:
        return ()
    return (symbol,) if symbol in mesh.axis_names else ()


def resolve_spec(shape: Sequence[int], pattern, mesh: Mesh) -> P:
    out = []
    for size, symbol in zip(shape, pattern):
        axes = _axes_for(symbol, mesh)
        keep = []
        prod = 1
        for a in axes:
            n = mesh.shape[a]
            if size % (prod * n) == 0:
                keep.append(a)
                prod *= n
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


def constrain(x: jax.Array, *pattern) -> jax.Array:
    """Apply a symbolic sharding constraint; no-op without an ambient mesh."""
    mesh = get_activation_mesh()
    if mesh is None or x.ndim != len(pattern):
        return x
    spec = resolve_spec(x.shape, pattern, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree, *pattern):
    return jax.tree.map(lambda x: constrain(x, *pattern), tree)
