"""Parallel execution subsystem.

* ``cluster``     — the paper's §5.3–5.5 multi-core cluster on a JAX device
  mesh: streamed iteration spaces sharded over a ``cores`` axis
  (``cluster_call`` / ``cluster_chain_call`` / ``cluster_kernel``), with a
  single ``psum`` standing in for the shared-TCDM combine.
* ``sharding``    — DP/FSDP/TP/EP/SP PartitionSpec policies for the model
  stack.
* ``collectives`` — ring matmul / reduce-scatter matmul building blocks.
* ``activations`` — activation-sharding context for training steps.

Submodules import jax-heavy machinery; import them explicitly
(``from repro.parallel import cluster``) rather than through this package
root, which stays import-free so dry-runs control device initialisation.
"""
