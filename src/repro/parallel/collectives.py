"""Collective-overlap matmuls: the SSR data-mover idea at cluster scale.

The paper's data mover prefetches the *next* operand while the FPU consumes
the current one.  At the ICI level the same structure is a **ring collective
matmul**: weights live row-sharded across the axis (FSDP); instead of
``all-gather(W)`` followed by one big matmul (fetch-then-compute, the
"explicit load" shape), each device multiplies the shard it currently holds
while ``ppermute`` streams the next shard around the ring — compute hides
the transfer exactly as the SSR FIFO hides memory latency.  Total bytes
equal the all-gather's; the win is overlap (plus never materialising the
full W per device: peak weight memory drops from |W| to 2·|W|/n).

Used by the §Perf hillclimb on the FSDP-gather-bound dense cells; validated
numerically against the plain matmul on fake devices.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _ring_body(x: jax.Array, w_shard: jax.Array, axis: str) -> jax.Array:
    """Inside shard_map: y_local = x_local @ concat_j(w_j).

    x (b_loc, D) — full contraction dim locally; w_shard (D/n, F) — this
    device's row block.  n ring steps, each: partial matmul with the block
    currently held, then rotate the block to the neighbour.
    """
    n = jax.lax.psum(1, axis)
    me = jax.lax.axis_index(axis)
    d_blk = w_shard.shape[0]
    f = w_shard.shape[1]
    b = x.shape[0]
    perm = [(i, (i - 1) % n) for i in range(n)]

    def step(t, carry):
        acc, w_cur = carry
        j = (me + t) % n                       # block id currently held
        xb = jax.lax.dynamic_slice_in_dim(x, j * d_blk, d_blk, axis=1)
        acc = acc + jnp.dot(xb, w_cur, preferred_element_type=jnp.float32)
        # stream the next operand block around the ring (the data mover)
        w_cur = jax.lax.ppermute(w_cur, axis, perm)
        return acc, w_cur

    acc0 = jnp.zeros((b, f), jnp.float32)
    acc, _ = jax.lax.fori_loop(0, n, step, (acc0, w_shard))
    return acc.astype(x.dtype)


def ring_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, *,
                axis: str = "data",
                batch_axes: Optional[tuple] = None) -> jax.Array:
    """y = x @ w with w row-sharded over ``axis`` and streamed via ring.

    ``x`` (B, D) may be batch-sharded over ``batch_axes``; ``w`` (D, F) is
    stored P(axis, None).  Output follows x's batch sharding.
    """
    n = mesh.shape[axis]
    if w.shape[0] % n:
        raise ValueError(f"contraction dim {w.shape[0]} not divisible by "
                         f"ring size {n}")
    bspec = batch_axes if batch_axes else None
    fn = shard_map(
        functools.partial(_ring_body, axis=axis), mesh=mesh,
        in_specs=(P(bspec, None), P(axis, None)),
        out_specs=P(bspec, None),
        check_rep=False,
    )
    return fn(x, w)


def reduce_scatter_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, *,
                          axis: str = "model",
                          batch_axes: Optional[tuple] = None) -> jax.Array:
    """y = x @ w with x col-sharded / w row-sharded over ``axis`` (TP down-
    projection): local partial matmul + psum — the contraction-parallel
    partner of :func:`ring_matmul` used by down-projections.
    """
    bspec = batch_axes if batch_axes else None

    def body(x_l, w_l):
        part = jnp.dot(x_l, w_l, preferred_element_type=jnp.float32)
        return jax.lax.psum(part, axis).astype(x.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(bspec, axis), P(axis, None)),
                   out_specs=P(bspec, None), check_rep=False)
    return fn(x, w)
