"""Cluster execution layer: streamed kernels sharded across a ``cores`` mesh.

The paper's headline multi-core results (§5.3–§5.5, Fig. 10/11) run the SSR
kernels on an 8-core RISC-V cluster sharing one TCDM: each core streams its
tile of the iteration space, and reductions finish through the shared
memory + hardware barrier.  This module is that cluster on a JAX device
mesh:

* a **core** is one device on a 1-D mesh axis named ``cores``
  (:func:`repro.launch.mesh.make_cluster_mesh`);
* the **iteration space** of a :class:`~repro.core.compiler.LoopNest` (or a
  chained sequence of nests) is partitioned on its *outermost* loop level —
  the same work-splitting the paper's OpenMP-style outer loop performs —
  and every shard runs the existing single-core path
  (:func:`~repro.core.lowering.ssr_call` /
  :func:`~repro.core.lowering.ssr_chain_call`) on its tile via
  ``shard_map``;
* the **shared-TCDM combine** of a reduction is one ``psum`` over the
  ``cores`` axis — the only inter-core communication.  Map-mode nests need
  none at all: per-core intermediates stay core-local, which
  :func:`repro.launch.hlo_analysis.check_cluster_locality` audits on the
  compiled HLO.

``cores=1`` degenerates to the plain single-core call (no mesh, no
collective), so the cluster layer is a strict superset of the §3 pipeline.
The matching cost model lives in :func:`repro.core.compiler.cluster_cost`
(Eq. (1)–(3) extended to C cores).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import autotune, resilience
from repro.core.compiler import Direction, LoopNest, MemRef
from repro.core.lowering import (BlockPolicy, DEFAULT_POLICY, Schedule,
                                 _record_fallback, ssr_call, ssr_chain_call)


class ClusterError(ValueError):
    """The nest/operands cannot be partitioned across the requested cores."""


CORES_AXIS = "cores"

#: Provenance of the most recent ``cluster_call``/``cluster_chain_call``:
#: the per-core schedule actually dispatched (tuned or default), the core
#: count, and the per-core tile bounds.  ``benchmarks/cluster_bench.py``
#: reads this to stamp schedule provenance onto its result rows; callers
#: should ``clear()`` it before the call they want attributed.
LAST_DISPATCH: Dict[str, object] = {}


def _record_dispatch(schedule: Optional[Schedule], cores: int,
                     bounds: Tuple[int, ...],
                     policy: BlockPolicy = DEFAULT_POLICY) -> None:
    from repro.core.lowering import DEFAULT_SCHEDULE

    # `tuned` means "came from the autotuner, not the default geometry":
    # an explicitly pinned DEFAULT_SCHEDULE (or a legacy policy=) is
    # still an untuned dispatch.
    if schedule is None:
        effective = DEFAULT_SCHEDULE if policy is DEFAULT_POLICY \
            else Schedule.from_policy(policy)
    else:
        effective = schedule
    LAST_DISPATCH.update(
        schedule=effective,
        tuned=schedule is not None and schedule != DEFAULT_SCHEDULE,
        cores=cores, tile_bounds=tuple(bounds))


def _cluster_mesh(cores: int, mesh: Optional[Mesh]) -> Mesh:
    if mesh is not None:
        if CORES_AXIS not in mesh.axis_names or mesh.shape[CORES_AXIS] != cores:
            raise ClusterError(
                f"mesh axes {mesh.axis_names}/{dict(mesh.shape)} do not "
                f"provide a '{CORES_AXIS}' axis of size {cores}")
        return mesh
    from repro.launch.mesh import make_cluster_mesh

    try:
        return make_cluster_mesh(cores)
    except ValueError as e:
        raise ClusterError(str(e)) from e


def pad_to_cores(arrays: Sequence[jax.Array],
                 cores: int) -> Tuple[Tuple[jax.Array, ...], int]:
    """Zero-pad 1-D operands so ``cores`` divides their length.

    The kernel-wrapper companion to :func:`cluster_call`'s divisibility
    requirement: returns the padded arrays and the padded length.  Only
    valid where zero padding is semantics-neutral — sum-like reductions
    (pad contributes 0) and maps whose tail the caller trims.
    """
    n = arrays[0].shape[0]
    pad = (-n) % cores
    if pad:
        arrays = [jnp.pad(a, (0, pad)) for a in arrays]
    return tuple(arrays), n + pad


def _split_level0(nest: LoopNest, cores: int) -> LoopNest:
    """The per-core tile: the outermost level split ``cores`` ways."""
    b0 = nest.bounds[0]
    if b0 % cores:
        raise ClusterError(
            f"outer bound {b0} not divisible by {cores} cores; pad the "
            "iteration space (zero padding is reduce-neutral) or pick a "
            "divisor core count")
    return dataclasses.replace(
        nest, bounds=(b0 // cores,) + nest.bounds[1:])


def _operand_ref(nests: Sequence[LoopNest],
                 name: str) -> Tuple[MemRef, LoopNest]:
    """The read ref named ``name`` and the nest that owns it."""
    for nest in nests:
        for ref in nest.refs:
            if ref.name == name and ref.kind == Direction.READ:
                return ref, nest
    raise ClusterError(f"operand {name!r} matches no read ref in the nest(s)")


def _shard_layout(ref: MemRef,
                  nest: LoopNest) -> Optional[Tuple[int, ...]]:
    """Logical shape to shard on dim 0, or ``None`` to replicate.

    A ref varying with the outermost level is partitioned with it: because
    lowering requires dense row-major layout over the varying levels, core
    ``c``'s tile is exactly rows ``[c·t, (c+1)·t)`` of the logical array.
    A ref with coefficient 0 at the split level (repeat/loop-invariant
    streams, e.g. GEMV's x) is replicated — every core streams its own
    copy, the TCDM-broadcast of the paper's cluster.
    """
    if ref.coeffs is None:
        raise ClusterError(
            f"ref {ref.name!r} is not affine; it cannot be streamed, let "
            "alone sharded")
    if ref.coeffs[0] == 0:
        return None
    if ref.offset:
        raise ClusterError(
            f"ref {ref.name!r}: base offset {ref.offset} cannot be "
            "partitioned on the outer level")
    return tuple(b for b, c in zip(nest.bounds, ref.coeffs) if c != 0)


def _prepare_operands(nests: Sequence[LoopNest],
                      operands: Dict[str, jax.Array]):
    """Reshape/spec every operand for ``shard_map`` over the cores axis."""
    names = sorted(operands)
    prepared, specs = [], []
    for name in names:
        ref, owner = _operand_ref(nests, name)
        layout = _shard_layout(ref, owner)
        arr = operands[name]
        if layout is None:
            prepared.append(arr)
            specs.append(P())
            continue
        # layout[0] is the outer bound; callers run _split_level0 first,
        # which guarantees it divides `cores`.
        try:
            view = arr.reshape(layout)
        except TypeError as e:  # jax raises TypeError on bad reshape
            raise ClusterError(
                f"operand {name!r} has {arr.size} elements, its stream "
                f"walks {layout}") from e
        prepared.append(view)
        specs.append(P(CORES_AXIS, *([None] * (len(layout) - 1))))
    return names, tuple(prepared), tuple(specs)


def _validate(cores: int, mode: str) -> None:
    if cores < 1:
        raise ClusterError(f"cores must be >= 1, got {cores}")
    if mode not in ("reduce", "map"):
        raise ClusterError(f"unknown cluster mode {mode!r}")


def _shard_operand_sig(nests: Sequence[LoopNest],
                       operands: Dict[str, jax.Array],
                       cores: int) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """Per-shard (shape, dtype) of every operand — what one core streams.

    Sharded refs (nonzero outer coefficient) split their leading logical
    dim C ways; replicated refs keep their global shape.  This is the
    schedule-cache identity of the *per-core tile*, so a winner tuned for
    the tile size (via the single-core tuner or a cluster sweep) is found
    regardless of the cluster-global operand shapes.
    """
    sig: Dict[str, Tuple[Tuple[int, ...], str]] = {}
    for name, arr in operands.items():
        ref, owner = _operand_ref(nests, name)
        layout = _shard_layout(ref, owner)
        if layout is None:
            sig[name] = (tuple(arr.shape), str(arr.dtype))
        else:
            sig[name] = ((layout[0] // cores,) + tuple(layout[1:]),
                         str(arr.dtype))
    return sig


def _safe_lookup(nest: LoopNest, operands, *, mode: str, out_dtype,
                 site: str) -> Optional[Schedule]:
    """Cache lookup that degrades to the default on typed dispatch faults.

    A broken cache must cost the cluster layer its tuned geometry, never
    the call: cache I/O errors and injected faults are recorded (one
    ``fallbacks`` tick + a :class:`FallbackEvent`) and resolve to ``None``
    — the default per-core schedule.  Returns ``None`` too on an ordinary
    miss, matching the pre-resilience contract.
    """
    try:
        sched = autotune.lookup(nest, operands, mode=mode,
                                out_dtype=str(jnp.dtype(out_dtype)))
    except resilience.fallback_error_types() as e:
        _record_fallback(site, e, from_schedule="tuned-lookup",
                         to_schedule="default")
        return None
    return None if sched == autotune.DEFAULT_SCHEDULE else sched


def _core_schedule(subs: Sequence[LoopNest],
                   operands: Dict[str, jax.Array], *,
                   mode: str, out_dtype) -> Optional[Schedule]:
    """The tuned schedule for one core's tile, or ``None`` (default).

    The per-core tile is a single-core problem of the *sharded* bounds, so
    the lookup keys on the sub-nest + per-shard operand shapes with
    ``cores=1`` — exactly what the tuner commits when it tunes that
    problem size.  Misses fall through to the default schedule.
    """
    try:
        sig = _shard_operand_sig(subs, operands, 1)  # subs are already split
    except ClusterError:
        return None
    # A chain keys on its stage-0 sub-nest; the operand signature (which
    # spans every stage) disambiguates chains sharing a producer shape.
    return _safe_lookup(subs[0], sig, mode=mode, out_dtype=out_dtype,
                        site="cluster:_core_schedule")


def _sharded_call(nests: Sequence[LoopNest], tile_fn: Callable,
                  operands: Dict[str, jax.Array], *, cores: int,
                  mode: str, mesh: Optional[Mesh]) -> jax.Array:
    """Shared shard_map scaffolding for the two clustered entry points.

    ``tile_fn(ops)`` runs one core's tile from its per-shard operand dict;
    reduces finish with the single psum, maps concatenate tiles along the
    split level.
    """
    names, prepared, in_specs = _prepare_operands(nests, operands)
    the_mesh = _cluster_mesh(cores, mesh)

    def per_core(*arrs):
        out = tile_fn(dict(zip(names, arrs)))
        if mode == "reduce":
            return jax.lax.psum(out, CORES_AXIS)
        return out

    out_specs = P() if mode == "reduce" else P(CORES_AXIS)
    fn = shard_map(per_core, mesh=the_mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(*prepared)


def cluster_call(nest: LoopNest, body: Callable[..., jax.Array],
                 operands: Dict[str, jax.Array], *,
                 cores: int,
                 mode: str = "reduce",
                 out_dtype=jnp.float32,
                 policy: BlockPolicy = DEFAULT_POLICY,
                 schedule: Optional[Schedule] = None,
                 num_lanes: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None) -> jax.Array:
    """Execute a :class:`LoopNest` sharded across a C-core device mesh.

    Same contract as :func:`~repro.core.lowering.ssr_call` plus ``cores``:
    the outermost loop level is split C ways, each core runs the single-core
    streamed kernel on its tile, and

    * ``mode="reduce"`` — per-core partials combine with one ``psum`` (the
      shared-TCDM reduction; the result is replicated on every core);
    * ``mode="map"`` — per-core output tiles concatenate along the split
      level; no collective is emitted at all.

    ``cores=1`` bypasses the mesh entirely and is bit-identical to
    ``ssr_call``.  Reduce bodies must be padding-neutral *and* tolerate the
    level-0 split (sum-like reductions are; order-sensitive folds are not).

    ``schedule=None`` resolves the **per-core tile's** schedule from the
    autotuner cache: the tile is a single-core problem of the *sharded*
    bounds, so the tuned block geometry tracks what one core actually
    streams, not the cluster-global shape.
    """
    _validate(cores, mode)
    if cores == 1:
        if schedule is None and policy is DEFAULT_POLICY:
            # Same resolution ssr_call/NestKernel perform (and under the
            # same guard: an explicit non-default policy pins the
            # geometry), so `cores=1` stays bit-identical to the
            # single-core registry path even after a tuner commit.
            schedule = _safe_lookup(nest, operands, mode=mode,
                                    out_dtype=out_dtype,
                                    site="cluster_call")
        _record_dispatch(schedule, 1, nest.bounds, policy)
        return ssr_call(nest, body, operands, mode=mode, out_dtype=out_dtype,
                        policy=policy, schedule=schedule,
                        num_lanes=num_lanes, interpret=interpret)
    sub = _split_level0(nest, cores)
    if schedule is None and policy is DEFAULT_POLICY:
        schedule = _core_schedule([sub], operands, mode=mode,
                                  out_dtype=out_dtype)
    _record_dispatch(schedule, cores, sub.bounds, policy)
    return _sharded_call(
        [nest],
        lambda ops: ssr_call(sub, body, ops, mode=mode, out_dtype=out_dtype,
                             policy=policy, schedule=schedule,
                             num_lanes=num_lanes, interpret=interpret),
        operands, cores=cores, mode=mode, mesh=mesh)


def cluster_chain_call(nests: Sequence[LoopNest],
                       bodies: Sequence[Callable[..., jax.Array]],
                       operands: Dict[str, jax.Array], *,
                       cores: int,
                       mode: str = "reduce",
                       out_dtype=jnp.float32,
                       policy: BlockPolicy = DEFAULT_POLICY,
                       schedule: Optional[Schedule] = None,
                       num_lanes: Optional[int] = None,
                       interpret: Optional[bool] = None,
                       mesh: Optional[Mesh] = None) -> jax.Array:
    """Execute a producer→consumer chain sharded across C cores.

    Each core runs the whole fused chain (ONE Pallas kernel — see
    :func:`~repro.core.lowering.ssr_chain_call`) on its tile of the shared
    iteration space, so the chained intermediates stay in *that core's*
    VMEM scratch: chaining composes with clustering because the link walk
    is dense row-major, hence splits cleanly on the outer level.  Only the
    final reduce (if any) crosses cores, via one ``psum``.
    """
    nests = tuple(nests)
    _validate(cores, mode)
    if cores == 1:
        if schedule is None and policy is DEFAULT_POLICY:
            # mirror ssr_chain_call's internal resolution (stage-0 nest +
            # full operand signature, same default-policy guard) so the
            # recorded provenance is the schedule the delegated call runs
            schedule = _safe_lookup(nests[0], operands, mode=mode,
                                    out_dtype=out_dtype,
                                    site="cluster_chain_call")
        _record_dispatch(schedule, 1, nests[0].bounds, policy)
        return ssr_chain_call(nests, bodies, operands, mode=mode,
                              out_dtype=out_dtype, policy=policy,
                              schedule=schedule, num_lanes=num_lanes,
                              interpret=interpret)
    subs = tuple(_split_level0(n, cores) for n in nests)
    if schedule is None and policy is DEFAULT_POLICY:
        schedule = _core_schedule(subs, operands, mode=mode,
                                  out_dtype=out_dtype)
    _record_dispatch(schedule, cores, subs[0].bounds, policy)
    return _sharded_call(
        nests,
        lambda ops: ssr_chain_call(subs, bodies, ops, mode=mode,
                                   out_dtype=out_dtype, policy=policy,
                                   schedule=schedule, num_lanes=num_lanes,
                                   interpret=interpret),
        operands, cores=cores, mode=mode, mesh=mesh)


def factor_cores(cores: int) -> Tuple[int, int]:
    """Closest-to-square (rows, cols) factorisation of a core count.

    The 2-D work split of :func:`cluster_kernel2d`: 8 → (4, 2), 4 → (2, 2),
    6 → (3, 2); a prime count degenerates to a 1-D row split (p, 1).
    """
    if cores < 1:
        raise ClusterError(f"cores must be >= 1, got {cores}")
    c = int(cores ** 0.5)
    while cores % c:
        c -= 1
    return cores // c, c


def cluster_kernel2d(fn: Callable, args: Sequence[jax.Array], *,
                     cores: int,
                     in_dims: Sequence[Tuple[Optional[int], Optional[int]]],
                     out_dims: Tuple[int, int] = (0, 1),
                     mesh: Optional[Mesh] = None):
    """Shard a registry kernel across a 2-D (rows × cols) core grid.

    The §5.3 cluster with *two* partitioned levels — GEMM's row×col split:
    ``cores`` factors into a (Cr, Cc) device grid (:func:`factor_cores`),
    ``in_dims[i] = (row_dim, col_dim)`` names which dim of ``args[i]``
    shards along each axis (``None`` = replicated on that axis), and every
    core runs the unchanged kernel on its tile.  The output tiles
    concatenate along ``out_dims`` — no collective is emitted, because
    each core owns a disjoint output tile (the contraction, if any, stays
    core-local).
    """
    args = tuple(args)
    if cores < 1:
        raise ClusterError(f"cores must be >= 1, got {cores}")
    if len(in_dims) != len(args):
        raise ClusterError(
            f"in_dims has {len(in_dims)} entries for {len(args)} args")
    if cores == 1:
        return fn(*args)
    cr, cc = factor_cores(cores)
    if mesh is None:
        import numpy as np

        devs = jax.devices()
        if len(devs) < cores:
            raise ClusterError(
                f"need {cores} devices for a {cr}x{cc} cluster, have "
                f"{len(devs)}; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={cores} before "
                "importing jax")
        mesh = Mesh(np.asarray(devs[:cores]).reshape(cr, cc),
                    ("rows", "cols"))
    specs = []
    for a, (rd, cd) in zip(args, in_dims):
        spec = [None] * a.ndim
        for dim, axis, extent in ((rd, "rows", cr), (cd, "cols", cc)):
            if dim is None:
                continue
            if a.shape[dim] % extent:
                raise ClusterError(
                    f"arg dim {dim} extent {a.shape[dim]} not divisible by "
                    f"{extent} ({axis}) cores")
            spec[dim] = axis
        specs.append(P(*spec))
    out_spec = [None] * (max(out_dims) + 1)
    out_spec[out_dims[0]] = "rows"
    out_spec[out_dims[1]] = "cols"
    wrapped = shard_map(fn, mesh=mesh, in_specs=tuple(specs),
                        out_specs=P(*out_spec), check_rep=False)
    return wrapped(*args)


def cluster_kernel(fn: Callable, args: Sequence[jax.Array], *,
                   cores: int,
                   in_dims: Sequence[Optional[int]],
                   out_dim: Optional[int] = None,
                   reduce: bool = False,
                   mesh: Optional[Mesh] = None):
    """Shard an existing registry kernel (not a nest) across C cores.

    For kernels whose iteration structure is neither a pure map nor a full
    reduction (e.g. GEMV: a reduction *per row*), the nest-level
    :func:`cluster_call` does not apply, but the work still splits on an
    output dimension.  ``in_dims[i]`` names the dim of ``args[i]`` to shard
    (``None`` = replicate, the repeat-stream operands); the per-core kernel
    runs unchanged on its slice.  ``reduce=True`` psums the outputs;
    otherwise ``out_dim`` is the concatenation dim.
    """
    args = tuple(args)
    if cores < 1:
        raise ClusterError(f"cores must be >= 1, got {cores}")
    if len(in_dims) != len(args):
        raise ClusterError(
            f"in_dims has {len(in_dims)} entries for {len(args)} args")
    if not reduce and out_dim is None:
        raise ClusterError("need out_dim (concat) or reduce=True (psum)")
    if cores == 1:
        return fn(*args)
    specs = []
    for a, dim in zip(args, in_dims):
        if dim is None:
            specs.append(P())
            continue
        if a.shape[dim] % cores:
            raise ClusterError(
                f"arg dim {dim} extent {a.shape[dim]} not divisible by "
                f"{cores} cores")
        spec = [None] * a.ndim
        spec[dim] = CORES_AXIS
        specs.append(P(*spec))
    the_mesh = _cluster_mesh(cores, mesh)

    def per_core(*arrs):
        out = fn(*arrs)
        if reduce:
            return jax.tree.map(lambda o: jax.lax.psum(o, CORES_AXIS), out)
        return out

    # Partial-rank spec: dims past out_dim are unsharded by convention, so
    # the output rank never needs probing.
    out_specs = P() if reduce else P(*([None] * out_dim), CORES_AXIS)
    wrapped = shard_map(per_core, mesh=the_mesh, in_specs=tuple(specs),
                        out_specs=out_specs, check_rep=False)
    return wrapped(*args)
