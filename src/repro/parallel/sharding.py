"""Sharding policy: DP / FSDP / TP / EP / SP rules as PartitionSpec trees.

Axes
----
``data``  — batch (DP) and the FSDP shard axis for parameters/optimizer state
``model`` — tensor parallelism (attention heads, FFN hidden, MoE experts=EP,
            long-context cache sequence=SP)
``pod``   — outer data-parallel axis on the multi-pod mesh (gradient
            all-reduce crosses the pod axis once per step)

Parameters get explicit per-leaf rules (FSDP+TP hybrid, ZeRO-3 style: every
weight is sharded on both an FSDP dim and, where it exists, a TP dim; XLA
inserts the per-layer all-gathers).  Optimizer moments mirror their
parameter's spec.  Caches/activations use a shape-driven heuristic
(divisibility-checked), which also covers the B=1 long-context cells by
falling back to sequence sharding (SP) when batch cannot split.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh: Mesh):
    """The data-parallel axes ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def model_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


# --- parameter rules -------------------------------------------------------
# map: leaf name -> (spec pattern per dim); DP marks the FSDP axis group,
# "model" the TP axis.  1-D leaves (norms, biases) follow their own rules.

_DP = "__dp__"

_PARAM_RULES = {
    # embeddings / head
    "embed": ("model", _DP),
    "lm_head": (_DP, "model"),
    "frontend_proj": (_DP, "model"),
    # attention
    "wq": (_DP, "model"),
    "wk": (_DP, None),     # GQA: kv heads replicated across TP shards
    "wv": (_DP, None),
    "wo": ("model", _DP),
    # MLA
    "wdq": (_DP, None),
    "wuq": (None, "model"),
    "wdkv": (_DP, None),
    "wkr": (_DP, None),
    "wuk": (None, "model"),
    "wuv": (None, "model"),
    # mlp
    "w_gate": (_DP, "model"),
    "w_up": (_DP, "model"),
    "w_down": ("model", _DP),
    "ffn_up": (_DP, "model"),
    "ffn_down": ("model", _DP),
    # router
    "router": (None, None),
    # mamba
    "w_in": (_DP, "model"),
    "conv_w": (None, "model"),
    "w_xproj": ("model", None),
    "w_dt": (None, "model"),
    "a_log": ("model", None),
    "w_out": ("model", _DP),
    # xlstm
    "w_ifo": ("model", None),
    "w_zifo": (_DP, "model"),
    "r_zifo": (_DP, "model"),
}

_EXPERT_RULES = {  # leaves under an "experts" subtree: dim0 = expert (EP)
    "w_gate": ("model", _DP, None),
    "w_up": ("model", _DP, None),
    "w_down": ("model", None, _DP),
}

_VEC_RULES = {  # 1-D leaves
    "conv_b": ("model",),
    "dt_bias": ("model",),
    "d_skip": ("model",),
    "b_zifo": ("model",),
}


def _fits(shape, spec, mesh: Mesh, dp) -> bool:
    for size, axis in zip(shape, spec):
        if axis is None:
            continue
        n = int(np.prod([mesh.shape[a] for a in axis])) if isinstance(
            axis, tuple) else mesh.shape[axis]
        if size % n:
            return False
    return True


def _resolve(pattern, mesh: Mesh, shape) -> P:
    dp = dp_axes(mesh)
    spec = tuple(dp if x == _DP else x for x in pattern)
    # drop axes that don't divide the dim (e.g. tiny models on big meshes)
    out = []
    for size, axis in zip(shape, spec):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        keep: list = []
        for a in axes:
            n = mesh.shape[a]
            if size % n == 0 and size // n >= 1 and (size // int(
                    np.prod([mesh.shape[k] for k in keep + [a]]))) >= 1 \
                    and size % int(
                    np.prod([mesh.shape[k] for k in keep + [a]])) == 0:
                keep.append(a)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def param_spec_tree(shapes: Any, cfg: ModelConfig, mesh: Mesh, *,
                    inference: bool = False):
    """PartitionSpec tree matching an ``eval_shape`` of ``init_params``.

    ``inference=True`` stores MoE expert weights sharded over
    ('model', 'data') jointly on the expert dim (weights-stationary 2-D EP
    for decode) when E divides the combined size.
    """

    expert_rules = _EXPERT_RULES
    if inference and cfg.moe is not None:
        combined = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                                if a in ("model", "data")]))
        if cfg.moe.num_experts % combined == 0:
            # 2-D EP: experts spread over (model × data) jointly
            ep2d = tuple(a for a in ("model", "data")
                         if a in mesh.axis_names)
            expert_rules = {
                "w_gate": (ep2d, None, None),
                "w_up": (ep2d, None, None),
                "w_down": (ep2d, None, None),
            }
        elif "data" in mesh.axis_names:
            # D-stationary small-E decode: experts over model, hidden over
            # data — matches _moe_apply_ep_dstat's in_specs exactly
            expert_rules = {
                "w_gate": ("model", "data", None),
                "w_up": ("model", "data", None),
                "w_down": ("model", "data", None),
            }

    def _inference_2d(core_shape) -> Optional[P]:
        """Weights-stationary decode sharding: shard a feature dim over
        ('model','data') jointly — weights never move at decode (activations
        are tiny; per-token FSDP gathers were 50 GB/token on llama3).
        Prefers the output dim (no psum); falls back to the input dim
        (XLA inserts a cheap psum of the tiny activations); else replicates.
        """
        axes = tuple(a for a in ("model", "data") if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if len(core_shape) != 2 or size <= 1:
            return None
        d_in, d_out = core_shape
        if d_out % size == 0:
            return P(None, axes)
        if d_in % size == 0:
            return P(axes, None)
        if d_out % mesh.shape["model"] == 0 if "model" in mesh.axis_names                 else False:
            return P(None, "model")
        return P(None, None)

    def rule(path, leaf) -> P:
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        in_group = names and names[0] == "groups"
        in_experts = "experts" in names
        shape = leaf.shape
        core_shape = shape[1:] if in_group else shape  # strip repeats axis
        if in_experts and name in expert_rules:
            pat = expert_rules[name]
            spec = _resolve(pat, mesh, core_shape)
        elif inference and len(core_shape) == 2:
            spec = _inference_2d(core_shape)
            if spec is None:
                spec = _resolve((None,) * len(core_shape), mesh, core_shape)
        elif len(core_shape) == 1:
            spec = _resolve(_VEC_RULES.get(name, (None,)), mesh, core_shape)
        elif name in _PARAM_RULES:
            spec = _resolve(_PARAM_RULES[name], mesh, core_shape)
        else:
            spec = _resolve((None,) * len(core_shape), mesh, core_shape)
        if in_group:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, shapes)


# --- activation / cache / batch heuristics ---------------------------------


def batch_spec(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> P:
    """Leading-dim spec for per-example inputs (tokens/labels/embeds)."""
    dp = dp_axes(mesh)
    keep = []
    rem = global_batch
    for a in dp:
        if rem % mesh.shape[a] == 0:
            keep.append(a)
            rem //= mesh.shape[a]
    return P(tuple(keep) if keep else None)


def heuristic_spec(shape: Sequence[int], mesh: Mesh, *, batch_dim: int = 0,
                   seq_dim: Optional[int] = None) -> P:
    """Greedy: shard batch over dp; then the sequence (or largest) dim over
    'model'; leave the rest replicated.  Used for KV caches and decode state.
    """
    dp = dp_axes(mesh)
    spec: list = [None] * len(shape)
    rem = shape[batch_dim]
    keep = []
    for a in dp:
        if rem % mesh.shape[a] == 0 and rem // mesh.shape[a] >= 1:
            keep.append(a)
            rem //= mesh.shape[a]
    if keep:
        spec[batch_dim] = tuple(keep) if len(keep) > 1 else keep[0]
    unused = [a for a in dp if a not in keep] + ["model"]
    # choose the dim to shard over remaining axes: prefer seq_dim, else max
    cand = seq_dim
    if cand is None or spec[cand] is not None or shape[cand] < 2:
        sizes = [(s, i) for i, s in enumerate(shape)
                 if spec[i] is None and i != batch_dim]
        cand = max(sizes)[1] if sizes else None
    if cand is not None:
        keep2 = []
        rem2 = shape[cand]
        for a in unused:
            if a in mesh.shape and rem2 % mesh.shape[a] == 0 \
                    and rem2 // mesh.shape[a] >= 1:
                keep2.append(a)
                rem2 //= mesh.shape[a]
        if keep2:
            spec[cand] = tuple(keep2) if len(keep2) > 1 else keep2[0]
    return P(*spec)


def cache_spec_tree(cache_shapes: Any, cfg: ModelConfig, mesh: Mesh):
    """Specs for decode caches: batch over dp, sequence over model (SP)."""

    def rule(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        shape = leaf.shape
        # group caches carry a leading repeats axis
        core = shape[1:]
        seq_dim = None
        if name in ("k", "v"):
            seq_dim = 1  # (B, S, KV, dh)
        elif name == "lat":
            seq_dim = 1  # (B, S, latent)
        spec = heuristic_spec(core, mesh, batch_dim=0, seq_dim=seq_dim)
        return P(None, *spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
