"""Int8 gradient compression with error feedback — distributed-optimization
trick for the cross-pod gradient all-reduce.

The ``pod`` axis crosses data-center-interconnect-class links, so the
once-per-step gradient all-reduce there is the natural compression target:
grads are quantised to int8 with a per-leaf absmax scale, summed over the
axis, and dequantised; the quantisation error is fed back into the next
step's gradients (error-feedback keeps SGD/Adam convergence — tested on a
small model in tests/test_distributed.py).

Used via ``shard_map`` (``compressed_psum``) where explicit collective
control exists; the jit-SPMD training path keeps XLA's fused all-reduce by
default and enables this only when ``--compress-grads`` is set.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, residual: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (quantised, scale, new_residual) with error feedback."""
    gf = g.astype(jnp.float32) + residual
    q, scale = quantize(gf)
    new_res = gf - dequantize(q, scale)
    return q, scale, new_res


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads: Any, residuals: Any, axis_name: str
                    ) -> Tuple[Any, Any]:
    """All-reduce-mean ``grads`` over ``axis_name`` in int8 (+error feedback).

    Must run inside ``shard_map``/``pmap`` where ``axis_name`` is bound.
    int8 summands are widened to int32 for the reduction (n ≤ 2^23 devices
    before overflow at |q| ≤ 127) and rescaled by the max scale across the
    axis so all peers dequantise identically.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        q, scale, new_r = compress_leaf(g, r)
        scale_max = jax.lax.pmax(scale, axis_name)
        # requantise against the shared scale so the sum is well-defined
        q_shared = jnp.clip(
            jnp.round(dequantize(q, scale) / scale_max), -127, 127
        ).astype(jnp.int32)
        total = jax.lax.psum(q_shared, axis_name)
        mean = total.astype(jnp.float32) * scale_max / n
        return mean.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
