"""Optimizers and distributed-optimization tricks."""

from .adamw import AdamWConfig, global_norm, init, update, warmup_cosine  # noqa: F401
from .compress import (  # noqa: F401
    compress_leaf,
    compressed_psum,
    dequantize,
    init_residuals,
    quantize,
)
