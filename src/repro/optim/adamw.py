"""AdamW with global-norm clipping, decoupled weight decay, and
configurable moment dtype (bf16 moments let the 405B/671B configs fit
16 GB/chip — recorded per-config).

Pure-functional: ``init`` → state pytree mirroring params (so the sharding
policy reuses the parameter specs), ``update`` → (new_params, new_state).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"


def init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def update(grads: Any, state: Dict[str, Any], params: Any,
           cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = cfg.learning_rate(count) if callable(cfg.learning_rate) \
        else cfg.learning_rate
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def one(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        upd = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * upd
        return p_new.astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [one(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.float32(lr)}


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def schedule(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return schedule
