"""Architecture registry: the ten assigned configs + the paper's own suite.

Each module defines ``CONFIG`` (the exact published configuration) and
``smoke()`` (a reduced same-family variant for CPU tests).  ``get(name)``
resolves either.  Input-shape cells are defined in ``shapes.py``.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, smoke_variant

ARCH_IDS: List[str] = [
    "xlstm_125m",
    "jamba_v01_52b",
    "yi_6b",
    "llama3_405b",
    "h2o_danube_18b",
    "qwen3_14b",
    "deepseek_v3_671b",
    "dbrx_132b",
    "hubert_xlarge",
    "internvl2_26b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch '{name}'; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if hasattr(mod, "smoke"):
        return mod.smoke()
    return smoke_variant(mod.CONFIG)


def all_configs() -> Dict[str, ModelConfig]:
    return {i: get(i) for i in ARCH_IDS}
