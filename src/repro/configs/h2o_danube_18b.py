"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24 layers, d_model 2560, 32 heads (GQA kv=8), d_ff 6912, vocab 32000,
SWA window 4096.  The bounded ring-buffer KV cache is what makes the
long_500k decode cell runnable (DESIGN.md §4).
"""

from repro.models.config import ModelConfig, smoke_variant, uniform_dense_groups

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    head_dim=80,
    groups=uniform_dense_groups(24),
    window=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    microbatches=2,
)


def smoke():
    return smoke_variant(CONFIG)
