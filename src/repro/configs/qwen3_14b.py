"""qwen3-14b — GQA with per-head qk RMS-norm [hf:Qwen/Qwen3-8B; hf].

40 layers, d_model 5120, 40 heads (GQA kv=8), d_ff 17408, vocab 151936.
"""

from repro.models.config import ModelConfig, smoke_variant, uniform_dense_groups

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    groups=uniform_dense_groups(40),
    qk_norm=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    microbatches=4,
)


def smoke():
    return smoke_variant(CONFIG)
