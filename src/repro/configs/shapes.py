"""Input-shape cells for the LM-family architectures (brief: 4 per arch).

``train_4k``    seq 4096,   global batch 256  → lowers ``train_step``
``prefill_32k`` seq 32768,  global batch 32   → lowers ``prefill_step``
``decode_32k``  context 32768, batch 128      → lowers ``serve_step``
``long_500k``   context 524288, batch 1       → lowers ``serve_step``

Skips (DESIGN.md §4): encoder-only archs have no decode step; ``long_500k``
requires a sub-quadratic context mechanism (recurrent state, sliding window,
or MLA latent cache).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def skip_reason(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None if the (arch × shape) cell runs; else the documented skip."""
    if cell.kind == "decode" and not cfg.has_decode:
        return "encoder-only architecture: no decode step"
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention architecture: 500k-token KV cache is "
                "quadratic-regime; skipped per brief")
    return None


def cells_for(cfg: ModelConfig) -> List[Tuple[ShapeCell, Optional[str]]]:
    return [(s, skip_reason(cfg, s)) for s in SHAPES]
