"""internvl2-26b — InternViT frontend + InternLM2-20B backbone [arXiv:2404.16821; hf].

Backbone: 48 layers, d_model 6144, 48 heads (GQA kv=8), d_ff 16384,
vocab 92553.  The InternViT vision tower is a STUB per the brief:
``input_specs`` provides 256 precomputed patch embeddings per image which a
linear adapter projects into the LM space (prefix positions).
"""

from repro.models.config import ModelConfig, smoke_variant, uniform_dense_groups

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    groups=uniform_dense_groups(48),
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_len=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    microbatches=4,
)


def smoke():
    return smoke_variant(CONFIG)
