"""yi-6b — llama-architecture GQA decoder [arXiv:2403.04652; hf].

32 layers, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000.
"""

from repro.models.config import ModelConfig, smoke_variant, uniform_dense_groups

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    groups=uniform_dense_groups(32),
    rope_theta=5_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    microbatches=4,
)


def smoke():
    return smoke_variant(CONFIG)
