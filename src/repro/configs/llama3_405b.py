"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783; unverified].

126 layers, d_model 16384, 128 heads (GQA kv=8), d_ff 53248, vocab 128256.
The scale stressor: FSDP+TP sharding and bf16 optimizer moments are required
to fit 16 GB/chip on the single-pod mesh (EXPERIMENTS.md §Dry-run).
"""

from repro.models.config import ModelConfig, smoke_variant, uniform_dense_groups

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    groups=uniform_dense_groups(126),
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    microbatches=16,
)


def smoke():
    return smoke_variant(CONFIG)
