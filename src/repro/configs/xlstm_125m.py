"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12 layers, d_model 768, 4 heads, d_ff = 0 (xLSTM blocks own their
projections: mLSTM pre-up-projection x2, sLSTM post gated FFN x4/3),
vocab 50304.  Block ratio mLSTM:sLSTM ~ 7:1 per the paper's xLSTM[7:1];
with 12 layers we use two (5xmLSTM + 1xsLSTM) groups.
"""

from repro.models.config import ModelConfig, ScanGroup, XLSTMConfig, smoke_variant

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    groups=(
        ScanGroup(pattern=(("mlstm", "none"),) * 5 + (("slstm", "none"),),
                  repeats=2),
    ),
    xlstm=XLSTMConfig(),
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    microbatches=4,
)


def smoke():
    return smoke_variant(CONFIG)
