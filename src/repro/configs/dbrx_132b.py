"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified].

40 layers, d_model 6144, 48 heads (GQA kv=8), per-expert d_ff 10752,
vocab 100352; every layer's FFN is MoE.
"""

from repro.models.config import ModelConfig, MoEConfig, ScanGroup, smoke_variant

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    groups=(ScanGroup(pattern=(("attn", "moe"),), repeats=40),),
    moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752),
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_dtype="bfloat16",
    microbatches=8,
)


def smoke():
    return smoke_variant(CONFIG)
