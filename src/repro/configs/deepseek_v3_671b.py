"""deepseek-v3-671b — MLA + 1 shared / 256 routed top-8 MoE [arXiv:2412.19437; hf].

61 layers (first 3 dense, 58 MoE), d_model 7168, 128 heads with multi-head
latent attention (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128),
dense d_ff 18432, expert d_ff 2048 (the assignment table's d_ff=2048 is the
per-expert width), vocab 129280.  MTP (multi-token prediction) is omitted —
it is a training-objective add-on orthogonal to operand streaming; noted in
DESIGN.md §Arch-applicability.  The MLA latent cache (576/token/layer) makes
the long_500k decode cell feasible.
"""

from repro.models.config import (MLAConfig, ModelConfig, MoEConfig, ScanGroup,
                                 smoke_variant)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    head_dim=128,
    groups=(
        ScanGroup(pattern=(("mla", "mlp"),), repeats=3),
        ScanGroup(pattern=(("mla", "moe"),), repeats=58),
    ),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    microbatches=16,
)


def smoke():
    return smoke_variant(CONFIG)
