"""jamba-v0.1-52b — Mamba+attention 1:7 interleave with MoE [arXiv:2403.19887; hf].

32 layers (4 Jamba blocks of 8), d_model 4096, 32 heads (GQA kv=8),
d_ff 14336, vocab 65536; 16 experts top-2, MoE every other layer, the
single attention layer at position 4 of each 8-layer block.
"""

from repro.models.config import (MambaConfig, ModelConfig, MoEConfig,
                                 ScanGroup, smoke_variant)

_PATTERN = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("attn", "moe"),
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    groups=(ScanGroup(pattern=_PATTERN, repeats=4),),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_dtype="bfloat16",
    microbatches=8,
)


def smoke():
    return smoke_variant(CONFIG)
