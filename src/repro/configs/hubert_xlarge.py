"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447; unverified].

48 layers, d_model 1280, 16 heads, d_ff 5120, target vocab 504 (k-means
units).  The conv waveform frontend is a STUB per the brief: ``input_specs``
feeds precomputed frame embeddings (B, S, d_model).  Encoder-only ⇒ no
decode shapes (DESIGN.md §4).  Positional handling: HuBERT's conv positional
embedding is replaced by RoPE in this implementation (positional mechanism
is orthogonal to operand streaming; recorded as a hardware-adaptation note).
"""

from repro.models.config import ModelConfig, smoke_variant, uniform_dense_groups

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    groups=uniform_dense_groups(48, ffn="gelu_mlp"),
    causal=False,
    frontend="audio",
    frontend_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    microbatches=2,
)


def smoke():
    return smoke_variant(CONFIG)
