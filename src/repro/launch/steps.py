"""Step functions: train (grad-accumulated), prefill, decode.

These are the jit roots that the dry-run lowers and the drivers execute.
All are pure: ``train_step(params, opt_state, batch) → (params, opt_state,
metrics)``; gradient accumulation is a ``lax.scan`` over microbatches
(activation footprint stays one-microbatch-sized regardless of global
batch — required for the 405B/671B cells to fit).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import config as mcfg
from repro.models import model as mdl
from repro.optim import adamw


def make_train_step(cfg: mcfg.ModelConfig, opt_cfg: adamw.AdamWConfig,
                    *, microbatches: Optional[int] = None) -> Callable:
    m = microbatches or cfg.microbatches

    def loss(p, mb):
        return mdl.loss_fn(p, cfg, mb)

    acc_dt = jnp.dtype(cfg.grad_accum_dtype)

    def train_step(params, opt_state, batch):
        if m > 1:
            split = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (lv, _), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, l_acc + lv), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, lsum), _ = jax.lax.scan(accum, (zeros, jnp.float32(0.0)),
                                            split)
            grads = jax.tree.map(lambda g: g / m, grads)
            lv = lsum / m
        else:
            (lv, _), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                               opt_cfg)
        return new_params, new_opt, {"loss": lv, **om}

    return train_step


def make_prefill_step(cfg: mcfg.ModelConfig, *, cache_len: int,
                      batch_chunks: int = 1) -> Callable:
    """(params, batch) → (last-position logits, caches).

    ``batch_chunks > 1`` processes the request batch in sequential chunks
    (``lax.map``) — prefill has no gradient rematerialisation to bound its
    footprint, so chunking the batch is what keeps 32k-token prefill of the
    MoE giants inside HBM (EXPERIMENTS §Dry-run).
    """

    def one_chunk(params, batch):
        if not cfg.has_decode:  # encoder: plain forward, no cache
            logits, _, _ = mdl.forward(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"))
            return logits, ()
        logits, caches, _ = mdl.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), want_cache=True, cache_len=cache_len)
        return logits[:, -1:], caches

    if batch_chunks <= 1:
        return one_chunk

    def prefill_step(params, batch):
        split = jax.tree.map(
            lambda x: x.reshape(batch_chunks, x.shape[0] // batch_chunks,
                                *x.shape[1:]), batch)
        logits, caches = jax.lax.map(
            lambda mb: one_chunk(params, mb), split)
        # un-chunk the leading batch axis everywhere
        merge = lambda x: x.reshape(x.shape[0] * x.shape[1], *x.shape[2:])
        logits = merge(logits)
        caches = jax.tree.map(
            lambda x: jnp.moveaxis(x, 0, 1).reshape(
                x.shape[1], x.shape[0] * x.shape[2], *x.shape[3:]), caches)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: mcfg.ModelConfig) -> Callable:
    """(params, caches, tokens (B,1), positions (B,)) → (logits, caches)."""

    def serve_step(params, caches, tokens, positions):
        return mdl.decode_step(params, cfg, tokens, caches, positions)

    return serve_step


def init_train_state(key, cfg: mcfg.ModelConfig,
                     opt_cfg: adamw.AdamWConfig) -> Dict[str, Any]:
    params = mdl.init_params(key, cfg)
    return {"params": params, "opt": adamw.init(params, opt_cfg)}


def abstract_train_state(cfg: mcfg.ModelConfig,
                         opt_cfg: adamw.AdamWConfig):
    """ShapeDtypeStruct pytree of the train state (no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg))


def abstract_caches(cfg: mcfg.ModelConfig, batch: int, max_len: int, dtype):
    return jax.eval_shape(
        lambda: mdl.init_caches(cfg, batch, max_len, dtype))
