"""Batched serving driver: prefill a request batch, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
        --batch 8 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline
from repro.launch import steps as step_lib
from repro.models import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")

    params = init_params(jax.random.PRNGKey(0), cfg)
    dcfg = pipeline.DataConfig(args.batch, args.prompt_len, seed=11)
    prompts = pipeline.make_batch(cfg, dcfg, 0)
    prompts.pop("labels", None)

    max_len = args.prompt_len + args.gen + 1
    prefill = jax.jit(step_lib.make_prefill_step(cfg, cache_len=max_len))
    serve = jax.jit(step_lib.make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [cur]
    t1 = time.time()
    for t in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + t, jnp.int32)
        logits, caches = serve(params, caches, cur, pos)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(cur)
    out = jax.block_until_ready(jnp.concatenate(toks, axis=1))
    t_decode = time.time() - t1

    total = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:8.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:,.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:8.1f} ms "
          f"({total/max(t_decode,1e-9):,.0f} tok/s, "
          f"{t_decode/max(args.gen-1,1)*1e3:.1f} ms/step)")
    print(f"sample continuation (seq 0): {out[0, :16].tolist()}")


if __name__ == "__main__":
    main()
