"""Trip-count-aware HLO analysis: FLOPs, memory traffic, collective bytes.

``compiled.cost_analysis()`` does **not** multiply while-loop bodies by their
trip counts (verified empirically), so for scan-rolled models (every config
here: layers, microbatches, recurrences are ``lax.scan``) its FLOPs
undercount by orders of magnitude.  This module walks the SPMD-partitioned
HLO text instead:

* builds the computation graph (entry, fusions, while bodies/conditions),
* extracts while trip counts from the loop-condition constants,
* accumulates per-device dot FLOPs (2 · |out| · contraction), elementwise
  FLOPs (1 · |out| for arithmetic ops), output bytes (an HBM-traffic proxy),
  and per-collective link traffic with ring-model factors:

    all-gather: (n−1)/n · |out|      reduce-scatter: (n−1)/n · |in|
    all-reduce: 2(n−1)/n · |buf|     all-to-all:     (n−1)/n · |buf|
    collective-permute: |buf|

  where n is the participant-group size parsed from ``replica_groups``.

Shapes in the partitioned module are shard-local, so every quantity is
per-device — exactly what the §Roofline terms need.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_ELEMENTWISE = (
    "add(", "subtract(", "multiply(", "divide(", "maximum(", "minimum(",
    "exponential(", "tanh(", "rsqrt(", "sqrt(", "log(", "power(", "negate(",
    "logistic(", "cosine(", "sine(", "select(", "compare(", "and(", "or(",
)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes_elems(text: str) -> Tuple[int, int]:
    """Total (bytes, elements) across all array shapes in a type string."""
    total_b = 0
    total_e = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            elems = math.prod(int(d) for d in dims.split(","))
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dtype]
    return total_b, total_e


@dataclasses.dataclass
class OpLine:
    name: str
    kind: str
    result_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpLine]


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes_out: float = 0.0          # Σ output bytes (HBM-traffic proxy)
    collective_bytes: float = 0.0   # per-device link traffic, ring model
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    collective_detail: List[Dict] = dataclasses.field(default_factory=list)
    while_trips: List[int] = dataclasses.field(default_factory=list)

    def add(self, other: "Analysis", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes_out += other.bytes_out * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) \
                + int(v * mult)


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_HEADER.match(line.strip())
        if m and line.strip().endswith("{"):
            cur = Computation(name=m.group(1), ops=[])
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            name, rtype, kind = om.groups()
            cur.ops.append(OpLine(name=name, kind=kind, result_type=rtype,
                                  line=line.strip()))
    if entry is None:  # fall back: computation named main*
        for n in comps:
            if n.startswith("main"):
                entry = n
                break
    return comps, entry or next(iter(comps))


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


def _group_size(line: str, world: int) -> int:
    # replica_groups=[G,N]<=[...]  → N participants per group
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    # explicit groups: {{0,1,2,3},{4,5,6,7}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return world


def _trip_count(comps: Dict[str, Computation], cond_name: str,
                default: int = 1) -> int:
    """Largest integer constant reachable from the while condition."""
    best = default
    seen = set()
    stack = [cond_name]
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        for op in comps[cname].ops:
            if op.kind == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    best = max(best, int(m.group(1)))
            called = _attr(op.line, "calls")
            if called:
                stack.append(called)
    return best


def _dot_flops(op: OpLine, shapes: Dict[str, str]) -> float:
    """2 · |out| · contraction-size.  Contraction from lhs dims.

    Handles both HLO operand spellings: inline-typed
    (``dot(f32[8,64]{1,0} %a, f32[64,64]{1,0} %b)``, jax ≤ 0.4.x) and bare
    names (``dot(%a, %b)``), falling back to the computation's shape table.
    """
    out_b, out_e = _shape_bytes_elems(op.result_type)
    m = re.search(r"\bdot\((.*?)\)", op.line)
    contraction = 1
    if m:
        operands = m.group(1)
        # lhs shape: first inline type if present, else look the name up
        shape_m = _SHAPE_RE.search(operands)
        if shape_m is None:
            name = operands.split(",")[0].strip().lstrip("%")
            lhs_type = shapes.get(name)
            shape_m = _SHAPE_RE.search(lhs_type) if lhs_type else None
        cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        if shape_m and shape_m.group(2) and cd and cd.group(1):
            dims = [int(d) for d in shape_m.group(2).split(",")]
            for i in cd.group(1).split(","):
                i = int(i)
                if i < len(dims):
                    contraction *= dims[i]
    return 2.0 * out_e * contraction


def analyze_computation(comps: Dict[str, Computation], name: str,
                        world: int, _memo: Dict[str, Analysis]) -> Analysis:
    if name in _memo:
        return _memo[name]
    comp = comps.get(name)
    out = Analysis()
    if comp is None:
        _memo[name] = out
        return out
    _memo[name] = out  # break cycles defensively
    shapes = {op.name: op.result_type for op in comp.ops}
    # also record parameter shapes from declaration lines
    for op in comp.ops:
        kind = op.kind
        line = op.line
        ob, oe = _shape_bytes_elems(op.result_type)
        if kind == "dot":
            f = _dot_flops(op, shapes)
            out.flops += f
            out.dot_flops += f
            out.bytes_out += ob
        elif kind == "while":
            body = _attr(line, "body")
            cond = _attr(line, "condition")
            trips = _trip_count(comps, cond, 1) if cond else 1
            out.while_trips.append(trips)
            sub = analyze_computation(comps, body, world, _memo) if body \
                else Analysis()
            out.add(sub, trips)
            if cond:
                out.add(analyze_computation(comps, cond, world, _memo), trips)
        elif kind in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "conditional", "custom-call"):
            called = _attr(line, "calls") or _attr(line, "to_apply")
            if called:
                sub = analyze_computation(comps, called, world, _memo)
                # to_apply bodies (reduce etc.) run per element; approximate
                # with |out| applications for reduce-likes, 1 for fusion/call
                mult = 1.0 if kind in ("fusion", "call", "conditional") \
                    else float(oe)
                out.add(sub, mult)
            out.bytes_out += ob
            if kind == "fusion":
                out.flops += oe  # fused elementwise ≈ 1 flop/elem
        elif (kind + "(") in _ELEMENTWISE:
            out.flops += oe
            out.bytes_out += ob
        else:
            out.bytes_out += ob
        # collectives
        for cname in _COLLECTIVES:
            if kind == cname or kind == cname + "-start":
                n = _group_size(line, world)
                if cname == "all-reduce":
                    traffic = 2.0 * ob * (n - 1) / max(n, 1)
                elif cname == "collective-permute":
                    traffic = float(ob)
                else:
                    traffic = ob * (n - 1) / max(n, 1)
                out.collective_bytes += traffic
                out.collective_counts[cname] = \
                    out.collective_counts.get(cname, 0) + 1
                out.collective_detail.append(
                    {"op": cname, "bytes": ob, "group": n,
                     "traffic": traffic})
                break
    return out


def analyze_hlo(hlo: str, world: int) -> Analysis:
    comps, entry = parse_computations(hlo)
    return analyze_computation(comps, entry, world, {})


# --------------------------------------------------------------------------
# Cluster locality audit: do per-core intermediates stay core-local?
# --------------------------------------------------------------------------


def collective_counts(hlo: str, world: int = 1) -> Dict[str, int]:
    """Per-op collective counts of a compiled module (trip-count-aware)."""
    return dict(analyze_hlo(hlo, world).collective_counts)


@dataclasses.dataclass(frozen=True)
class LocalityCheck:
    """Compiled-HLO evidence that a clustered kernel communicates only
    through its final combine (paper §5.3: cores share results via the
    TCDM *once*, everything else is core-local).

    A ``reduce``-mode cluster call may emit exactly one ``all-reduce``
    (the psum combine); a ``map``-mode call must emit no collective at
    all — any extra collective means a per-core intermediate leaked off
    core.
    """

    mode: str
    counts: Dict[str, int]

    @property
    def ok(self) -> bool:
        extras = {k: v for k, v in self.counts.items() if k != "all-reduce"}
        n_ar = self.counts.get("all-reduce", 0)
        if extras:
            return False
        return n_ar == (1 if self.mode == "reduce" else 0)


def check_cluster_locality(fn, args, kwargs=None, *, mode: str,
                           world: int = 1) -> LocalityCheck:
    """Compile a clustered call and audit its collectives.

    ``fn(*args, **kwargs)`` must be the full cluster call (including the
    shard_map).  Returns the verdict; callers assert ``.ok``.
    """
    import jax  # deferred: this module is otherwise jax-free text analysis

    kwargs = kwargs or {}
    hlo = jax.jit(lambda *a: fn(*a, **kwargs)).lower(*args) \
        .compile().as_text()
    return LocalityCheck(mode=mode, counts=collective_counts(hlo, world))


# --------------------------------------------------------------------------
# Fusion audit: is the chained intermediate's HBM buffer actually gone?
# --------------------------------------------------------------------------


def count_materialized(hlo: str, dtype: str, dims: Tuple[int, ...]) -> int:
    """Ops (parameters excluded) whose result materialises ``dtype[dims]``.

    Tuple-typed results (while-loop state etc.) count every matching
    component: a buffer carried through a loop is still a live buffer.
    """
    comps, _ = parse_computations(hlo)
    want = (dtype, ",".join(str(d) for d in dims))
    n = 0
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "parameter":
                continue
            for dt, ds in _SHAPE_RE.findall(op.result_type):
                if (dt, ds) == want:
                    n += 1
    return n


@dataclasses.dataclass(frozen=True)
class FusionCheck:
    """Compiled-HLO evidence for (or against) intermediate elimination.

    ``*_buffers`` counts materialisations of the intermediate's exact
    padded type in each program; ``*_bytes_out`` is the total output-bytes
    traffic proxy from :func:`analyze_hlo`.  The fused program must not
    materialise *more* intermediate-typed buffers and must move strictly
    fewer bytes — otherwise the "fusion" just hid the copy somewhere else.
    (The buffer census is ``<=``, not ``<``: since prepare/trim fuse into
    each kernel's jitted program, XLA can alias away the copy buffers of
    the *unfused* composition too, so at some problem sizes both programs
    count the same number of intermediate-shaped values even though the
    unfused one still runs two grid loops with an HBM hand-off between
    them.  The strict byte reduction is what pins the eliminated
    store+load.)
    """

    dtype: str
    dims: Tuple[int, ...]
    fused_buffers: int
    unfused_buffers: int
    fused_bytes_out: float
    unfused_bytes_out: float

    @property
    def intermediate_eliminated(self) -> bool:
        return (self.fused_buffers <= self.unfused_buffers
                and self.fused_bytes_out < self.unfused_bytes_out)

    @property
    def bytes_saved(self) -> float:
        return self.unfused_bytes_out - self.fused_bytes_out


def check_fusion(fused_fn, unfused_fn, args, kwargs,
                 dtype: str, dims: Tuple[int, ...],
                 world: int = 1) -> FusionCheck:
    """Compile both variants and audit the intermediate buffer.

    ``dtype``/``dims`` describe the padded 2-D buffer the unfused
    composition materialises between its kernels (HLO spelling, e.g.
    ``("f32", (32, 128))``).  Compilation happens on the host backend —
    the *structure* (which buffers exist) is what is asserted, and that is
    backend-independent for the interpret/Mosaic pair by construction.
    """
    import jax  # deferred: this module is otherwise jax-free text analysis

    def lower(fn):
        wrapped = jax.jit(lambda *a: fn(*a, **kwargs))
        return wrapped.lower(*args).compile().as_text()

    fused_hlo = lower(fused_fn)
    unfused_hlo = lower(unfused_fn)
    return FusionCheck(
        dtype=dtype, dims=tuple(dims),
        fused_buffers=count_materialized(fused_hlo, dtype, dims),
        unfused_buffers=count_materialized(unfused_hlo, dtype, dims),
        fused_bytes_out=analyze_hlo(fused_hlo, world).bytes_out,
        unfused_bytes_out=analyze_hlo(unfused_hlo, world).bytes_out)


# --------------------------------------------------------------------------
# DAG fusion audit: every intermediate at once, multi-consumer included.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DagFusionCheck:
    """Compiled-HLO evidence that a fused DAG's intermediates are gone.

    A DAG has several intermediates (a diamond's multi-consumer value plus
    the ordinary links); ``inters`` lists each one's padded (dtype, dims)
    as the *unfused* composition materialises it.  The census counts every
    distinct intermediate shape once per side (duplicate shapes in
    ``inters`` dedupe — two f32 buffers of the same padded dims are
    indistinguishable in HLO text, so their counts are summed under one
    entry) and applies the :class:`FusionCheck` criterion in aggregate:
    no more intermediate-shaped buffers, strictly fewer bytes moved.
    """

    inters: Tuple[Tuple[str, Tuple[int, ...]], ...]
    fused_buffers: int
    unfused_buffers: int
    fused_bytes_out: float
    unfused_bytes_out: float
    per_shape: Tuple[Tuple[str, Tuple[int, ...], int, int], ...] = ()

    @property
    def intermediates_eliminated(self) -> bool:
        return (self.fused_buffers <= self.unfused_buffers
                and self.fused_bytes_out < self.unfused_bytes_out)

    @property
    def bytes_saved(self) -> float:
        return self.unfused_bytes_out - self.fused_bytes_out


def check_dag_fusion(fused_fn, unfused_fn, args, kwargs,
                     inters, world: int = 1) -> DagFusionCheck:
    """Compile both variants and audit EVERY DAG intermediate's buffer.

    ``inters`` is an iterable of ``(dtype, dims)`` pairs — one per
    intermediate the unfused composition materialises (see
    ``repro.kernels.dag.DagCase.inters``).  Both programs must run the
    same pinned schedule so fusion is the only structural difference.
    """
    import jax  # deferred: this module is otherwise jax-free text analysis

    def lower(fn):
        wrapped = jax.jit(lambda *a: fn(*a, **kwargs))
        return wrapped.lower(*args).compile().as_text()

    fused_hlo = lower(fused_fn)
    unfused_hlo = lower(unfused_fn)
    shapes = []                       # distinct, first-seen order
    for dtype, dims in inters:
        key = (dtype, tuple(dims))
        if key not in shapes:
            shapes.append(key)
    per_shape = tuple(
        (dtype, dims,
         count_materialized(fused_hlo, dtype, dims),
         count_materialized(unfused_hlo, dtype, dims))
        for dtype, dims in shapes)
    return DagFusionCheck(
        inters=tuple((d, tuple(s)) for d, s in inters),
        fused_buffers=sum(f for _, _, f, _ in per_shape),
        unfused_buffers=sum(u for _, _, _, u in per_shape),
        fused_bytes_out=analyze_hlo(fused_hlo, world).bytes_out,
        unfused_bytes_out=analyze_hlo(unfused_hlo, world).bytes_out,
        per_shape=per_shape)
