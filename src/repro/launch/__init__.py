"""Subsystem package."""
