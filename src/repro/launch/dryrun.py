import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import (jax locks the device count on first
#   init).  Everything below this line may import jax.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this harness:

1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
2. derives the sharding policy (parallel/sharding.py) for params, optimizer
   state, inputs, and caches,
3. lowers the appropriate step function with ShapeDtypeStruct stand-ins
   (``input_specs`` — zero allocation, the 671B param tree never exists),
4. compiles, records ``memory_analysis()`` (per-device — proves it fits),
   ``cost_analysis()`` (raw XLA numbers), and the trip-count-adjusted HLO
   walk (FLOPs / bytes / per-collective link traffic) for §Roofline,
5. appends the record to a resumable JSON cache.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import shapes as shp
from repro.data import pipeline
from repro.launch import hlo_analysis, steps
from repro.launch.mesh import describe, make_production_mesh
from repro.models import config as mcfg
from repro.models import model as mdl
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.activations import activation_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_PATH = "dryrun_results.json"


def _clamp_microbatches(cfg, cell, mesh) -> int:
    """Largest m ≤ cfg.microbatches with (B/m) divisible by the dp size."""
    dp = shd.dp_size(mesh)
    m = min(cfg.microbatches, cell.global_batch)
    while m > 1 and (cell.global_batch % m
                     or (cell.global_batch // m) % dp):
        m -= 1
    return max(m, 1)


def _batch_specs(cfg, cell, mesh, dcfg):
    ispecs = pipeline.input_specs(cfg, dcfg)
    bspec = shd.batch_spec(cfg, mesh, cell.global_batch)
    out = {}
    for k, v in ispecs.items():
        out[k] = P(*([bspec[0]] + [None] * (len(v.shape) - 1)))
    return ispecs, out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               cfg_override: Optional[mcfg.ModelConfig] = None,
               extra_tag: str = "") -> Dict[str, Any]:
    cfg = cfg_override or configs.get(arch)
    cell = shp.get_shape(shape_name)
    skip = shp.skip_reason(cfg, cell)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "tag": extra_tag,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
    }
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["mesh_desc"] = describe(mesh)
    _am = activation_mesh(mesh)
    _am.__enter__()
    t0 = time.time()
    opt_cfg = adamw.AdamWConfig(moment_dtype=cfg.optimizer_dtype)

    if cell.kind == "train":
        micro = _clamp_microbatches(cfg, cell, mesh)
        rec["microbatches"] = micro
        seq = cell.seq_len - (cfg.frontend_len if cfg.frontend == "vision"
                              else 0)
        dcfg = pipeline.DataConfig(cell.global_batch, seq)
        state = steps.abstract_train_state(cfg, opt_cfg)
        pspec = shd.param_spec_tree(state["params"], cfg, mesh)
        ospec = {"m": pspec, "v": pspec, "count": P()}
        ispecs, bspec = _batch_specs(cfg, cell, mesh, dcfg)
        fn = steps.make_train_step(cfg, opt_cfg, microbatches=micro)
        with mesh:
            lowered = jax.jit(
                fn,
                in_shardings=(shd.named(mesh, pspec), shd.named(mesh, ospec),
                              shd.named(mesh, bspec)),
                out_shardings=(shd.named(mesh, pspec), shd.named(mesh, ospec),
                               None),
                donate_argnums=(0, 1),
            ).lower(state["params"], state["opt"], ispecs)
    elif cell.kind == "prefill":
        seq = cell.seq_len - (cfg.frontend_len if cfg.frontend == "vision"
                              else 0)
        dcfg = pipeline.DataConfig(cell.global_batch, seq)
        params = jax.eval_shape(
            lambda: mdl.init_params(jax.random.PRNGKey(0), cfg))
        pspec = shd.param_spec_tree(params, cfg, mesh)
        ispecs, bspec = _batch_specs(cfg, cell, mesh, dcfg)
        ispecs.pop("labels", None)
        bspec.pop("labels", None)
        # batch-chunking is OFF for the dry-run: the post-chunk cache
        # merge relayouts across the sharded batch dim (observed 192 GiB on
        # yi prefill); EP MoE + streaming attention bound prefill instead.
        fn = steps.make_prefill_step(cfg, cache_len=cell.seq_len)
        cache_out = None
        if cfg.has_decode:
            caches_abs = steps.abstract_caches(
                cfg, cell.global_batch,
                min(cell.seq_len, cfg.window) if cfg.window else cell.seq_len,
                jnp.dtype(cfg.compute_dtype))
            cache_out = shd.named(
                mesh, shd.cache_spec_tree(caches_abs, cfg, mesh))
        with mesh:
            lowered = jax.jit(
                fn,
                in_shardings=(shd.named(mesh, pspec),
                              shd.named(mesh, bspec)),
                out_shardings=(None, cache_out),
            ).lower(params, ispecs)
    else:  # decode
        b = cell.global_batch
        params = jax.eval_shape(
            lambda: mdl.init_params(jax.random.PRNGKey(0), cfg))
        pspec = shd.param_spec_tree(params, cfg, mesh, inference=True)
        caches = steps.abstract_caches(
            cfg, b, cell.seq_len, jnp.dtype(cfg.compute_dtype))
        cspec = shd.cache_spec_tree(caches, cfg, mesh)
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        bsp = shd.batch_spec(cfg, mesh, b)
        fn = steps.make_decode_step(cfg)
        with mesh:
            lowered = jax.jit(
                fn,
                in_shardings=(shd.named(mesh, pspec),
                              shd.named(mesh, cspec),
                              NamedSharding(mesh, P(bsp[0], None)),
                              NamedSharding(mesh, P(bsp[0]))),
                out_shardings=(None, shd.named(mesh, cspec)),
                donate_argnums=(1,),
            ).lower(params, caches, tok, pos)

    _am.__exit__()
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    memstats = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(memstats.argument_size_in_bytes),
        "output_bytes": int(memstats.output_size_in_bytes),
        "temp_bytes": int(memstats.temp_size_in_bytes),
        "alias_bytes": int(memstats.alias_size_in_bytes),
        "peak_per_device_gib": round(
            (memstats.argument_size_in_bytes
             + memstats.output_size_in_bytes
             + memstats.temp_size_in_bytes
             - memstats.alias_size_in_bytes) / 2**30, 3),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis_raw"] = {
        k: float(v) for k, v in ca.items()
        if isinstance(v, (int, float)) and k in
        ("flops", "bytes accessed", "transcendentals")
    }
    t2 = time.time()
    hlo = compiled.as_text()
    an = hlo_analysis.analyze_hlo(hlo, mesh.size)
    rec["hlo"] = {
        "flops_per_device": an.flops,
        "dot_flops_per_device": an.dot_flops,
        "bytes_out_per_device": an.bytes_out,
        "collective_bytes_per_device": an.collective_bytes,
        "collective_counts": an.collective_counts,
        "while_trips": sorted(set(an.while_trips), reverse=True)[:8],
        "hlo_chars": len(hlo),
    }
    rec["analyze_s"] = round(time.time() - t2, 2)
    rec["status"] = "ok"
    return rec


def load_results(path: str = RESULTS_PATH) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(results: Dict[str, Any], path: str = RESULTS_PATH) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def cell_key(arch: str, shape: str, mesh: str, tag: str = "") -> str:
    k = f"{arch}|{shape}|{mesh}"
    return f"{k}|{tag}" if tag else k


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"],
                    default="both")
    ap.add_argument("--out", default=RESULTS_PATH)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in shp.SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]

    results = load_results(args.out)
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                key = cell_key(arch, shape, mesh_name)
                if key in results and not args.force \
                        and results[key].get("status") in ("ok", "skipped"):
                    print(f"[cached] {key}", flush=True)
                    continue
                print(f"[run] {key}", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp)
                except Exception as e:  # noqa: BLE001 - record and continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                results[key] = rec
                save_results(results, args.out)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" mem={rec['memory']['peak_per_device_gib']}GiB"
                             f" compile={rec['compile_s']}s")
                elif status == "skipped":
                    extra = f" ({rec['skip_reason'][:60]})"
                else:
                    extra = f" ({rec['error'][:80]})"
                print(f"  -> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
