"""Training driver: checkpointed, fault-tolerant, optionally multi-device.

Examples::

    # smoke-scale run on CPU with failure injection + restart
    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --smoke \
        --steps 50 --fail-at 17 --ckpt /tmp/ckpt

    # sharded run over fake devices
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --smoke \
        --steps 20 --mesh 4,2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.launch import steps as step_lib
from repro.launch.mesh import describe, make_host_mesh
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.activations import activation_mesh
from repro.runtime.fault import FailureInjector, StragglerMonitor, Supervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated node failures at these steps")
    ap.add_argument("--mesh", default=None,
                    help="data,model (requires that many devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    opt_cfg = adamw.AdamWConfig(
        learning_rate=adamw.warmup_cosine(args.lr, 10, args.steps),
        moment_dtype=cfg.optimizer_dtype)
    dcfg = pipeline.DataConfig(global_batch=args.batch, seq_len=args.seq)

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_host_mesh(data=d, model=m)
        print(f"mesh: {describe(mesh)}")

    state = step_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    train = step_lib.make_train_step(cfg, opt_cfg,
                                     microbatches=args.microbatches)
    if mesh is not None:
        pspec = shd.param_spec_tree(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         state["params"]), cfg, mesh)
        ospec = {"m": pspec, "v": pspec,
                 "count": jax.sharding.PartitionSpec()}
        with mesh, activation_mesh(mesh):
            train = jax.jit(
                train,
                in_shardings=(shd.named(mesh, pspec), shd.named(mesh, ospec),
                              None),
                out_shardings=(shd.named(mesh, pspec),
                               shd.named(mesh, ospec), None),
                donate_argnums=(0, 1))
            state = {
                "params": jax.device_put(state["params"],
                                         shd.named(mesh, pspec)),
                "opt": jax.device_put(state["opt"], shd.named(mesh, ospec)),
            }
    else:
        train = jax.jit(train, donate_argnums=(0, 1))

    metrics_log = []

    def step_fn(st, step):
        batch = pipeline.make_batch(cfg, dcfg, step)
        params, opt, metrics = train(st["params"], st["opt"], batch)
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            metrics_log.append((step, loss))
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):8.3f}", flush=True)
        return {"params": params, "opt": opt}

    sup = Supervisor(
        ckpt=CheckpointManager(args.ckpt, keep=3),
        checkpoint_every=args.checkpoint_every,
        injector=FailureInjector(fail_at_steps=tuple(args.fail_at)),
        straggler=StragglerMonitor())
    latest = sup.ckpt.latest_step()
    start = 0
    if latest is not None:
        print(f"resuming from checkpoint step {latest}")
        state = sup.ckpt.restore(latest, state)
        start = latest

    t0 = time.time()
    state = sup.run(state, step_fn, args.steps, start_step=start)
    dt = time.time() - t0
    tok = (args.steps - start) * args.batch * args.seq
    print(f"done: {args.steps} steps, {tok/max(dt,1e-9):,.0f} tok/s, "
          f"restarts={sup.restarts}, events={sup.events}")
    if len(metrics_log) >= 2:
        print(f"loss: first {metrics_log[0][1]:.4f} -> "
              f"last {metrics_log[-1][1]:.4f}")


if __name__ == "__main__":
    main()
