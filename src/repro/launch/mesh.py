"""Production mesh construction.

``make_production_mesh`` builds the target fleet meshes:

* single pod: 16×16 = 256 chips, axes ``(data, model)``
* multi-pod:  2×16×16 = 512 chips, axes ``(pod, data, model)`` — ``pod`` is
  the outer data-parallel axis (one cross-pod gradient all-reduce per step).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets ``XLA_FLAGS`` for 512 host devices *before*
any jax import; tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.38; older releases imply Auto axes
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many (fake) devices the host exposes."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def make_cluster_mesh(cores: int) -> Mesh:
    """1-D ``cores`` mesh for the SSR cluster layer (paper §5.3–5.5).

    One device per core, axis name ``cores`` — the mesh axis
    ``parallel/cluster.py`` shards streamed iteration spaces over.  Built
    from an explicit device list (never ``make_mesh``) so a host exposing
    more devices than cores still yields exactly the requested cluster.
    """
    import numpy as np

    devs = jax.devices()
    if cores < 1:
        raise ValueError(f"cores must be >= 1, got {cores}")
    if len(devs) < cores:
        raise ValueError(
            f"need {cores} devices for a {cores}-core cluster, have "
            f"{len(devs)}; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={cores} before "
            "importing jax")
    return Mesh(np.asarray(devs[:cores]), ("cores",))


def describe(mesh: Mesh) -> str:
    return "×".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names) + \
        f" ({mesh.size} chips)"
