"""The ``ssrcfg`` CSR analogue: opt-in stream semantics, off by default.

Paper §2.2.2: "The SSR extension needs to be opt-in and disabled by default
[...] Sections of code using SSR are expected to set this bit at their
beginning and clear it at their end, essentially defining an 'SSR region'."

Model code consults :func:`ssr_enabled` when choosing between the streamed
Pallas kernel path and the plain-XLA path; both are semantically identical
(tested), so flipping the bit is always safe — exactly the compatibility
property the CSR gives existing RISC-V binaries.
"""

from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def ssr_enabled() -> bool:
    return getattr(_state, "enabled", False)


@contextlib.contextmanager
def ssr_region(enabled: bool = True):
    """``csrwi ssrcfg, 1`` … ``csrwi ssrcfg, 0`` as a context manager."""
    prev = ssr_enabled()
    _state.enabled = enabled
    try:
        yield
    finally:
        _state.enabled = prev


def set_ssr(enabled: bool) -> None:
    _state.enabled = enabled
