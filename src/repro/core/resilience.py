"""Resilience layer: fault-injection seams, typed fallback set, retry.

The paper frames SSR as a *non-invasive* extension — baseline execution
always remains available as a correct fallback — and this repo's dispatch
stack honours the same contract in software: every tuned/pipelined/fused
fast path must degrade to a correct slower path instead of crashing.  This
module is the shared substrate that makes that contract testable:

* **Seams** — named points in the dispatch stack where a fault can be
  injected deterministically (:data:`SEAMS`): schedule-cache reads and
  writes, the lowering of a plan to Pallas blocks, the jitted-pipeline
  compile, and the autotuner's timing loop.  Production code calls
  :func:`inject` at each seam; it is a no-op until a fault is armed via
  :func:`arm` / the :func:`inject_faults` context manager / the
  ``REPRO_FAULTS`` env var (``"seam[:kind[:times]]"``, comma-separated).
  This generalises ``runtime/fault.py``'s step-indexed
  :class:`~repro.runtime.fault.FailureInjector` — same idea (deterministic,
  bounded, recorded firings), keyed by seam name instead of step number —
  and ``runtime.fault.SimulatedFailure`` now derives from
  :class:`InjectedFault` so one ``except`` clause covers both families.

* **Typed fallback set** — :func:`fallback_error_types`.  Dispatch only
  degrades on this closed set (injected faults, ``LoweringError``, cache
  I/O ``OSError``, XLA compile failures); genuine user/numerics errors
  (missing operands, shape mismatches, NaNs) are never masked.

* **FallbackEvent log** — every degradation is recorded structurally
  (seam, dispatch site, error, from→to schedule, quarantined key) so tests
  and the ``--chaos-smoke`` bench can assert the *ladder*, not just the
  result.

* **retry()** — bounded retry with jittered exponential backoff for
  transient I/O (the schedule cache's commit path uses it; so can any
  test).  Deterministic when handed a seeded ``rng``/fake ``sleep``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

#: The dispatch stack's injection points.  ``cache.read``/``cache.write``
#: fire inside :class:`repro.core.autotune.ScheduleCache` probes/commits,
#: ``lowering`` inside ``lower_plan``/``lower_nest``/``lower_chain``,
#: ``compile`` just before each jitted-pipeline build (``ssr_call`` /
#: ``ssr_chain_call`` / ``ssr_dag_call`` / ``NestKernel``), ``measure``
#: inside the autotuner's timing loop.
SEAMS = ("cache.read", "cache.write", "lowering", "compile", "measure")

#: Injection flavours: ``fault`` raises :class:`InjectedFault` (a generic
#: infrastructure failure), ``oserror`` raises :class:`InjectedOSError`
#: (a transient I/O failure — the :func:`retry` helper's food).
KINDS = ("fault", "oserror")

_ENV_FAULTS = "REPRO_FAULTS"


class InjectedFault(RuntimeError):
    """A deterministic injected infrastructure failure at a named seam."""

    def __init__(self, seam: str, kind: str = "fault"):
        super().__init__(f"injected {kind} at seam {seam!r}")
        self.seam = seam
        self.kind = kind


class InjectedOSError(OSError):
    """Injected *transient* I/O failure — retriable, typed as OSError."""

    def __init__(self, seam: str):
        super().__init__(f"injected transient OSError at seam {seam!r}")
        self.seam = seam
        self.kind = "oserror"


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: fire ``times`` times at ``seam`` then go quiet.

    ``times < 0`` means unlimited (every :func:`inject` at the seam
    raises).  ``fired`` records how often it actually went off.
    """

    seam: str
    kind: str = "fault"
    times: int = 1
    fired: int = 0

    def exhausted(self) -> bool:
        return 0 <= self.times <= self.fired

    def raise_(self) -> None:
        self.fired += 1
        FAULT_STATS["injected"] += 1
        FAULT_STATS[self.seam] = FAULT_STATS.get(self.seam, 0) + 1
        if self.kind == "oserror":
            raise InjectedOSError(self.seam)
        raise InjectedFault(self.seam, self.kind)


_ARMED: List[FaultSpec] = []
_ARMED_LOCK = threading.Lock()
_ENV_CONSUMED = False

#: ``injected`` total plus a per-seam firing count.
FAULT_STATS: Dict[str, int] = {"injected": 0}


def parse_faults(text: str) -> List[FaultSpec]:
    """Parse a ``REPRO_FAULTS`` value: ``seam[:kind[:times]]``, commas.

    Examples: ``"cache.read"`` (one InjectedFault on the first cache
    probe), ``"cache.write:oserror:2"`` (two transient OSErrors on the
    commit path — exactly what :func:`retry` absorbs), ``"compile"``.
    Unknown seams/kinds fail loudly: a typo must not silently disarm a
    chaos run.
    """
    specs = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        seam = bits[0]
        if seam not in SEAMS:
            raise ValueError(f"unknown fault seam {seam!r}; seams: {SEAMS}")
        kind = bits[1] if len(bits) > 1 and bits[1] else "fault"
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; kinds: {KINDS}")
        times = int(bits[2]) if len(bits) > 2 else 1
        specs.append(FaultSpec(seam=seam, kind=kind, times=times))
    return specs


def arm(seam: str, *, kind: str = "fault", times: int = 1) -> FaultSpec:
    """Arm one fault; returns the spec so callers can inspect ``fired``."""
    if seam not in SEAMS:
        raise ValueError(f"unknown fault seam {seam!r}; seams: {SEAMS}")
    if kind not in KINDS:
        raise ValueError(f"unknown fault kind {kind!r}; kinds: {KINDS}")
    spec = FaultSpec(seam=seam, kind=kind, times=times)
    with _ARMED_LOCK:
        _ARMED.append(spec)
    return spec


def disarm(spec: FaultSpec) -> None:
    with _ARMED_LOCK:
        if spec in _ARMED:
            _ARMED.remove(spec)


def armed_specs() -> List[FaultSpec]:
    with _ARMED_LOCK:
        return list(_ARMED)


def reset_faults(*, reload_env: bool = False) -> None:
    """Disarm everything and zero the firing stats.

    ``reload_env=True`` re-reads ``REPRO_FAULTS`` on the next
    :func:`inject`; the default marks the env as consumed, so tests that
    reset the injector are immune to an ambient chaos matrix.
    """
    global _ENV_CONSUMED
    with _ARMED_LOCK:
        _ARMED.clear()
    FAULT_STATS.clear()
    FAULT_STATS["injected"] = 0
    _ENV_CONSUMED = not reload_env


def _arm_from_env() -> None:
    global _ENV_CONSUMED
    if _ENV_CONSUMED:
        return
    _ENV_CONSUMED = True
    text = os.environ.get(_ENV_FAULTS, "")
    if not text:
        return
    with _ARMED_LOCK:
        _ARMED.extend(parse_faults(text))


def inject(seam: str) -> None:
    """The seam hook: raise if a fault is armed here, else do nothing.

    Deterministic: the first non-exhausted armed spec for ``seam`` fires
    (in arming order), its ``fired`` count advances, and an exhausted spec
    never fires again — so ``times=1`` models exactly one transient
    failure followed by a healthy system, the shape every graceful-
    degradation test wants.
    """
    _arm_from_env()
    if not _ARMED:          # fast path: nothing armed, zero overhead
        return
    with _ARMED_LOCK:
        spec = next((s for s in _ARMED
                     if s.seam == seam and not s.exhausted()), None)
    if spec is not None:
        spec.raise_()


@contextlib.contextmanager
def inject_faults(*seams: str, kind: str = "fault", times: int = 1):
    """Arm faults for a ``with`` block; disarmed (and counted) on exit.

    Yields the list of armed :class:`FaultSpec`, so the block can assert
    how often each actually fired.
    """
    specs = [arm(s, kind=kind, times=times) for s in seams]
    try:
        yield specs
    finally:
        for s in specs:
            disarm(s)


# --------------------------------------------------------------------------
# Typed fallback-error set + classification
# --------------------------------------------------------------------------


def fallback_error_types() -> Tuple[type, ...]:
    """The closed set of error types dispatch may degrade on.

    Injected faults, the lowering's own rejection type, cache-I/O
    ``OSError``, and XLA's runtime/compile error when jax is importable.
    Everything else — missing operands (``ValueError``), bad body
    signatures (``TypeError``), numerics — propagates untouched: fallback
    must never mask a genuine user error.
    """
    types: List[type] = [InjectedFault, OSError]
    from .lowering import LoweringError
    types.append(LoweringError)
    try:
        from jax.errors import JaxRuntimeError
        types.append(JaxRuntimeError)
    except ImportError:       # pragma: no cover - older jax
        try:
            from jax._src.lib import xla_client
            types.append(xla_client.XlaRuntimeError)
        except (ImportError, AttributeError):
            pass
    return tuple(types)


def classify(exc: BaseException) -> str:
    """Best-effort seam attribution of a fallback-triggering error."""
    seam = getattr(exc, "seam", None)
    if isinstance(seam, str):
        return seam
    if type(exc).__name__ == "LoweringError":
        return "lowering"
    if isinstance(exc, OSError):
        return "cache.read"
    return "compile"


# --------------------------------------------------------------------------
# Structured fallback log
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FallbackEvent:
    """One recorded rung-descent on the degradation ladder."""

    seam: str            # which seam failed (classify() of the error)
    site: str            # dispatch entry: "ssr_call", "nest_kernel", ...
    error_type: str      # type name of the triggering error
    error: str           # str() of the triggering error
    from_schedule: str   # what was being attempted ("tuned", "ssr", ...)
    to_schedule: str     # the rung landed on ("default", "baseline", ...)
    key: Optional[str] = None   # quarantined cache key, if any


FALLBACK_LOG: List[FallbackEvent] = []
_FALLBACK_LOG_MAX = 4096


def record_fallback(*, seam: str, site: str, error: BaseException,
                    from_schedule: str, to_schedule: str,
                    key: Optional[str] = None) -> FallbackEvent:
    event = FallbackEvent(seam=seam, site=site,
                          error_type=type(error).__name__,
                          error=str(error), from_schedule=from_schedule,
                          to_schedule=to_schedule, key=key)
    if len(FALLBACK_LOG) >= _FALLBACK_LOG_MAX:
        del FALLBACK_LOG[:_FALLBACK_LOG_MAX // 2]
    FALLBACK_LOG.append(event)
    return event


def fallback_events() -> List[FallbackEvent]:
    return list(FALLBACK_LOG)


def reset_fallback_log() -> None:
    FALLBACK_LOG.clear()


# --------------------------------------------------------------------------
# Bounded retry with jittered exponential backoff
# --------------------------------------------------------------------------

#: Module-level deterministic jitter source: reproducible backoff
#: sequences without threading a seed through every call site.
_RETRY_RNG = random.Random(0x5E51)


def retry(fn: Callable[[], Any], *, attempts: int = 3,
          base_delay: float = 0.005, max_delay: float = 0.1,
          retry_on: Tuple[type, ...] = (OSError,),
          sleep: Callable[[float], None] = time.sleep,
          rng: Optional[random.Random] = None,
          on_retry: Optional[Callable[[int, BaseException], None]] = None
          ) -> Any:
    """Call ``fn`` up to ``attempts`` times, backing off between tries.

    Retries only on ``retry_on`` (transient I/O by default); any other
    exception — and the last ``retry_on`` failure — propagates.  Backoff
    is exponential with full jitter, capped at ``max_delay``;
    ``on_retry(attempt, error)`` fires before each re-try so callers can
    count retries in their stats.
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    rng = rng or _RETRY_RNG
    for attempt in range(1, attempts + 1):
        try:
            return fn()
        except retry_on as e:
            if attempt == attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            delay = min(max_delay, base_delay * (2 ** (attempt - 1)))
            sleep(rng.uniform(0, delay))


def reset() -> None:
    """Full module reset: armed faults, stats, and the fallback log."""
    reset_faults()
    reset_fallback_log()
