"""The §3.2 "SSR-ification" compiler pass, ported from LLVM MIR to a loop IR.

The paper's pass runs after instruction selection and before register
allocation: it (1) finds loops, (2) pattern-matches affine load/store address
expressions, (3) allocates candidates to the available data movers
*deepest-first*, (4) emits stream configuration before the loop header,
(5) replaces the memory ops with stream-register uses, and (6) blocks the
stream registers during register allocation.

Our input "MIR" is a :class:`LoopNest` of affine :class:`MemRef` accesses plus
a compute-op count — the information the MIR pattern-match extracts.  The
output :class:`StreamPlan` carries the allocated :class:`StreamSpec` per lane,
the residual (non-SSRable) accesses, and the Eq. (1)–(3) cost verdict, and can
be lowered straight to ``ssr_pallas`` streams.  The paper's caveat that "not
every loop benefits from SSRs" is the Eq. (3) test, applied per nest exactly
as §3.2 recommends ("at compile time based on the expected number of
iterations").
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from . import isa
from . import nest_analysis
from .nest_analysis import LoopNest, MemRef  # noqa: F401  (re-exported API)
from .stream import Direction, StreamSpec, MAX_DIMS  # noqa: F401

DEFAULT_NUM_LANES = 2  # the implementation in the paper has two data movers


@dataclasses.dataclass(frozen=True)
class Allocation:
    lane: int
    ref: MemRef
    spec: StreamSpec


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    nest: LoopNest
    allocations: Tuple[Allocation, ...]
    residual: Tuple[MemRef, ...]   # accesses that stay as explicit loads/stores
    ssrified: bool                 # Eq. (3) verdict (False => emit baseline)
    n_ssr: int
    n_base: int
    # Index-handling instructions (index load + per-element pointer
    # arithmetic) the baseline pays for each *allocated* indirect ref and
    # the indirection extension elides — the quantity Indirection-SSR
    # (arXiv 2011.08070) / Sparse SSR (arXiv 2305.05559) report per nnz.
    eliminated_idx_instrs: int = 0

    @property
    def speedup(self) -> float:
        return self.n_base / self.n_ssr if self.ssrified else 1.0


# Depth/lane/instruction analyses live in core/nest_analysis.py — one
# derivation shared by ssrify, chain, cluster_cost and the lowering.
_ref_depth = nest_analysis.ref_depth


def _to_spec(ref: MemRef, nest: LoopNest) -> StreamSpec:
    """Build the AGU configuration for an affine access in this nest.

    Loop levels whose coefficient is zero become ``repeat`` (read streams:
    the same datum re-emitted — the paper's repeat register) when they are
    innermost, or bound-1 dims otherwise.

    An *indirect* ref has no static walk of its own — its AGU is slaved to
    the index stream's (arXiv 2011.08070: the index FIFO feeds the address
    stage), so its spec is the index stream's walk rebased at the gather
    table.
    """
    assert ref.coeffs is not None
    if ref.is_indirect():
        idx_spec = _to_spec(nest_analysis.index_stream_of(ref, nest), nest)
        return dataclasses.replace(idx_spec, base=ref.offset,
                                   direction=ref.kind)
    bounds: List[int] = []
    strides: List[int] = []
    repeat = 1
    # walk from outermost; trailing zero-coeff levels of a read stream fold
    # into the repeat register.
    coeffs = list(ref.coeffs)
    trailing_zero = 0
    for c in reversed(coeffs):
        if c == 0:
            trailing_zero += 1
        else:
            break
    if ref.kind == Direction.READ and trailing_zero:
        for lvl in range(len(coeffs) - trailing_zero, len(coeffs)):
            repeat *= nest.bounds[lvl]
        coeffs = coeffs[: len(coeffs) - trailing_zero]
    for lvl, c in enumerate(coeffs):
        bounds.append(nest.bounds[lvl])
        strides.append(c)
    if not bounds:  # scalar (loop-invariant) access
        bounds, strides = [1], [0]
    return StreamSpec(bounds=tuple(bounds), strides=tuple(strides),
                      base=ref.offset, repeat=repeat, direction=ref.kind)


def ssrify(nest: LoopNest, *, num_lanes: int = DEFAULT_NUM_LANES,
           force: bool = False) -> StreamPlan:
    """Run the pass: allocate streams deepest-first, then apply Eq. (3).

    ``force=True`` skips the profitability test (the paper's "runtime
    decision" path where both variants exist and the caller knows N).
    """
    candidates = [r for r in nest.refs if r.is_affine() or r.is_indirect()]
    residual = [r for r in nest.refs
                if not (r.is_affine() or r.is_indirect())]
    # §3.2 step 3: deepest-first — a simple heuristic for iteration count.
    candidates.sort(key=lambda r: _ref_depth(r, nest), reverse=True)
    allocations: List[Allocation] = []
    for ref in candidates:
        if len(allocations) < num_lanes:
            allocations.append(
                Allocation(lane=len(allocations), ref=ref,
                           spec=_to_spec(ref, nest)))
        else:
            residual.append(ref)

    d = len(nest.bounds)
    s = len(allocations)
    L = list(nest.bounds)
    # Residual explicit memory ops stay in the body at their depth: fold
    # them into per-level instruction counts for the cost model.  Streamed
    # and baseline bodies carry the same residual ops — only the allocated
    # lanes differ — so one count serves both Eq. (1) and Eq. (2)...
    I = nest_analysis.instr_counts(nest, residual)
    # ...except for allocated *indirect* refs: Eq. (2)'s s-term charges one
    # explicit memory instruction per lane per iteration, but a gather also
    # pays the index→pointer arithmetic in the baseline body.  The
    # indirection extension folds that into the AGU, so the extra charge
    # lands on the baseline count only (arXiv 2011.08070 §III).
    I_base = list(I)
    eliminated = 0
    for a in allocations:
        if not a.ref.is_indirect():
            continue
        depth = max(0, _ref_depth(a.ref, nest))
        I_base[depth] += 1
        iters = 1
        for lvl in range(depth + 1):
            iters *= nest.bounds[lvl]
        # Per executed element the baseline issues an index load plus the
        # pointer arithmetic; both vanish once the lane gathers directly.
        eliminated += 2 * iters
    n_with = isa.n_ssr(L, I, max(s, 1)) if s else isa.n_base(L, I, 0)
    n_without = isa.n_base(L, I_base, s)
    # force=True is the paper's "runtime decision" path: both variants are
    # compiled and the caller elects SSR regardless of the static verdict.
    profitable = bool(s) and (
        force or (isa.ssr_profitable(L) and n_with <= n_without))
    if not profitable:
        return StreamPlan(nest=nest, allocations=(), residual=tuple(nest.refs),
                          ssrified=False, n_ssr=n_without, n_base=n_without)
    return StreamPlan(nest=nest, allocations=tuple(allocations),
                      residual=tuple(residual), ssrified=True,
                      n_ssr=n_with, n_base=n_without,
                      eliminated_idx_instrs=eliminated)


# --------------------------------------------------------------------------
# Stream chaining: fuse producer→consumer nests into one stream region.
#
# "A RISC-V ISA Extension for Chaining in Scalar Processors" (Colagrande et
# al., 2025) chains a producer's output stream directly into a consumer's
# input stream, so the intermediate never round-trips through memory.  Our
# block-granular analogue fuses whole LoopNests: if nest k writes a ref that
# nest k+1 reads with the *same* affine walk over the *same* iteration
# space, the store and the load cancel and both bodies run inside one
# stream region.  The Eq. (1)–(3) accounting extends naturally: one setup
# instead of len(nests), fewer allocated lanes, and — the quantity that
# actually decides memory-bound kernels — 2·ΠL eliminated loads+stores per
# link.
# --------------------------------------------------------------------------


class ChainError(ValueError):
    """The nests cannot be unified into one chained stream region."""


@dataclasses.dataclass(frozen=True)
class ChainLink:
    """One producer→consumer edge: the unified intermediate ref.

    ``coeffs``/``offset`` are the (identical) affine walk of the producer's
    write and the consumer's read; ``elems`` is ΠL — the number of elements
    that never touch memory once the link is fused.
    """

    name: str
    producer_stage: int
    coeffs: Tuple[int, ...]
    offset: int
    elems: int


@dataclasses.dataclass(frozen=True)
class ChainedPlan:
    """A sequence of StreamPlans fused over one shared iteration space.

    ``stages[k]`` is the per-stage plan with the link refs *stripped* (the
    producer no longer stores its output, the consumer no longer loads it);
    ``links[k]`` records the unified intermediate between stages k and k+1.
    The cost fields extend Eq. (1)/(2):

    * ``n_chain``   — one fused stream region: a single setup, the union of
      the surviving lanes, the sum of all stage bodies;
    * ``n_unfused`` — Σ over stages of the stand-alone Eq. (1) count, each
      with its own setup and its intermediate store/load lane;
    * ``eliminated_loads``/``eliminated_stores`` — the intermediate memory
      accesses that simply never happen (ΠL each per link) — the chaining
      paper's headline quantity, invisible to pure instruction counts on a
      machine where streamed accesses are free but bandwidth is not.
    """

    stages: Tuple[StreamPlan, ...]
    links: Tuple[ChainLink, ...]
    bounds: Tuple[int, ...]
    n_chain: int
    n_unfused: int
    eliminated_loads: int
    eliminated_stores: int

    @property
    def eliminated_accesses(self) -> int:
        return self.eliminated_loads + self.eliminated_stores

    @property
    def chain_speedup(self) -> float:
        """Instruction-count speedup of the fused region vs the sequence."""
        return self.n_unfused / self.n_chain

    @property
    def num_lanes(self) -> int:
        return sum(len(s.allocations) for s in self.stages)


def _dense_strides(bounds: Sequence[int]) -> Tuple[int, ...]:
    strides = [1] * len(bounds)
    for k in range(len(bounds) - 2, -1, -1):
        strides[k] = strides[k + 1] * bounds[k + 1]
    return tuple(strides)


def _stage_instr_counts(plan: StreamPlan) -> List[int]:
    """Per-level body instruction counts with residual accesses folded in."""
    return nest_analysis.instr_counts(plan.nest, plan.residual)


def _unify_walk(name: str, w: MemRef, r: MemRef) -> None:
    """Raise ChainError unless the producer's write walk and the consumer's
    read walk of ``name`` are the same affine address sequence — the
    condition under which the store and the load cancel."""
    if w.coeffs is None or r.coeffs is None:
        raise ChainError(
            f"intermediate '{name}' is not affine on both sides")
    if w.coeffs != r.coeffs or w.offset != r.offset:
        raise ChainError(
            f"intermediate '{name}': producer walk "
            f"{w.coeffs}+{w.offset} != consumer walk "
            f"{r.coeffs}+{r.offset}; streams cannot be unified")


def _fused_region_count(stages: Sequence[StreamPlan],
                        bounds: Sequence[int]) -> int:
    """Eq. (1) for one fused stream region: a single setup over the union
    of the surviving lanes, per-level bodies summed across stages."""
    L = list(bounds)
    I = [0] * len(bounds)
    for plan in stages:
        for lvl, c in enumerate(_stage_instr_counts(plan)):
            I[lvl] += c
    s = sum(len(p.allocations) for p in stages)
    return isa.n_ssr(L, I, s) if s else isa.n_base(L, I, 0)


def chain(nests: Sequence[LoopNest], *,
          num_lanes: Optional[int] = None,
          force: bool = False) -> ChainedPlan:
    """Fuse a producer→consumer sequence of nests into one ChainedPlan.

    Adjacent nests are unified through exactly one intermediate ref: the
    producer's WRITE and the consumer's READ of the same name, with equal
    affine coefficients and offset, over identical iteration spaces.  The
    link refs are stripped and each stage is SSR-ified independently
    (``num_lanes=None`` allocates every affine ref, the ``ssr_call``
    convention); the cost model charges one fused setup and credits the
    eliminated intermediate traffic.
    """
    nests = tuple(nests)
    if len(nests) < 2:
        raise ChainError("chaining needs at least two nests")
    bounds = nests[0].bounds
    for k, nest in enumerate(nests[1:], start=1):
        if nest.bounds != bounds:
            raise ChainError(
                f"stage {k} iteration space {nest.bounds} != stage 0 "
                f"{bounds}; chained nests must share one iteration space")

    links: List[ChainLink] = []
    for k in range(len(nests) - 1):
        p, c = nests[k], nests[k + 1]
        writes = {r.name: r for r in p.refs if r.kind == Direction.WRITE}
        reads = {r.name: r for r in c.refs if r.kind == Direction.READ}
        common = sorted(set(writes) & set(reads))
        if len(common) != 1:
            raise ChainError(
                f"stages {k}→{k + 1}: need exactly one producer-write / "
                f"consumer-read ref in common, found {common or 'none'}")
        w, r = writes[common[0]], reads[common[0]]
        _unify_walk(common[0], w, r)
        links.append(ChainLink(name=common[0], producer_stage=k,
                               coeffs=w.coeffs, offset=w.offset,
                               elems=math.prod(bounds)))

    # Strip the unified refs: the producer's store and the consumer's load
    # vanish — that is the fusion.
    stage_nests: List[LoopNest] = []
    for k, nest in enumerate(nests):
        incoming = links[k - 1].name if k > 0 else None
        outgoing = links[k].name if k < len(nests) - 1 else None
        refs = tuple(
            r for r in nest.refs
            if not (r.name == incoming and r.kind == Direction.READ)
            and not (r.name == outgoing and r.kind == Direction.WRITE))
        stage_nests.append(dataclasses.replace(nest, refs=refs))

    stages = tuple(
        ssrify(sn, num_lanes=nest_analysis.auto_lanes(sn, num_lanes),
               force=force)
        for sn in stage_nests)

    # Unfused cost: each original nest as its own stream region (its link
    # ref occupies a lane and its setup is paid per stage).
    unfused_plans = [
        ssrify(n, num_lanes=nest_analysis.auto_lanes(n, num_lanes),
               force=force)
        for n in nests]
    n_unfused = sum(
        p.n_ssr if p.ssrified else p.n_base for p in unfused_plans)

    # Fused cost: one setup over the union of surviving lanes; the body at
    # each level is the sum of every stage's body (+ residual accesses).
    n_chain = _fused_region_count(stages, bounds)

    elems = sum(link.elems for link in links)
    return ChainedPlan(stages=stages, links=tuple(links), bounds=bounds,
                       n_chain=n_chain, n_unfused=n_unfused,
                       eliminated_loads=elems, eliminated_stores=elems)


# --------------------------------------------------------------------------
# Chain DAGs: whole-program fusion beyond linear pipelines.
#
# Production dataflow — layernorm, softmax cross-entropy, MLP blocks — is
# not a pipeline: one produced value feeds *several* consumers (diamonds,
# residual adds).  The scalar-chaining follow-up (arXiv 2503.20609) shows
# register chaining generalizes to arbitrary DAGs; our block-granular
# analogue lifts ChainedPlan to a ChainDAG whose edges each record one
# producer-WRITE → consumer-READ unification.  A multi-consumer
# intermediate is written to VMEM scratch once and read K times, so the
# accounting credits ONE eliminated store and K eliminated loads — the
# refcount the lowering uses to free the scratch slot after its last
# consumer.  ChainedPlan remains the linear special case (exactly one
# consumer per edge, consumer == producer + 1) and keeps its own
# entry point so linear-chain behavior is unchanged.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DagEdge:
    """One producer→consumer dataflow edge of a :class:`ChainDAG`.

    Like :class:`ChainLink` plus an explicit ``consumer_stage`` — in a DAG
    the consumer is no longer implied by ``producer_stage + 1``, and one
    producer may appear in several edges (multi-consumer intermediate).
    """

    name: str
    producer_stage: int
    consumer_stage: int
    coeffs: Tuple[int, ...]
    offset: int
    elems: int


@dataclasses.dataclass(frozen=True)
class ChainDAG:
    """Stages fused over one iteration space along an arbitrary DAG.

    ``stages[k]`` is the per-stage plan with every edge ref stripped (a
    produced value is stored by no one, loaded by no one); ``edges`` are
    the unified intermediates in deterministic ``(consumer, producer,
    name)`` order.  Stage order is topological by construction (an edge
    always points forward).  The cost fields mirror :class:`ChainedPlan`:

    * ``n_dag``     — ONE fused stream region (single setup, union of
      surviving lanes, bodies summed);
    * ``n_unfused`` — Σ of stand-alone per-stage counts, each paying its
      own setup and its intermediate store/load lanes;
    * ``eliminated_stores`` — ΠL per *distinct* intermediate (written
      once no matter how many consumers);
    * ``eliminated_loads``  — ΠL per *edge* (each consumer's load is a
      separate eliminated access — the multi-consumer credit).
    """

    stages: Tuple[StreamPlan, ...]
    edges: Tuple[DagEdge, ...]
    bounds: Tuple[int, ...]
    n_dag: int
    n_unfused: int
    eliminated_loads: int
    eliminated_stores: int

    @property
    def links(self) -> Tuple[DagEdge, ...]:
        """Lowering-compatible view: the edges are the chain's links."""
        return self.edges

    @property
    def eliminated_accesses(self) -> int:
        return self.eliminated_loads + self.eliminated_stores

    @property
    def dag_speedup(self) -> float:
        """Instruction-count speedup of the fused region vs the sequence."""
        return self.n_unfused / self.n_dag

    @property
    def num_lanes(self) -> int:
        return sum(len(s.allocations) for s in self.stages)

    @property
    def intermediates(self) -> Tuple[str, ...]:
        """Distinct produced names, in producer-stage order."""
        seen: List[str] = []
        for e in sorted(self.edges, key=lambda e: (e.producer_stage, e.name)):
            if e.name not in seen:
                seen.append(e.name)
        return tuple(seen)

    def in_edges(self, stage: int) -> Tuple[DagEdge, ...]:
        """Incoming edges of ``stage`` in (producer, name) order — the
        order the stage's body receives its carried blocks."""
        return tuple(sorted((e for e in self.edges
                             if e.consumer_stage == stage),
                            key=lambda e: (e.producer_stage, e.name)))

    def out_edges(self, stage: int) -> Tuple[DagEdge, ...]:
        return tuple(sorted((e for e in self.edges
                             if e.producer_stage == stage),
                            key=lambda e: (e.consumer_stage, e.name)))

    def last_consumer(self, name: str) -> int:
        """The stage after which ``name``'s scratch slot is dead — the
        refcount-to-zero point the lowering frees at."""
        return max(e.consumer_stage for e in self.edges if e.name == name)


def chain_dag(nests: Sequence[LoopNest], *,
              num_lanes: Optional[int] = None,
              force: bool = False) -> ChainDAG:
    """Fuse a topologically-ordered sequence of nests into one ChainDAG.

    Dataflow is discovered by name: a ref WRITTEN by stage p and READ by a
    later stage c becomes an edge p→c (one write may feed many reads); a
    read name no earlier stage writes stays an external operand stream.
    The same loud :class:`ChainError` failures as :func:`chain` apply to
    still-illegal graphs — mismatched iteration spaces, non-affine or
    mismatched walks — plus the DAG-specific ones: a name written twice,
    a read before its write (the sequence must be topological), a
    disconnected stage, and more than one terminal stage (only the final
    stage's value may leave the fused region).
    """
    nests = tuple(nests)
    if len(nests) < 2:
        raise ChainError("chaining needs at least two nests")
    bounds = nests[0].bounds
    for k, nest in enumerate(nests[1:], start=1):
        if nest.bounds != bounds:
            raise ChainError(
                f"stage {k} iteration space {nest.bounds} != stage 0 "
                f"{bounds}; chained nests must share one iteration space")

    writers: dict = {}
    for k, nest in enumerate(nests):
        for r in nest.refs:
            if r.kind != Direction.WRITE:
                continue
            if r.name in writers:
                raise ChainError(
                    f"intermediate '{r.name}' is written by both stage "
                    f"{writers[r.name][0]} and stage {k}; each intermediate "
                    "needs exactly one producer")
            writers[r.name] = (k, r)

    edges: List[DagEdge] = []
    for k, nest in enumerate(nests):
        for r in nest.refs:
            if r.kind != Direction.READ or r.name not in writers:
                continue
            p, w = writers[r.name]
            if p >= k:
                raise ChainError(
                    f"stage {k} reads '{r.name}' which stage {p} has not "
                    "produced yet; stages must be listed in topological "
                    "order (producers before consumers)")
            _unify_walk(r.name, w, r)
            edges.append(DagEdge(name=r.name, producer_stage=p,
                                 consumer_stage=k, coeffs=w.coeffs,
                                 offset=w.offset, elems=math.prod(bounds)))
    edges.sort(key=lambda e: (e.consumer_stage, e.producer_stage, e.name))

    consumed = {e.name for e in edges}
    touched = {e.producer_stage for e in edges} \
        | {e.consumer_stage for e in edges}
    for k in range(len(nests)):
        if k not in touched:
            raise ChainError(
                f"stage {k} is disconnected from the dag: no produced "
                "value links it to any other stage")
    sinks = [k for k in range(len(nests))
             if not any(e.producer_stage == k for e in edges)]
    if sinks != [len(nests) - 1]:
        raise ChainError(
            f"stages {sinks} all terminate the dag; exactly one final "
            "stage (the last) may produce the fused region's output")
    for name, (k, _w) in writers.items():
        if name not in consumed:
            raise ChainError(
                f"stage {k} writes '{name}' but no later stage reads it; "
                "dead intermediates cannot leave the fused region")

    # Strip every unified ref: each produced name loses its single WRITE
    # and each of its consumer READs — the store and K loads all vanish.
    stage_nests: List[LoopNest] = []
    for k, nest in enumerate(nests):
        incoming = {e.name for e in edges if e.consumer_stage == k}
        outgoing = {e.name for e in edges if e.producer_stage == k}
        refs = tuple(
            r for r in nest.refs
            if not (r.name in incoming and r.kind == Direction.READ)
            and not (r.name in outgoing and r.kind == Direction.WRITE))
        stage_nests.append(dataclasses.replace(nest, refs=refs))

    stages = tuple(
        ssrify(sn, num_lanes=nest_analysis.auto_lanes(sn, num_lanes),
               force=force)
        for sn in stage_nests)

    unfused_plans = [
        ssrify(n, num_lanes=nest_analysis.auto_lanes(n, num_lanes),
               force=force)
        for n in nests]
    n_unfused = sum(
        p.n_ssr if p.ssrified else p.n_base for p in unfused_plans)
    n_dag = _fused_region_count(stages, bounds)

    elems = math.prod(bounds)
    return ChainDAG(stages=stages, edges=tuple(edges), bounds=bounds,
                    n_dag=n_dag, n_unfused=n_unfused,
                    eliminated_loads=elems * len(edges),
                    eliminated_stores=elems * len(consumed))


# --------------------------------------------------------------------------
# Cluster cost model: Eq. (1)–(3) extended to a C-core cluster (§5.3–5.5).
#
# The paper runs the kernels on an 8-core cluster sharing one TCDM and
# reports speedup-vs-cores (Fig. 10/11), near-100 % utilization, and the
# iso-performance claim that 3× fewer SSR cores match a baseline cluster.
# Here the model is made explicit: the outermost loop level is tiled across
# C cores (ceil tiles — the max core bounds the cluster, exactly Amdahl's
# straggler), each core pays its own Eq. (1) count including its own stream
# setup, and the combine is a log2-depth barrier/psum tree (the event-unit
# + shared-TCDM reduction).  η per cluster charges idle issue slots on
# underloaded cores, which is how the paper's single-core 3× decays toward
# 2.2× at six cores (§5.4).
# --------------------------------------------------------------------------

#: Instructions charged per stage of the combine tree: the §5.3 hardware
#: barrier (event-unit wait/wake) plus one partial-sum load+add+store.
COMBINE_COST = 16


@dataclasses.dataclass(frozen=True)
class CoreCost:
    """One core's share of a clustered nest, in Eq. (1) accounting.

    ``n`` is the executed-instruction count on this core (0 for cores left
    idle by a ragged split); on a single-issue core every executed
    instruction is also an instruction *fetch*, so ``fetches == n`` — the
    quantity behind the paper's 3.5× i-fetch reduction (§5.6).
    ``bytes_moved`` counts the unique elements this core's allocated
    streams pull from shared memory (repeat streams once), at 4 B/elem.
    """

    core: int
    bounds: Tuple[int, ...]
    n: int
    compute: int
    bytes_moved: int

    @property
    def eta(self) -> float:
        return self.compute / self.n if self.n else 0.0

    @property
    def fetches(self) -> int:
        return self.n


@dataclasses.dataclass(frozen=True)
class ClusterReport:
    """Eq. (1)–(3) extended to C cores (§5.3–5.5 / Fig. 10–11).

    ``n_single`` is the one-core streamed count; the cluster finishes when
    its slowest core does, plus the combine tree: ``n_cluster =
    max_c n_c + combine``.  ``speedup`` is therefore the architectural
    speedup-vs-cores curve, and ``eta_cluster`` the cluster-wide useful
    utilization (idle cores charged), the two §V quantities
    ``benchmarks/cluster_bench.py`` sweeps.
    """

    cores: int
    bounds: Tuple[int, ...]
    per_core: Tuple[CoreCost, ...]
    n_single: int
    n_base_single: int
    combine: int
    chained: bool = False
    eliminated_accesses: int = 0

    @property
    def n_cluster(self) -> int:
        return max(c.n for c in self.per_core) + self.combine

    @property
    def speedup(self) -> float:
        """Architectural speedup of the C-core cluster over one SSR core."""
        return self.n_single / self.n_cluster

    @property
    def speedup_vs_base(self) -> float:
        """Speedup over one *baseline* (no-SSR) core — the Fig. 11 axis."""
        return self.n_base_single / self.n_cluster

    @property
    def eta_cluster(self) -> float:
        total_compute = sum(c.compute for c in self.per_core)
        return total_compute / (self.cores * self.n_cluster)

    @property
    def total_fetches(self) -> int:
        return sum(c.fetches for c in self.per_core) \
            + self.cores * self.combine

    @property
    def bytes_moved(self) -> int:
        return sum(c.bytes_moved for c in self.per_core)


_nest_compute = nest_analysis.nest_compute


def _plan_bytes(plan: StreamPlan, itemsize: int = 4) -> int:
    """Unique streamed elements across the plan's lanes, in bytes."""
    total = 0
    for a in plan.allocations:
        elems = 1
        for b, c in zip(plan.nest.bounds, a.ref.coeffs):
            if c != 0:
                elems *= b
        total += elems * itemsize
    return total


def _tile_extents(b0: int, cores: int) -> List[int]:
    """Ceil-tile split of the outer bound: the max tile bounds the cluster."""
    tile = -(-b0 // cores)
    return [max(0, min(tile, b0 - c * tile)) for c in range(cores)]


def _combine_instrs(cores: int, combine_cost: int) -> int:
    return combine_cost * (cores - 1).bit_length() if cores > 1 else 0


_auto_lanes = nest_analysis.auto_lanes


def cluster_cost(nests, cores: int, *,
                 num_lanes: Optional[int] = None,
                 combine_cost: int = COMBINE_COST) -> ClusterReport:
    """Cost a nest (or producer→consumer chain) on a C-core cluster.

    Accepts a single :class:`LoopNest` or a chainable sequence (routed
    through :func:`chain` per core, so chained intermediates stay core-
    local and their eliminated accesses scale with the split).  The split
    is ceil-tiled on the outermost level — no divisibility requirement
    here, unlike the execution layer, because the *model's* cluster time is
    set by the largest tile either way.
    """
    if cores < 1:
        raise ValueError(f"cores must be >= 1, got {cores}")
    single = isinstance(nests, LoopNest)
    seq: Tuple[LoopNest, ...] = (nests,) if single else tuple(nests)
    bounds = seq[0].bounds
    extents = _tile_extents(bounds[0], cores)

    def sub_nests(e: int) -> Tuple[LoopNest, ...]:
        return tuple(dataclasses.replace(n, bounds=(e,) + n.bounds[1:])
                     for n in seq)

    if single:
        lanes = _auto_lanes(seq[0], num_lanes)
        full = ssrify(seq[0], num_lanes=lanes, force=True)
        n_single, n_base_single = full.n_ssr, full.n_base
    else:
        full_chain = chain(seq, num_lanes=num_lanes, force=True)
        n_single = full_chain.n_chain
        n_base_single = sum(
            ssrify(n, num_lanes=_auto_lanes(n, num_lanes)).n_base
            for n in seq)

    per_core: List[CoreCost] = []
    eliminated = 0
    for c, e in enumerate(extents):
        if e == 0:
            per_core.append(CoreCost(core=c, bounds=(0,) + bounds[1:],
                                     n=0, compute=0, bytes_moved=0))
            continue
        subs = sub_nests(e)
        if single:
            plan = ssrify(subs[0], num_lanes=_auto_lanes(subs[0], num_lanes),
                          force=True)
            n = plan.n_ssr
            comp = _nest_compute(subs[0])
            nbytes = _plan_bytes(plan)
        else:
            cp = chain(subs, num_lanes=num_lanes, force=True)
            n = cp.n_chain
            comp = sum(_nest_compute(s) for s in subs)
            nbytes = sum(_plan_bytes(p) for p in cp.stages)
            eliminated += cp.eliminated_accesses
        per_core.append(CoreCost(core=c, bounds=subs[0].bounds, n=n,
                                 compute=comp, bytes_moved=nbytes))

    return ClusterReport(cores=cores, bounds=bounds,
                         per_core=tuple(per_core),
                         n_single=n_single, n_base_single=n_base_single,
                         combine=_combine_instrs(cores, combine_cost),
                         chained=not single,
                         eliminated_accesses=eliminated)


def iso_performance_cores(nests, baseline_cores: int, *,
                          num_lanes: Optional[int] = None,
                          combine_cost: int = COMBINE_COST,
                          max_cores: int = 64) -> int:
    """Smallest SSR-core count matching a C-core *baseline* cluster.

    The §5.5/Fig. 11 claim — "3x fewer cores are needed in a cluster to
    achieve the same performance" — replayed on the explicit per-core
    model: the baseline cluster runs Eq. (2) counts (explicit loads in the
    hot loop) on each tile; we grow the SSR cluster until its ``n_cluster``
    is no worse.
    """
    single = isinstance(nests, LoopNest)
    seq: Tuple[LoopNest, ...] = (nests,) if single else tuple(nests)
    extents = _tile_extents(seq[0].bounds[0], baseline_cores)
    worst = 0
    for e in extents:
        if e == 0:
            continue
        n_b = 0
        for nest in seq:
            sub = dataclasses.replace(nest, bounds=(e,) + nest.bounds[1:])
            n_b += ssrify(sub, num_lanes=_auto_lanes(sub, num_lanes)).n_base
        worst = max(worst, n_b)
    target = worst + _combine_instrs(baseline_cores, combine_cost)
    for c in range(1, max_cores + 1):
        rep = cluster_cost(nests if single else seq, c,
                           num_lanes=num_lanes, combine_cost=combine_cost)
        if rep.n_cluster <= target:
            return c
    raise ValueError(
        f"no SSR cluster of <= {max_cores} cores matches {baseline_cores} "
        "baseline cores — combine overhead dominates this nest")


def dot_product_nest(n: int) -> LoopNest:
    """The running example (Fig. 4): sum += A[i]*B[i]."""
    return LoopNest(
        bounds=(n,),
        refs=(MemRef("A", Direction.READ, (1,)),
              MemRef("B", Direction.READ, (1,))),
        compute_per_level=(1,),
    )


def elementwise_nest(n: int, names: Sequence[str] = ("X",),
                     compute: int = 1) -> LoopNest:
    """1-D map nest: one unit-stride read stream per operand name."""
    return LoopNest(
        bounds=(n,),
        refs=tuple(MemRef(nm, Direction.READ, (1,)) for nm in names),
        compute_per_level=(compute,),
    )


def stencil_nest(n: int, taps: int) -> LoopNest:
    """Executable nest for the 1-D star stencil: y[i] = Σ_j w[j]·x[i+j].

    ``x`` is a *windowed* READ — its unit-stride walk revisits
    ``taps - 1`` neighbours per step (the halo), which ``lower_nest``
    serves with a +1-shifted twin stream and in-kernel slice taps
    (DESIGN.md §13; the §2.3 second-AGU trick at block granularity).
    ``w`` rides as a loop-invariant coefficient block (repeat register);
    the operand ``x`` carries the widened ``n + taps - 1`` logical extent.
    Shared by ``kernels/stencil.py``, ``kernel_bench`` and
    ``cluster_bench`` as both the execution schedule and the Eq. (1)–(3)
    accounting.
    """
    return LoopNest(
        bounds=(n,),
        refs=(MemRef("x", Direction.READ, (1,), window=(taps,)),
              MemRef("w", Direction.READ, (0,)),
              MemRef("y", Direction.WRITE, (1,))),
        compute_per_level=(taps,),
    )


def stencil2d_nest(h: int, w: int, taps: int) -> LoopNest:
    """Executable nest for the 2-D cross stencil (kernels/stencil.py).

    ``x`` reads a ``taps × taps`` neighbourhood around each (i, j) — a
    halo window on *both* levels, so the lowering emits 4 shifted streams
    (2**k for k halo'd levels) and stitches the widened block in-kernel.
    The operand is the padded ``(h + taps - 1, w + taps - 1)`` grid; its
    row pitch is the widened width, hence the ``w + taps - 1`` row
    coefficient.  ``wx``/``wy`` are the invariant tap coefficients.
    """
    return LoopNest(
        bounds=(h, w),
        refs=(MemRef("x", Direction.READ, (w + taps - 1, 1),
                     window=(taps, taps)),
              MemRef("wx", Direction.READ, (0, 0)),
              MemRef("wy", Direction.READ, (0, 0)),
              MemRef("y", Direction.WRITE, (w, 1))),
        compute_per_level=(0, 2 * taps),
    )


def attention_nest(sq: int, sk: int, d: int) -> LoopNest:
    """Executable nest for O[q,:] = softmax(Q·Kᵀ·scale)·V (flash form).

    Loop order (q, d, k): K/V walk the contraction level k with row
    pitch d (storage order (k, d), a permutation — GEMM's B pattern); Q
    repeats across k (§2.3 repeat register); O revisits each (q, d)
    block across the whole k walk with ``acc_kind="online_softmax"`` —
    ``lower_nest`` carries the flash-attention (max, sum, acc) triple in
    VMEM and rescales on every k step (DESIGN.md §13).  The body owns
    the score scaling and masking; the kernel owns the recurrence.
    """
    return LoopNest(
        bounds=(sq, d, sk),
        refs=(MemRef("K", Direction.READ, (0, 1, d)),
              MemRef("V", Direction.READ, (0, 1, d)),
              MemRef("Q", Direction.READ, (d, 1, 0)),
              MemRef("O", Direction.WRITE, (d, 1, 0),
                     acc_kind="online_softmax")),
        compute_per_level=(0, 0, 2),
    )


def gemv_nest(m: int, n: int) -> LoopNest:
    """Executable nest for y[m] = A[m,n]·x[n] (kernels/gemv.py).

    A walks both loops dense (row-major), x repeats across rows (the §2.3
    repeat register — coefficient 0 on the m level), y writes once per
    row and is revisited across the n walk, so ``lower_nest`` carries a
    VMEM accumulator across the contraction — the standard level-mapped
    path (no waiver); the autotuner searches its full block geometry.
    """
    return LoopNest(
        bounds=(m, n),
        refs=(MemRef("A", Direction.READ, (n, 1)),
              MemRef("x", Direction.READ, (0, 1)),   # repeated per row
              MemRef("y", Direction.WRITE, (1, 0))),
        compute_per_level=(0, 1),
    )


def gemm_nest(m: int, n: int, k: int) -> LoopNest:
    """C[m,n] += A[m,k]·B[k,n] — 3-deep, with A reused across n (repeat).

    The full §3.2 pattern, write side included: C's coefficient is 0 on the
    contraction level (k), so the output address is *revisited* across the
    whole inner loop — the lowering turns that into a VMEM accumulator that
    initialises on the first k step and drains on the last (see
    ``lowering.lower_nest``).  B walks the innermost loop with stride n
    (its storage order is (k, n), a permutation of the loop order) — fine
    for the word-granular AGU and for the level-mapped block lowering,
    not for the flattened 1-D schedule of ``lower_plan``.
    """
    return LoopNest(
        bounds=(m, n, k),
        refs=(
            MemRef("A", Direction.READ, (k, 0, 1)),   # varies with m,k; reused over n
            MemRef("B", Direction.READ, (0, 1, n)),   # varies with n,k
            MemRef("C", Direction.WRITE, (n, 1, 0)),  # revisited across k
        ),
        # fmadd inner only: C's writeback is the explicit WRITE ref above —
        # charged as a residual store when it has no lane, free when streamed
        compute_per_level=(0, 0, 1),
    )


def spmv_nest(m: int, k: int) -> LoopNest:
    """y[m] = Σ_j vals[i,j] · x[cidx[i,j]] over an ELL-packed CSR matrix.

    ``k`` is the row capacity (max nnz per row after ELL padding): vals and
    cidx walk the packed (m, k) arrays dense, x is the *gather* — an
    indirect ref whose addresses are the column indices streaming out of
    cidx (arXiv 2011.08070's index stream feeding the address stage).  y is
    revisited across j, so the lowering accumulates.  This is also the
    sparse-row generalisation of :func:`gemv_nest`: set cidx = iota and it
    degenerates to the dense row walk.
    """
    return LoopNest(
        bounds=(m, k),
        refs=(
            MemRef("vals", Direction.READ, (k, 1)),
            MemRef("cidx", Direction.READ, (k, 1)),
            MemRef("x", Direction.READ, (0, 0), index_of="cidx"),
            MemRef("y", Direction.WRITE, (1, 0)),     # revisited across j
        ),
        compute_per_level=(0, 1),
    )


def spmm_nest(m: int, c: int, k: int, pitch: int) -> LoopNest:
    """Y[m,c] = Σ_j vals[i,j] · X[cidx[i,j], c] — CSR × dense (SpMM).

    Loop order (i, c, j) keeps the contraction innermost so the lowering's
    accumulator rule applies.  vals/cidx repeat across the dense column
    loop c (coefficient 0 — the §2.3 repeat register); X is the indirect
    ref: its base address is ``pitch·cidx[i,j]`` (``pitch`` = padded row
    pitch of the flattened X table) plus the affine column walk ``c``.
    """
    return LoopNest(
        bounds=(m, c, k),
        refs=(
            MemRef("vals", Direction.READ, (k, 0, 1)),
            MemRef("cidx", Direction.READ, (k, 0, 1)),
            MemRef("X", Direction.READ, (0, 1, 0),
                   index_of="cidx", index_scale=pitch),
            MemRef("Y", Direction.WRITE, (c, 1, 0)),  # revisited across j
        ),
        compute_per_level=(0, 0, 1),
    )
