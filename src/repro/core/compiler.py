"""The §3.2 "SSR-ification" compiler pass, ported from LLVM MIR to a loop IR.

The paper's pass runs after instruction selection and before register
allocation: it (1) finds loops, (2) pattern-matches affine load/store address
expressions, (3) allocates candidates to the available data movers
*deepest-first*, (4) emits stream configuration before the loop header,
(5) replaces the memory ops with stream-register uses, and (6) blocks the
stream registers during register allocation.

Our input "MIR" is a :class:`LoopNest` of affine :class:`MemRef` accesses plus
a compute-op count — the information the MIR pattern-match extracts.  The
output :class:`StreamPlan` carries the allocated :class:`StreamSpec` per lane,
the residual (non-SSRable) accesses, and the Eq. (1)–(3) cost verdict, and can
be lowered straight to ``ssr_pallas`` streams.  The paper's caveat that "not
every loop benefits from SSRs" is the Eq. (3) test, applied per nest exactly
as §3.2 recommends ("at compile time based on the expected number of
iterations").
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from . import isa
from .stream import Direction, StreamSpec, MAX_DIMS

DEFAULT_NUM_LANES = 2  # the implementation in the paper has two data movers


@dataclasses.dataclass(frozen=True)
class MemRef:
    """One load/store whose address is affine in the loop indices.

    ``coeffs[k]`` multiplies loop index ``k`` (outermost first); accesses with
    a non-affine address are represented by ``coeffs=None`` and are never
    SSR-ified (the MIR pattern-match fails — §3.2 step 2).
    """

    name: str
    kind: Direction
    coeffs: Optional[Tuple[int, ...]]  # None => not affine
    offset: int = 0
    depth: Optional[int] = None  # innermost loop level the access lives in

    def is_affine(self) -> bool:
        return self.coeffs is not None


@dataclasses.dataclass(frozen=True)
class LoopNest:
    """A perfect loop nest with known bounds (outermost first)."""

    bounds: Tuple[int, ...]
    refs: Tuple[MemRef, ...]
    compute_per_level: Tuple[int, ...]  # useful ops per body, per level

    def __post_init__(self) -> None:
        if len(self.bounds) > MAX_DIMS:
            raise ValueError(
                f"nest depth {len(self.bounds)} exceeds AGU dims ({MAX_DIMS}); "
                "outer levels must stay in software (paper §3.1)"
            )
        if len(self.compute_per_level) != len(self.bounds):
            raise ValueError("compute_per_level must match nest depth")


@dataclasses.dataclass(frozen=True)
class Allocation:
    lane: int
    ref: MemRef
    spec: StreamSpec


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    nest: LoopNest
    allocations: Tuple[Allocation, ...]
    residual: Tuple[MemRef, ...]   # accesses that stay as explicit loads/stores
    ssrified: bool                 # Eq. (3) verdict (False => emit baseline)
    n_ssr: int
    n_base: int

    @property
    def speedup(self) -> float:
        return self.n_base / self.n_ssr if self.ssrified else 1.0


def _ref_depth(ref: MemRef, nest: LoopNest) -> int:
    """Deepest loop level whose index the address actually varies with."""
    if ref.depth is not None:
        return ref.depth
    if not ref.is_affine():
        return -1
    depth = 0
    for k, c in enumerate(ref.coeffs):
        if c != 0:
            depth = k
    return depth


def _to_spec(ref: MemRef, nest: LoopNest) -> StreamSpec:
    """Build the AGU configuration for an affine access in this nest.

    Loop levels whose coefficient is zero become ``repeat`` (read streams:
    the same datum re-emitted — the paper's repeat register) when they are
    innermost, or bound-1 dims otherwise.
    """
    assert ref.coeffs is not None
    bounds: List[int] = []
    strides: List[int] = []
    repeat = 1
    # walk from outermost; trailing zero-coeff levels of a read stream fold
    # into the repeat register.
    coeffs = list(ref.coeffs)
    trailing_zero = 0
    for c in reversed(coeffs):
        if c == 0:
            trailing_zero += 1
        else:
            break
    if ref.kind == Direction.READ and trailing_zero:
        for lvl in range(len(coeffs) - trailing_zero, len(coeffs)):
            repeat *= nest.bounds[lvl]
        coeffs = coeffs[: len(coeffs) - trailing_zero]
    for lvl, c in enumerate(coeffs):
        bounds.append(nest.bounds[lvl])
        strides.append(c)
    if not bounds:  # scalar (loop-invariant) access
        bounds, strides = [1], [0]
    return StreamSpec(bounds=tuple(bounds), strides=tuple(strides),
                      base=ref.offset, repeat=repeat, direction=ref.kind)


def ssrify(nest: LoopNest, *, num_lanes: int = DEFAULT_NUM_LANES,
           force: bool = False) -> StreamPlan:
    """Run the pass: allocate streams deepest-first, then apply Eq. (3).

    ``force=True`` skips the profitability test (the paper's "runtime
    decision" path where both variants exist and the caller knows N).
    """
    candidates = [r for r in nest.refs if r.is_affine()]
    residual = [r for r in nest.refs if not r.is_affine()]
    # §3.2 step 3: deepest-first — a simple heuristic for iteration count.
    candidates.sort(key=lambda r: _ref_depth(r, nest), reverse=True)
    allocations: List[Allocation] = []
    for ref in candidates:
        if len(allocations) < num_lanes:
            allocations.append(
                Allocation(lane=len(allocations), ref=ref,
                           spec=_to_spec(ref, nest)))
        else:
            residual.append(ref)

    d = len(nest.bounds)
    s = len(allocations)
    L = list(nest.bounds)
    # Residual explicit memory ops stay in the body at their depth: fold them
    # into per-level instruction counts for the cost model.
    I_ssr = list(nest.compute_per_level)
    I_base = list(nest.compute_per_level)
    for ref in residual:
        lvl = max(0, _ref_depth(ref, nest))
        I_ssr[lvl] += 1
        I_base[lvl] += 1
    n_with = isa.n_ssr(L, I_ssr, max(s, 1)) if s else isa.n_base(L, I_base, 0)
    n_without = isa.n_base(L, I_base, s)
    # force=True is the paper's "runtime decision" path: both variants are
    # compiled and the caller elects SSR regardless of the static verdict.
    profitable = bool(s) and (
        force or (isa.ssr_profitable(L) and n_with <= n_without))
    if not profitable:
        return StreamPlan(nest=nest, allocations=(), residual=tuple(nest.refs),
                          ssrified=False, n_ssr=n_without, n_base=n_without)
    return StreamPlan(nest=nest, allocations=tuple(allocations),
                      residual=tuple(residual), ssrified=True,
                      n_ssr=n_with, n_base=n_without)


def dot_product_nest(n: int) -> LoopNest:
    """The running example (Fig. 4): sum += A[i]*B[i]."""
    return LoopNest(
        bounds=(n,),
        refs=(MemRef("A", Direction.READ, (1,)),
              MemRef("B", Direction.READ, (1,))),
        compute_per_level=(1,),
    )


def gemm_nest(m: int, n: int, k: int) -> LoopNest:
    """C[m,n] += A[m,k]·B[k,n] — 3-deep, with A reused across n (repeat)."""
    return LoopNest(
        bounds=(m, n, k),
        refs=(
            MemRef("A", Direction.READ, (k, 0, 1)),   # varies with m,k; reused over n
            MemRef("B", Direction.READ, (0, 1, n)),   # varies with n,k
        ),
        compute_per_level=(0, 1, 1),  # C init/writeback at n-level, fmadd inner
    )
