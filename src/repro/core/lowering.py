"""Lower compiler :class:`StreamPlan`\\ s to executable Pallas kernels.

This module closes the §3.2 loop that the paper's LLVM pass closes in MIR:

    LoopNest ──ssrify()──► StreamPlan ──lower_plan()──► (grid, BlockStreams)
                                                         │
                              ssr_call() ◄───────────────┘  → ssr_pallas()

``ssrify`` allocates affine accesses to data-mover lanes and renders the
Eq. (3) verdict; *nothing* in the seed tree executed that plan.  Here each
allocated :class:`StreamSpec` becomes a Pallas ``grid`` + affine ``index_map``
(the AGU at block granularity, derived via :func:`agu.block_grid`), and
:func:`ssr_call` runs the whole pipeline end to end: feed it a nest, a block
body, and the operand arrays, and the loop executes as a streamed Pallas
kernel whose operand delivery *is* the plan's AGU schedule.

Lowerable patterns (the TPU block-granularity subset of the AGU model):

* unit-stride innermost walk (``coeffs[-1] == 1``) with *dense row-major*
  outer levels — each grid step consumes one whole VMEM block;
* levels with coefficient 0 — the index_map ignores that grid axis, so the
  pipeline revisits the block: the paper's **repeat register**;
* fully loop-invariant operands — a single block served to every step.

Anything else (e.g. a strided column walk, expressible by the word-granular
hardware AGU but not by whole-block DMA) raises :class:`LoweringError`; those
kernels keep their hand-scheduled 2-D block layouts under ``repro.kernels``.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import agu
from .compiler import Allocation, LoopNest, StreamPlan, ssrify
from .ssr import BlockStream, ssr_pallas
from .stream import Direction, StreamSpec


class LoweringError(ValueError):
    """The plan's access pattern has no whole-block Pallas schedule."""


@dataclasses.dataclass(frozen=True)
class BlockPolicy:
    """How element streams are blocked into VMEM tiles.

    A TPU "word" for streaming purposes is one (rows × lanes) tile; the
    policy is the stream's element width in the §2 correspondence.
    """

    rows: int = 8
    lanes: int = 128

    @property
    def block_elems(self) -> int:
        return self.rows * self.lanes

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.rows, self.lanes)


DEFAULT_POLICY = BlockPolicy()


@dataclasses.dataclass(frozen=True)
class LoweredStream:
    """One allocation lowered to block granularity.

    ``logical_shape`` is the operand view the lowering expects *before*
    padding (``None`` = take the array as-is, e.g. loop-invariant streams);
    ``prepare`` turns the user's flat/logical array into the 2-D padded
    layout whose row-blocks the ``index_map`` addresses.
    """

    name: str
    stream: BlockStream
    spec: StreamSpec                       # compiler allocation, for oracles
    logical_shape: Optional[Tuple[int, ...]]
    padded_last: int                       # innermost extent after padding
    policy: BlockPolicy
    offset: int = 0                        # base-pointer shift (AGU `base`)

    def prepare(self, arr: jax.Array) -> jax.Array:
        """Pad + reshape ``arr`` into the (rows, lanes) layout streamed."""
        lanes = self.policy.lanes
        if self.logical_shape is None:      # loop-invariant: one block
            # The AGU base pointer shifts the view: element 0 of the block
            # is data[offset], exactly what the spec's repeat walk emits.
            flat = arr.reshape(-1)[self.offset:]
            pad = (-flat.shape[0]) % lanes if flat.shape[0] else lanes
            if pad:
                flat = jnp.pad(flat, (0, pad))
            return flat.reshape(-1, lanes)
        want = math.prod(self.logical_shape)
        flat = arr.reshape(-1)
        if flat.shape[0] != want:
            raise ValueError(
                f"stream '{self.name}': operand has {flat.shape[0]} elements, "
                f"plan expects logical shape {self.logical_shape}")
        view = flat.reshape(self.logical_shape)
        pad = self.padded_last - self.logical_shape[-1]
        if pad:
            view = jnp.pad(view, [(0, 0)] * (view.ndim - 1) + [(0, pad)])
        return view.reshape(-1, lanes)


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """A StreamPlan turned into a launchable Pallas schedule."""

    plan: StreamPlan
    policy: BlockPolicy
    grid: Tuple[int, ...]
    in_streams: Tuple[LoweredStream, ...]
    out_streams: Tuple[LoweredStream, ...]

    @property
    def steps(self) -> int:
        return math.prod(self.grid)


def _inner_steps(nest: LoopNest, policy: BlockPolicy) -> int:
    return -(-nest.bounds[-1] // policy.block_elems)


def _lower_allocation(alloc: Allocation, nest: LoopNest,
                      policy: BlockPolicy) -> LoweredStream:
    """Turn one lane's affine access into a BlockStream over row-blocks."""
    coeffs = alloc.ref.coeffs
    assert coeffs is not None  # only affine refs are ever allocated
    d = len(nest.bounds)
    E = policy.block_elems
    steps_inner = _inner_steps(nest, policy)
    padded_inner = steps_inner * E

    varying = [k for k, c in enumerate(coeffs) if c != 0]
    if not varying:
        # Loop-invariant operand: one block revisited by every grid step —
        # the repeat register driven to its limit.
        def invariant_map(*_g):
            return (0, 0)

        return LoweredStream(
            name=alloc.ref.name,
            stream=BlockStream(block_shape=(1, policy.lanes),
                               index_map=invariant_map,
                               direction=alloc.ref.kind,
                               name=alloc.ref.name),
            spec=alloc.spec, logical_shape=None, padded_last=policy.lanes,
            policy=policy, offset=alloc.ref.offset)

    if varying[-1] != d - 1 or coeffs[d - 1] != 1:
        raise LoweringError(
            f"stream '{alloc.ref.name}': innermost coefficient "
            f"{coeffs[d - 1]} is not a unit-stride walk of the innermost "
            "loop — the word-granular AGU supports it, whole-block DMA does "
            "not; use a hand-scheduled 2-D block kernel")

    # Dense row-major check for the outer varying levels: each coefficient
    # must equal the extent-product of the faster-varying levels, so the
    # operand is a plain (L_a, …, L_inner) array we can pad on its last dim.
    extents: Dict[int, int] = {d - 1: nest.bounds[d - 1]}
    expect = nest.bounds[d - 1]
    for k in reversed(varying[:-1]):
        if coeffs[k] != expect:
            raise LoweringError(
                f"stream '{alloc.ref.name}': level-{k} coefficient "
                f"{coeffs[k]} != dense row-major stride {expect}; the "
                "operand layout is not a contiguous array over its varying "
                "loops")
        extents[k] = nest.bounds[k]
        expect *= nest.bounds[k]
    if alloc.ref.offset % E:
        raise LoweringError(
            f"stream '{alloc.ref.name}': base offset {alloc.ref.offset} is "
            f"not block-aligned (block = {E} elements)")

    logical_shape = tuple(nest.bounds[k] for k in varying)
    # Padded flat stride of each varying outer level (innermost padded to a
    # whole number of blocks), expressed in *row-blocks*.
    base_block = alloc.ref.offset // E
    block_coeff: Dict[int, int] = {}
    stride_blocks = steps_inner
    for k in reversed(varying[:-1]):
        block_coeff[k] = stride_blocks
        stride_blocks *= nest.bounds[k]

    def index_map(*g):
        # grid axes = (outer nest levels …, tiled innermost); levels with a
        # zero coefficient simply don't appear — Pallas sees an unchanged
        # index and skips the re-fetch (repeat register).
        row = base_block + g[d - 1]
        for k, bc in block_coeff.items():
            row = row + g[k] * bc
        return (row, 0)

    return LoweredStream(
        name=alloc.ref.name,
        stream=BlockStream(block_shape=policy.block_shape,
                           index_map=index_map,
                           direction=alloc.ref.kind,
                           name=alloc.ref.name),
        spec=alloc.spec,
        logical_shape=logical_shape,
        padded_last=padded_inner,
        policy=policy)


def lower_plan(plan: StreamPlan,
               policy: BlockPolicy = DEFAULT_POLICY) -> LoweredPlan:
    """Lower every allocated lane of ``plan`` to Pallas block schedules.

    The grid is the nest's loop structure with the innermost level tiled by
    the policy block — computed through :func:`agu.block_grid` on the nest's
    canonical (dense row-major) iteration-space spec, so the kernel's block
    schedule provably *is* the AGU pattern at block granularity.
    """
    if not plan.allocations:
        raise LoweringError(
            "plan has no stream allocations (Eq. (3) verdict was 'keep "
            "baseline'); lower the force=True plan for the runtime-decision "
            "path")
    nest = plan.nest
    E = policy.block_elems
    padded_inner = _inner_steps(nest, policy) * E
    padded_bounds = tuple(nest.bounds[:-1]) + (padded_inner,)
    strides = [1] * len(padded_bounds)
    for k in range(len(padded_bounds) - 2, -1, -1):
        strides[k] = strides[k + 1] * padded_bounds[k + 1]
    canonical = StreamSpec(bounds=padded_bounds, strides=tuple(strides))
    grid = agu.block_grid(canonical, (E,))

    lowered = [_lower_allocation(a, nest, policy) for a in plan.allocations]
    ins = tuple(s for s in lowered if s.stream.direction == Direction.READ)
    outs = tuple(s for s in lowered if s.stream.direction == Direction.WRITE)
    return LoweredPlan(plan=plan, policy=policy, grid=grid,
                       in_streams=ins, out_streams=outs)


# --------------------------------------------------------------------------
# End-to-end execution: ssr_call
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _plan_for(nest: LoopNest, num_lanes: int) -> StreamPlan:
    """Plan cache keyed on the nest signature (frozen dataclass hash).

    ``force=True`` is the paper's runtime-decision path: the caller asked to
    *execute* the streamed variant, so allocation must happen regardless of
    the static Eq. (3) verdict (which remains available via ``plan_stats``).
    """
    return ssrify(nest, num_lanes=num_lanes, force=True)


@functools.lru_cache(maxsize=256)
def plan_stats(nest: LoopNest, num_lanes: int = 2) -> StreamPlan:
    """The static-verdict plan (no force) — Eq. (1)–(3) cost accounting."""
    return ssrify(nest, num_lanes=num_lanes)


# Built-kernel cache, LRU-bounded.  Keys include the body function's
# identity: pass a module-level (or otherwise long-lived) body to hit the
# cache — a fresh inline lambda per call builds a fresh kernel each time.
_KERNEL_CACHE_MAX = 256
_kernel_cache: "collections.OrderedDict[Any, Callable]" = \
    collections.OrderedDict()


def _kernel_cache_get(key):
    fn = _kernel_cache.get(key)
    if fn is not None:
        _kernel_cache.move_to_end(key)
    return fn


def _kernel_cache_put(key, fn) -> None:
    _kernel_cache[key] = fn
    _kernel_cache.move_to_end(key)
    while len(_kernel_cache) > _KERNEL_CACHE_MAX:
        _kernel_cache.popitem(last=False)


def clear_caches() -> None:
    _plan_for.cache_clear()
    plan_stats.cache_clear()
    _kernel_cache.clear()


def _first_last(grid: Tuple[int, ...]):
    """Predicates for the first/last step of a (possibly multi-dim) grid."""
    from jax.experimental import pallas as pl

    first = pl.program_id(0) == 0
    last = pl.program_id(0) == pl.num_programs(0) - 1
    for k in range(1, len(grid)):
        first = jnp.logical_and(first, pl.program_id(k) == 0)
        last = jnp.logical_and(last, pl.program_id(k) == pl.num_programs(k) - 1)
    return first, last


def _build_kernel(lowered: LoweredPlan, body: Callable, mode: str,
                  out_dtype, interpret: Optional[bool]) -> Callable:
    """Wrap a block-level ``body`` into a full ssr_pallas kernel."""
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    grid = lowered.grid
    policy = lowered.policy
    n_in = len(lowered.in_streams)
    in_streams = [s.stream for s in lowered.in_streams]

    if mode == "reduce":
        def kernel(*refs):
            in_refs, o_ref, acc_ref = refs[:n_in], refs[n_in], refs[n_in + 1]
            first, last = _first_last(grid)

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            part = body(*[r[...] for r in in_refs])
            acc_ref[...] += jnp.asarray(part, out_dtype).reshape(1, 1)

            @pl.when(last)
            def _write():
                o_ref[...] = acc_ref[...]

        out_streams = [BlockStream((1, 1), lambda *g: (0, 0),
                                   Direction.WRITE, name="acc")]
        out_shapes = [jax.ShapeDtypeStruct((1, 1), out_dtype)]
        scratch = [pltpu.VMEM((1, 1), out_dtype)]
    elif mode == "map":
        steps = lowered.steps
        # Output walks the grid dense row-major: one block per step.
        place = [1] * len(grid)
        for k in range(len(grid) - 2, -1, -1):
            place[k] = place[k + 1] * grid[k + 1]

        def out_map(*g):
            row = g[0] * place[0]
            for k in range(1, len(g)):
                row = row + g[k] * place[k]
            return (row, 0)

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            o_ref[...] = jnp.asarray(
                body(*[r[...] for r in in_refs]), out_dtype
            ).reshape(policy.block_shape)

        out_streams = [BlockStream(policy.block_shape, out_map,
                                   Direction.WRITE, name="out")]
        out_shapes = [jax.ShapeDtypeStruct(
            (steps * policy.rows, policy.lanes), out_dtype)]
        scratch = []
    else:
        raise ValueError(f"unknown ssr_call mode {mode!r}")

    return ssr_pallas(
        kernel, grid=grid,
        in_streams=in_streams, out_streams=out_streams,
        out_shapes=out_shapes, scratch_shapes=scratch,
        interpret=interpret,
        dimension_semantics=("arbitrary",) * len(grid),
    )


def ssr_call(nest: LoopNest, body: Callable[..., jax.Array],
             operands: Dict[str, jax.Array], *,
             mode: str = "reduce",
             out_dtype=jnp.float32,
             policy: BlockPolicy = DEFAULT_POLICY,
             num_lanes: Optional[int] = None,
             interpret: Optional[bool] = None) -> jax.Array:
    """Execute a :class:`LoopNest` as a streamed Pallas kernel.

    ``body(*blocks)`` is the pure compute region: it receives one VMEM block
    per allocated read stream (in allocation order — deepest-first, i.e. the
    order ``plan.allocations`` lists them) and returns

    * ``mode="reduce"`` — a scalar partial, accumulated across all grid
      steps (the Fig. 4 ``%x`` accumulator register);
    * ``mode="map"`` — one output block, written to a dense write stream
      walking the grid (the output AGU); the result is trimmed to the
      nest's iteration count.

    ``operands`` maps :class:`MemRef` names to arrays.  Zero padding is
    applied per stream, so bodies must be padding-neutral for ``reduce``
    (sum/dot-style bodies are).  Plans are cached on the nest signature,
    built kernels on (nest, policy, mode, body, dtypes, interpret).
    """
    if num_lanes is None:
        num_lanes = sum(1 for r in nest.refs if r.is_affine())
    plan = _plan_for(nest, num_lanes)
    lowered = lower_plan(plan, policy)
    missing = [s.name for s in lowered.in_streams if s.name not in operands]
    if missing:
        raise ValueError(f"missing operands for streams {missing}")
    prepared = [s.prepare(operands[s.name]) for s in lowered.in_streams]

    key = (nest, policy, mode, body, str(jnp.dtype(out_dtype)),
           tuple((p.shape, str(p.dtype)) for p in prepared),
           num_lanes, interpret)
    fn = _kernel_cache_get(key)
    if fn is None:
        fn = _build_kernel(lowered, body, mode, jnp.dtype(out_dtype),
                           interpret)
        _kernel_cache_put(key, fn)

    out = fn(*prepared)
    if mode == "reduce":
        return out[0, 0]
    # map: drop the inner-level padding (it interleaves for d > 1 nests),
    # then flatten back to one value per nest iteration.
    padded_inner = _inner_steps(nest, policy) * policy.block_elems
    out_nd = out.reshape(*nest.bounds[:-1], padded_inner)
    return out_nd[..., :nest.bounds[-1]].reshape(-1)
