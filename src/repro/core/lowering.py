"""Lower compiler :class:`StreamPlan`\\ s to executable Pallas kernels.

This module closes the §3.2 loop that the paper's LLVM pass closes in MIR:

    LoopNest ──ssrify()──► StreamPlan ──lower_plan()──► (grid, BlockStreams)
                                                         │
                              ssr_call() ◄───────────────┘  → ssr_pallas()

``ssrify`` allocates affine accesses to data-mover lanes and renders the
Eq. (3) verdict; *nothing* in the seed tree executed that plan.  Here each
allocated :class:`StreamSpec` becomes a Pallas ``grid`` + affine ``index_map``
(the AGU at block granularity, derived via :func:`agu.block_grid`), and
:func:`ssr_call` runs the whole pipeline end to end: feed it a nest, a block
body, and the operand arrays, and the loop executes as a streamed Pallas
kernel whose operand delivery *is* the plan's AGU schedule.

Lowerable patterns (the TPU block-granularity subset of the AGU model):

* unit-stride innermost walk (``coeffs[-1] == 1``) with *dense row-major*
  outer levels — each grid step consumes one whole VMEM block;
* levels with coefficient 0 — the index_map ignores that grid axis, so the
  pipeline revisits the block: the paper's **repeat register**;
* fully loop-invariant operands — a single block served to every step.

Anything else (e.g. a strided column walk, expressible by the word-granular
hardware AGU but not by whole-block DMA) raises :class:`LoweringError`; those
kernels keep their hand-scheduled 2-D block layouts under ``repro.kernels``.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import agu
from .compiler import (Allocation, ChainedPlan, LoopNest, StreamPlan,
                       _dense_strides, chain, ssrify)
from .ssr import BlockStream, ssr_pallas
from .stream import Direction, StreamSpec


class LoweringError(ValueError):
    """The plan's access pattern has no whole-block Pallas schedule."""


@dataclasses.dataclass(frozen=True)
class BlockPolicy:
    """How element streams are blocked into VMEM tiles.

    A TPU "word" for streaming purposes is one (rows × lanes) tile; the
    policy is the stream's element width in the §2 correspondence.
    """

    rows: int = 8
    lanes: int = 128

    @property
    def block_elems(self) -> int:
        return self.rows * self.lanes

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.rows, self.lanes)


DEFAULT_POLICY = BlockPolicy()


@dataclasses.dataclass(frozen=True)
class LoweredStream:
    """One allocation lowered to block granularity.

    ``logical_shape`` is the operand view the lowering expects *before*
    padding (``None`` = take the array as-is, e.g. loop-invariant streams);
    ``prepare`` turns the user's flat/logical array into the 2-D padded
    layout whose row-blocks the ``index_map`` addresses.
    """

    name: str
    stream: BlockStream
    spec: StreamSpec                       # compiler allocation, for oracles
    logical_shape: Optional[Tuple[int, ...]]
    padded_last: int                       # innermost extent after padding
    policy: BlockPolicy
    offset: int = 0                        # base-pointer shift (AGU `base`)

    def prepare(self, arr: jax.Array) -> jax.Array:
        """Pad + reshape ``arr`` into the (rows, lanes) layout streamed."""
        lanes = self.policy.lanes
        if self.logical_shape is None:      # loop-invariant: one block
            # The AGU base pointer shifts the view: element 0 of the block
            # is data[offset], exactly what the spec's repeat walk emits.
            flat = arr.reshape(-1)[self.offset:]
            pad = (-flat.shape[0]) % lanes if flat.shape[0] else lanes
            if pad:
                flat = jnp.pad(flat, (0, pad))
            return flat.reshape(-1, lanes)
        want = math.prod(self.logical_shape)
        flat = arr.reshape(-1)
        if flat.shape[0] != want:
            raise ValueError(
                f"stream '{self.name}': operand has {flat.shape[0]} elements, "
                f"plan expects logical shape {self.logical_shape}")
        view = flat.reshape(self.logical_shape)
        pad = self.padded_last - self.logical_shape[-1]
        if pad:
            view = jnp.pad(view, [(0, 0)] * (view.ndim - 1) + [(0, pad)])
        return view.reshape(-1, lanes)


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """A StreamPlan turned into a launchable Pallas schedule."""

    plan: StreamPlan
    policy: BlockPolicy
    grid: Tuple[int, ...]
    in_streams: Tuple[LoweredStream, ...]
    out_streams: Tuple[LoweredStream, ...]

    @property
    def steps(self) -> int:
        return math.prod(self.grid)


def _inner_steps(nest: LoopNest, policy: BlockPolicy) -> int:
    return _inner_steps_of(nest.bounds, policy)


def _inner_steps_of(bounds: Tuple[int, ...], policy: BlockPolicy) -> int:
    return -(-bounds[-1] // policy.block_elems)


def _lower_allocation(alloc: Allocation, nest: LoopNest,
                      policy: BlockPolicy) -> LoweredStream:
    """Turn one lane's affine access into a BlockStream over row-blocks."""
    coeffs = alloc.ref.coeffs
    assert coeffs is not None  # only affine refs are ever allocated
    d = len(nest.bounds)
    E = policy.block_elems
    steps_inner = _inner_steps(nest, policy)
    padded_inner = steps_inner * E

    varying = [k for k, c in enumerate(coeffs) if c != 0]
    if not varying:
        # Loop-invariant operand: one block revisited by every grid step —
        # the repeat register driven to its limit.
        def invariant_map(*_g):
            return (0, 0)

        return LoweredStream(
            name=alloc.ref.name,
            stream=BlockStream(block_shape=(1, policy.lanes),
                               index_map=invariant_map,
                               direction=alloc.ref.kind,
                               name=alloc.ref.name),
            spec=alloc.spec, logical_shape=None, padded_last=policy.lanes,
            policy=policy, offset=alloc.ref.offset)

    if varying[-1] != d - 1 or coeffs[d - 1] != 1:
        raise LoweringError(
            f"stream '{alloc.ref.name}': innermost coefficient "
            f"{coeffs[d - 1]} is not a unit-stride walk of the innermost "
            "loop — the word-granular AGU supports it, whole-block DMA does "
            "not; use a hand-scheduled 2-D block kernel")

    # Dense row-major check for the outer varying levels: each coefficient
    # must equal the extent-product of the faster-varying levels, so the
    # operand is a plain (L_a, …, L_inner) array we can pad on its last dim.
    extents: Dict[int, int] = {d - 1: nest.bounds[d - 1]}
    expect = nest.bounds[d - 1]
    for k in reversed(varying[:-1]):
        if coeffs[k] != expect:
            raise LoweringError(
                f"stream '{alloc.ref.name}': level-{k} coefficient "
                f"{coeffs[k]} != dense row-major stride {expect}; the "
                "operand layout is not a contiguous array over its varying "
                "loops")
        extents[k] = nest.bounds[k]
        expect *= nest.bounds[k]
    if alloc.ref.offset % E:
        raise LoweringError(
            f"stream '{alloc.ref.name}': base offset {alloc.ref.offset} is "
            f"not block-aligned (block = {E} elements)")

    logical_shape = tuple(nest.bounds[k] for k in varying)
    # Padded flat stride of each varying outer level (innermost padded to a
    # whole number of blocks), expressed in *row-blocks*.
    base_block = alloc.ref.offset // E
    block_coeff: Dict[int, int] = {}
    stride_blocks = steps_inner
    for k in reversed(varying[:-1]):
        block_coeff[k] = stride_blocks
        stride_blocks *= nest.bounds[k]

    def index_map(*g):
        # grid axes = (outer nest levels …, tiled innermost); levels with a
        # zero coefficient simply don't appear — Pallas sees an unchanged
        # index and skips the re-fetch (repeat register).
        row = base_block + g[d - 1]
        for k, bc in block_coeff.items():
            row = row + g[k] * bc
        return (row, 0)

    return LoweredStream(
        name=alloc.ref.name,
        stream=BlockStream(block_shape=policy.block_shape,
                           index_map=index_map,
                           direction=alloc.ref.kind,
                           name=alloc.ref.name),
        spec=alloc.spec,
        logical_shape=logical_shape,
        padded_last=padded_inner,
        policy=policy)


def _canonical_grid(bounds: Tuple[int, ...],
                    policy: BlockPolicy) -> Tuple[int, ...]:
    """Grid for a nest's iteration space, innermost level tiled by blocks.

    Derived through :func:`agu.block_grid` on the canonical dense row-major
    spec so the schedule provably *is* the AGU pattern at block granularity.
    """
    E = policy.block_elems
    padded_inner = _inner_steps_of(bounds, policy) * E
    padded_bounds = tuple(bounds[:-1]) + (padded_inner,)
    canonical = StreamSpec(bounds=padded_bounds,
                           strides=_dense_strides(padded_bounds))
    return agu.block_grid(canonical, (E,))


def lower_plan(plan: StreamPlan,
               policy: BlockPolicy = DEFAULT_POLICY) -> LoweredPlan:
    """Lower every allocated lane of ``plan`` to Pallas block schedules.

    The grid is the nest's loop structure with the innermost level tiled by
    the policy block — computed through :func:`agu.block_grid` on the nest's
    canonical (dense row-major) iteration-space spec, so the kernel's block
    schedule provably *is* the AGU pattern at block granularity.
    """
    if not plan.allocations:
        raise LoweringError(
            "plan has no stream allocations (Eq. (3) verdict was 'keep "
            "baseline'); lower the force=True plan for the runtime-decision "
            "path")
    nest = plan.nest
    grid = _canonical_grid(nest.bounds, policy)

    lowered = [_lower_allocation(a, nest, policy) for a in plan.allocations]
    ins = tuple(s for s in lowered if s.stream.direction == Direction.READ)
    outs = tuple(s for s in lowered if s.stream.direction == Direction.WRITE)
    return LoweredPlan(plan=plan, policy=policy, grid=grid,
                       in_streams=ins, out_streams=outs)


# --------------------------------------------------------------------------
# Stream chaining: a ChainedPlan lowers to ONE Pallas kernel whose
# intermediates live in VMEM scratch blocks and never touch HBM.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LoweredChain:
    """A ChainedPlan turned into a single launchable Pallas schedule.

    All stages share one grid (the unified iteration space, innermost level
    tiled by the policy block).  ``stage_in_streams[k]`` are stage k's
    external read streams; the link intermediates have *no* streams at all —
    they exist only as VMEM scratch inside the kernel.
    """

    chained: ChainedPlan
    policy: BlockPolicy
    grid: Tuple[int, ...]
    stage_in_streams: Tuple[Tuple[LoweredStream, ...], ...]

    @property
    def in_streams(self) -> Tuple[LoweredStream, ...]:
        return tuple(s for stage in self.stage_in_streams for s in stage)

    @property
    def steps(self) -> int:
        return math.prod(self.grid)


def lower_chain(chained: ChainedPlan,
                policy: BlockPolicy = DEFAULT_POLICY) -> LoweredChain:
    """Lower a producer→consumer chain to one fused Pallas schedule.

    Block-granular chaining requires each link to walk the canonical dense
    row-major pattern of the shared iteration space: then grid step ``g``'s
    produced block is *exactly* the block the consumer eats at step ``g``,
    so the intermediate can live in a VMEM scratch block instead of an HBM
    buffer.  Anything else (strided/offset intermediate layouts) raises
    :class:`LoweringError` — the word-granular chaining hardware could
    stagger streams, whole-block fusion cannot.
    """
    bounds = chained.bounds
    dense = _dense_strides(bounds)
    for link in chained.links:
        if link.coeffs != dense or link.offset != 0:
            raise LoweringError(
                f"link '{link.name}': intermediate walk {link.coeffs}+"
                f"{link.offset} is not the dense row-major walk {dense}+0 of "
                "the iteration space — the producer's block is not the "
                "consumer's block, so the intermediate cannot stay in VMEM")
    if not any(p.allocations for p in chained.stages):
        raise LoweringError(
            "chained plan has no stream allocations (every stage kept the "
            "baseline verdict); chain with force=True for the "
            "runtime-decision path")

    stage_streams = []
    for k, plan in enumerate(chained.stages):
        lowered = [_lower_allocation(a, plan.nest, policy)
                   for a in plan.allocations]
        writes = [s.name for s in lowered
                  if s.stream.direction == Direction.WRITE]
        if writes:
            raise LoweringError(
                f"chain stage {k} carries write streams {writes}; only the "
                "final output (synthesised from the call mode) may leave "
                "the fused region")
        stage_streams.append(tuple(lowered))

    return LoweredChain(chained=chained, policy=policy,
                        grid=_canonical_grid(bounds, policy),
                        stage_in_streams=tuple(stage_streams))


# --------------------------------------------------------------------------
# End-to-end execution: ssr_call / ssr_chain_call
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _plan_for(nest: LoopNest, num_lanes: int) -> StreamPlan:
    """Plan cache keyed on the nest signature (frozen dataclass hash).

    ``force=True`` is the paper's runtime-decision path: the caller asked to
    *execute* the streamed variant, so allocation must happen regardless of
    the static Eq. (3) verdict (which remains available via ``plan_stats``).
    """
    return ssrify(nest, num_lanes=num_lanes, force=True)


@functools.lru_cache(maxsize=256)
def plan_stats(nest: LoopNest, num_lanes: int = 2) -> StreamPlan:
    """The static-verdict plan (no force) — Eq. (1)–(3) cost accounting."""
    return ssrify(nest, num_lanes=num_lanes)


@functools.lru_cache(maxsize=256)
def _chain_for(nests: Tuple[LoopNest, ...],
               num_lanes: Optional[int]) -> ChainedPlan:
    """Chained-plan cache (force=True: the caller asked to execute fused)."""
    return chain(nests, num_lanes=num_lanes, force=True)


def _body_key(body: Callable) -> Any:
    """Stable cache identity for a block body.

    Keying on the function *object* is a footgun: an inline lambda is a
    fresh object every call, so every call silently rebuilds (and re-jits)
    the kernel.  Python compiles the lambda's code object once per source
    location, so ``(code, bound self, closure values, defaults,
    kw-defaults)`` identifies the body's behaviour — two lambdas from the
    same line with equal closures share a kernel, while bound methods of
    *different* instances (per-instance state lives on ``__self__``, not
    in the code or closure) and factories varying a keyword-only default
    do not collide.  Unhashable contents (e.g. captured arrays) fall back
    to object identity: never stale, just uncached across re-creations.
    """
    code = getattr(body, "__code__", None)
    if code is None:
        return body
    cells = getattr(body, "__closure__", None) or ()
    kwdefs = getattr(body, "__kwdefaults__", None) or {}
    try:
        key = (code, getattr(body, "__self__", None),
               tuple(c.cell_contents for c in cells),
               getattr(body, "__defaults__", None) or (),
               tuple(sorted(kwdefs.items())))
        hash(key)
    except (TypeError, ValueError):  # unhashable content / empty cell
        return body
    return key


# Built-kernel cache, LRU-bounded.  Keys include the body's ``_body_key``:
# inline lambdas hit the cache as long as their closure values are hashable
# and equal (see the footgun note above).
_KERNEL_CACHE_MAX = 256
_kernel_cache: "collections.OrderedDict[Any, Callable]" = \
    collections.OrderedDict()


def _kernel_cache_get(key):
    fn = _kernel_cache.get(key)
    if fn is not None:
        _kernel_cache.move_to_end(key)
    return fn


def _kernel_cache_put(key, fn) -> None:
    _kernel_cache[key] = fn
    _kernel_cache.move_to_end(key)
    while len(_kernel_cache) > _KERNEL_CACHE_MAX:
        _kernel_cache.popitem(last=False)


def clear_caches() -> None:
    _plan_for.cache_clear()
    plan_stats.cache_clear()
    _chain_for.cache_clear()
    _kernel_cache.clear()


def _first_last(grid: Tuple[int, ...]):
    """Predicates for the first/last step of a (possibly multi-dim) grid."""
    from jax.experimental import pallas as pl

    first = pl.program_id(0) == 0
    last = pl.program_id(0) == pl.num_programs(0) - 1
    for k in range(1, len(grid)):
        first = jnp.logical_and(first, pl.program_id(k) == 0)
        last = jnp.logical_and(last, pl.program_id(k) == pl.num_programs(k) - 1)
    return first, last


def _assemble_kernel(grid: Tuple[int, ...], policy: BlockPolicy,
                     in_streams: Sequence[BlockStream],
                     compute: Callable, n_links: int, mode: str,
                     out_dtype, part_shape: Optional[Tuple[int, ...]],
                     interpret: Optional[bool]) -> Callable:
    """Shared kernel assembler for single-nest and chained plans.

    ``compute(in_refs, link_refs)`` returns the per-step value; ``n_links``
    VMEM scratch blocks hold chained intermediates (zero for plain plans).
    Reduce mode accumulates into a *vector* accumulator when the partial is
    a multi-element 2-D block — the whole (rows, lanes) vreg adds every
    step, folded to the scalar exactly once on the last step — and keeps
    the legacy scalar ``(1, 1)`` accumulator for scalar partials.  Map-mode
    grid axes are independent and declared ``parallel``; only reduce mode
    needs sequential (``arbitrary``) semantics for its carried accumulator.
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    n_in = len(in_streams)
    link_scratch = [pltpu.VMEM(policy.block_shape, out_dtype)
                    for _ in range(n_links)]

    if mode == "reduce":
        vector_acc = (part_shape is not None and len(part_shape) == 2
                      and math.prod(part_shape) > 1)
        acc_shape = tuple(part_shape) if vector_acc else (1, 1)

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            links = refs[n_in + 1:n_in + 1 + n_links]
            acc_ref = refs[n_in + 1 + n_links]
            first, last = _first_last(grid)

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            part = jnp.asarray(compute(in_refs, links), out_dtype)
            acc_ref[...] += part.reshape(acc_shape)

            @pl.when(last)
            def _write():
                if vector_acc:
                    o_ref[...] = jnp.sum(acc_ref[...]).reshape(1, 1)
                else:
                    o_ref[...] = acc_ref[...]

        out_streams = [BlockStream((1, 1), lambda *g: (0, 0),
                                   Direction.WRITE, name="acc")]
        out_shapes = [jax.ShapeDtypeStruct((1, 1), out_dtype)]
        scratch = link_scratch + [pltpu.VMEM(acc_shape, out_dtype)]
        semantics = ("arbitrary",) * len(grid)
    elif mode == "map":
        steps = math.prod(grid)
        # Output walks the grid dense row-major: one block per step.
        place = _dense_strides(grid)

        def out_map(*g):
            row = g[0] * place[0]
            for k in range(1, len(g)):
                row = row + g[k] * place[k]
            return (row, 0)

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            links = refs[n_in + 1:n_in + 1 + n_links]
            o_ref[...] = jnp.asarray(
                compute(in_refs, links), out_dtype
            ).reshape(policy.block_shape)

        out_streams = [BlockStream(policy.block_shape, out_map,
                                   Direction.WRITE, name="out")]
        out_shapes = [jax.ShapeDtypeStruct(
            (steps * policy.rows, policy.lanes), out_dtype)]
        scratch = list(link_scratch)
        semantics = ("parallel",) * len(grid)
    else:
        raise ValueError(f"unknown ssr_call mode {mode!r}")

    return ssr_pallas(
        kernel, grid=grid,
        in_streams=list(in_streams), out_streams=out_streams,
        out_shapes=out_shapes, scratch_shapes=scratch,
        interpret=interpret,
        dimension_semantics=semantics,
    )


def _probe_part_shape(fn: Callable, in_shapes: Sequence[Tuple[int, ...]],
                      dtype) -> Tuple[int, ...]:
    """Trace ``fn`` on abstract blocks to learn the partial's shape."""
    structs = [jax.ShapeDtypeStruct(s, dtype) for s in in_shapes]
    return tuple(jax.eval_shape(lambda *xs: fn(*xs), *structs).shape)


def _build_kernel(lowered: LoweredPlan, body: Callable, mode: str,
                  out_dtype, interpret: Optional[bool]) -> Callable:
    """Wrap a block-level ``body`` into a full ssr_pallas kernel."""
    part_shape = None
    if mode == "reduce":
        part_shape = _probe_part_shape(
            body, [s.stream.block_shape for s in lowered.in_streams],
            out_dtype)

    def compute(in_refs, _links):
        return body(*[r[...] for r in in_refs])

    return _assemble_kernel(lowered.grid, lowered.policy,
                            [s.stream for s in lowered.in_streams],
                            compute, 0, mode, out_dtype, part_shape,
                            interpret)


def _chain_stage_shapes(lowered: LoweredChain, bodies: Sequence[Callable],
                        out_dtype,
                        require_final_block: bool = False) -> Tuple[int, ...]:
    """Shape-check every stage and return the final partial's shape.

    Each linked intermediate must fill exactly one policy block — that is
    the VMEM scratch the next stage reads.  ``require_final_block`` extends
    the check to the last stage (map mode, where the value feeds the dense
    write stream).
    """
    policy = lowered.policy
    cur: Any = None
    for k, stage in enumerate(lowered.stage_in_streams):
        ins = [jax.ShapeDtypeStruct(s.stream.block_shape, out_dtype)
               for s in stage]
        if k == 0:
            cur = jax.eval_shape(lambda *xs: bodies[0](*xs), *ins)
        else:
            carried = jax.ShapeDtypeStruct(policy.block_shape, out_dtype)
            cur = jax.eval_shape(
                lambda c, *xs, _b=bodies[k]: _b(c, *xs), carried, *ins)
        must_block = k < len(bodies) - 1 or require_final_block
        if must_block and math.prod(cur.shape) != policy.block_elems:
            what = ("a linked intermediate" if k < len(bodies) - 1
                    else "the map-mode output")
            raise LoweringError(
                f"chain stage {k} body returns shape {cur.shape} "
                f"({math.prod(cur.shape)} elements); {what} "
                f"must fill one {policy.block_shape} VMEM block")
    return tuple(cur.shape)


def _build_chain_kernel(lowered: LoweredChain, bodies: Sequence[Callable],
                        mode: str, out_dtype,
                        interpret: Optional[bool]) -> Callable:
    """Fuse all stage bodies into ONE Pallas kernel.

    Per grid step: stage 0 computes its block from its read streams; each
    intermediate is written to a VMEM scratch block (never HBM) and read
    back by the next stage's body the same step; the final stage's value
    feeds the usual map/reduce epilogue.
    """
    policy = lowered.policy
    counts = [len(stage) for stage in lowered.stage_in_streams]
    offsets = [0]
    for c in counts[:-1]:
        offsets.append(offsets[-1] + c)
    n_links = len(lowered.chained.links)

    final_shape = _chain_stage_shapes(lowered, bodies, out_dtype,
                                      require_final_block=(mode == "map"))
    part_shape = final_shape if mode == "reduce" else None

    def compute(in_refs, link_refs):
        vals = [r[...] for r in in_refs[:counts[0]]]
        cur = bodies[0](*vals)
        for k in range(1, len(bodies)):
            s_ref = link_refs[k - 1]
            s_ref[...] = jnp.asarray(cur, out_dtype).reshape(
                policy.block_shape)
            args = [r[...] for r in
                    in_refs[offsets[k]:offsets[k] + counts[k]]]
            cur = bodies[k](s_ref[...], *args)
        return cur

    return _assemble_kernel(lowered.grid, policy,
                            [s.stream for s in lowered.in_streams],
                            compute, n_links, mode, out_dtype, part_shape,
                            interpret)


def ssr_call(nest: LoopNest, body: Callable[..., jax.Array],
             operands: Dict[str, jax.Array], *,
             mode: str = "reduce",
             out_dtype=jnp.float32,
             policy: BlockPolicy = DEFAULT_POLICY,
             num_lanes: Optional[int] = None,
             interpret: Optional[bool] = None) -> jax.Array:
    """Execute a :class:`LoopNest` as a streamed Pallas kernel.

    ``body(*blocks)`` is the pure compute region: it receives one VMEM block
    per allocated read stream (in allocation order — deepest-first, i.e. the
    order ``plan.allocations`` lists them) and returns

    * ``mode="reduce"`` — a partial accumulated across all grid steps (the
      Fig. 4 ``%x`` accumulator register).  A *block-shaped* partial (e.g.
      ``lambda a, b: a * b``) uses a vectorised (rows, lanes) accumulator
      folded to the scalar once on the last step — the whole VPU vreg adds
      every step; a scalar partial (e.g. ``jnp.sum(a * b)``) keeps the
      legacy (1, 1) accumulator;
    * ``mode="map"`` — one output block, written to a dense write stream
      walking the grid (the output AGU); the result is trimmed to the
      nest's iteration count.

    ``operands`` maps :class:`MemRef` names to arrays.  Zero padding is
    applied per stream, so bodies must be padding-neutral for ``reduce``
    (sum/dot-style bodies are).  Plans are cached on the nest signature,
    built kernels on (nest, policy, mode, body key, dtypes, interpret) —
    see :func:`_body_key`: inline lambdas hit the cache as long as their
    closure values are hashable and equal.
    """
    if num_lanes is None:
        num_lanes = sum(1 for r in nest.refs if r.is_affine())
    plan = _plan_for(nest, num_lanes)
    lowered = lower_plan(plan, policy)
    missing = [s.name for s in lowered.in_streams if s.name not in operands]
    if missing:
        raise ValueError(f"missing operands for streams {missing}")
    prepared = [s.prepare(operands[s.name]) for s in lowered.in_streams]

    key = (nest, policy, mode, _body_key(body), str(jnp.dtype(out_dtype)),
           tuple((p.shape, str(p.dtype)) for p in prepared),
           num_lanes, interpret)
    fn = _kernel_cache_get(key)
    if fn is None:
        fn = _build_kernel(lowered, body, mode, jnp.dtype(out_dtype),
                           interpret)
        _kernel_cache_put(key, fn)

    out = fn(*prepared)
    return _trim_output(out, nest.bounds, mode, policy)


def _trim_output(out: jax.Array, bounds: Tuple[int, ...], mode: str,
                 policy: BlockPolicy) -> jax.Array:
    if mode == "reduce":
        return out[0, 0]
    # map: drop the inner-level padding (it interleaves for d > 1 nests),
    # then flatten back to one value per nest iteration.
    padded_inner = _inner_steps_of(bounds, policy) * policy.block_elems
    out_nd = out.reshape(*bounds[:-1], padded_inner)
    return out_nd[..., :bounds[-1]].reshape(-1)


def ssr_chain_call(nests: Sequence[LoopNest],
                   bodies: Sequence[Callable[..., jax.Array]],
                   operands: Dict[str, jax.Array], *,
                   mode: str = "map",
                   out_dtype=jnp.float32,
                   policy: BlockPolicy = DEFAULT_POLICY,
                   num_lanes: Optional[int] = None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Execute a producer→consumer chain of nests as ONE Pallas kernel.

    ``nests[k]`` and ``nests[k+1]`` must be chainable (see
    :func:`repro.core.compiler.chain`): the producer's WRITE ref unifies
    with the consumer's READ ref over one shared iteration space.  The
    intermediates live in VMEM scratch blocks — they are never stored to
    (or re-loaded from) HBM, which is the whole point.

    ``bodies[0](*stage0_blocks)`` computes the first intermediate;
    ``bodies[k](carried_block, *stagek_blocks)`` receives the previous
    stage's block first.  ``mode`` applies to the *final* stage, with the
    same contract as :func:`ssr_call` — including the vectorised reduce
    accumulator when the last body returns a block-shaped partial.  Reduce
    bodies must be padding-neutral at every stage: the padded tail flows
    through the whole chain.
    """
    nests = tuple(nests)
    bodies = tuple(bodies)
    if len(bodies) != len(nests):
        raise ValueError(
            f"need one body per nest, got {len(bodies)} bodies for "
            f"{len(nests)} nests")
    chained = _chain_for(nests, num_lanes)
    lowered = lower_chain(chained, policy)
    flat = lowered.in_streams
    missing = sorted({s.name for s in flat} - set(operands))
    if missing:
        raise ValueError(f"missing operands for streams {missing}")
    prepared = [s.prepare(operands[s.name]) for s in flat]

    key = ("chain", nests, policy, mode,
           tuple(_body_key(b) for b in bodies), str(jnp.dtype(out_dtype)),
           tuple((p.shape, str(p.dtype)) for p in prepared),
           num_lanes, interpret)
    fn = _kernel_cache_get(key)
    if fn is None:
        fn = _build_chain_kernel(lowered, bodies, mode,
                                 jnp.dtype(out_dtype), interpret)
        _kernel_cache_put(key, fn)

    out = fn(*prepared)
    return _trim_output(out, chained.bounds, mode, policy)
