"""Lower compiler :class:`StreamPlan`\\ s to executable Pallas kernels.

This module closes the §3.2 loop that the paper's LLVM pass closes in MIR:

    LoopNest ──ssrify()──► StreamPlan ──lower_plan()──► (grid, BlockStreams)
                                                         │
                              ssr_call() ◄───────────────┘  → ssr_pallas()

``ssrify`` allocates affine accesses to data-mover lanes and renders the
Eq. (3) verdict; *nothing* in the seed tree executed that plan.  Here each
allocated :class:`StreamSpec` becomes a Pallas ``grid`` + affine ``index_map``
(the AGU at block granularity, derived via :func:`agu.block_grid`), and
:func:`ssr_call` runs the whole pipeline end to end: feed it a nest, a block
body, and the operand arrays, and the loop executes as a streamed Pallas
kernel whose operand delivery *is* the plan's AGU schedule.

Two lowering paths cover the AGU model at block granularity:

**Flat (``lower_plan``)** — read-only map/reduce nests whose operands walk
the iteration space in loop order: unit-stride innermost walk
(``coeffs[-1] == 1``) with *dense row-major* outer levels, levels with
coefficient 0 (the index_map ignores that grid axis, so the pipeline
revisits the block: the paper's **repeat register**), and fully
loop-invariant operands.

**Level-mapped (``lower_nest``)** — the general §3.2 pattern for nests
with an explicit output WRITE ref: one grid axis per loop level, operand
storage orders that may *permute* the loop order (GEMM's B, stored (k, n)
against (m, n, k) loops), and an output revisited across trailing
contraction axes, lowered to a VMEM scratch accumulator with
init-on-first / drain-on-last steps — Fig. 4's accumulator register as a
whole block.  ``ssr_call`` picks the path from the nest itself.

Anything outside both (overlapping halo windows, per-stage power-of-two
strides — expressible by the word-granular hardware AGU but not by
whole-block DMA) raises :class:`LoweringError`; those kernels keep
hand-scheduled layouts under ``repro.kernels``, each behind a declared
``lowering_waiver``.

Every entry point is **schedule-parametric**: a :class:`Schedule` (block
geometry, per-level tile targets, grid-axis order, accumulator dtype) can
be passed explicitly, searched by ``core/autotune.py``, or left at the
default.  Dispatch is **zero-overhead**: prepare (pad/reshape), the Pallas
kernel and the result trim compose into one cached jitted callable per
(nest, schedule, shapes, body), so repeated calls never re-dispatch the
padding traffic eagerly (see ``DISPATCH_STATS``).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import agu
from . import nest_analysis
from . import resilience
from .compiler import (Allocation, ChainDAG, ChainedPlan, LoopNest,
                       StreamPlan, _dense_strides, chain, chain_dag, ssrify)
from .ssr import BlockStream, auto_block, ssr_pallas
from .stream import Direction, StreamSpec


class LoweringError(ValueError):
    """The plan's access pattern has no whole-block Pallas schedule."""


@dataclasses.dataclass(frozen=True)
class BlockPolicy:
    """How element streams are blocked into VMEM tiles.

    A TPU "word" for streaming purposes is one (rows × lanes) tile; the
    policy is the stream's element width in the §2 correspondence.
    """

    rows: int = 8
    lanes: int = 128

    @property
    def block_elems(self) -> int:
        return self.rows * self.lanes

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.rows, self.lanes)


DEFAULT_POLICY = BlockPolicy()


#: Per-level tile targets, in units of the policy's lane/sublane widths.
#: Lanes-role levels (the last storage dim of some stream) tile up to
#: 4×128 = 512 elements; sublane-role levels up to 32×8 = 256 rows.
#: These are the *defaults* — a :class:`Schedule` overrides both.
_LANES_TILE_FACTOR = 4
_ROWS_TILE_FACTOR = 32


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One complete block-scheduling decision for a lowered kernel.

    :class:`BlockPolicy` says how element streams become VMEM tiles;
    ``Schedule`` is the full searched artifact on top of it — the knobs the
    autotuner (``core/autotune.py``) varies per (nest, shapes, backend):

    * ``rows``/``lanes`` — the block geometry (the policy);
    * ``lanes_tile_factor``/``rows_tile_factor`` — per-level tile targets
      of the level-mapped path, in units of ``lanes``/``rows``
      (:func:`lower_nest`'s ``_nest_tiles``);
    * ``axis_order`` — a permutation of the loop levels giving the grid
      iteration order (outermost first).  Only the level-mapped path honours
      it; contraction axes must stay trailing so the accumulator's revisits
      remain consecutive grid steps.  ``None`` keeps loop order;
    * ``acc_dtype`` — the contraction accumulator dtype (dtype *name*, so
      the dataclass stays hashable/JSON-serialisable).  f32 is the MXU/VPU
      accumulation width and the repo-wide default;
    * ``buffer_depth`` — the data mover's FIFO depth (paper §2.3: the mover
      "proactively performs memory reads").  2 keeps Pallas's synchronous
      double-buffered pipeline; > 2 emits the explicit N-deep DMA rotation
      (``core/ssr.py::_pipelined_call``) that prefetches grid step
      ``i + depth − 1`` while step ``i`` computes.  VMEM budgeting scales
      with it (``ssr.stream_vmem_bytes``), so the autotuner trades depth
      against tile size under one budget;
    * ``stream_depths`` — per-stream FIFO depths (one entry per read
      stream, in allocation order), overriding the uniform
      ``buffer_depth``: the strided operand that misses in HBM gets a
      deep rotation while the unit-stride one stays shallow, each charged
      individually through ``ssr.stream_vmem_bytes``.  ``None`` (the
      default) keeps every stream at ``buffer_depth``.  Searched only in
      full (non-quick) autotune runs;
    * ``cut_edges`` — for fused-DAG calls only (``ssr_dag_call``): the
      edge indices (into ``ChainDAG.edges``) at which the graph is *cut*
      into separate kernels, each cut intermediate materialising in HBM.
      ``None``/``()`` fuses the whole DAG into one kernel.  The fusion
      search (``autotune.autotune_dag``) commits the winning cut here so
      dispatch resolves the best partitioning transparently.

    Frozen + hashable: a ``Schedule`` is a cache key component everywhere
    (kernel cache, schedule cache, benchmark provenance).
    """

    rows: int = 8
    lanes: int = 128
    lanes_tile_factor: int = _LANES_TILE_FACTOR
    rows_tile_factor: int = _ROWS_TILE_FACTOR
    axis_order: Optional[Tuple[int, ...]] = None
    acc_dtype: str = "float32"
    buffer_depth: int = 2
    stream_depths: Optional[Tuple[int, ...]] = None
    cut_edges: Optional[Tuple[int, ...]] = None

    @property
    def policy(self) -> BlockPolicy:
        return BlockPolicy(rows=self.rows, lanes=self.lanes)

    @property
    def block_elems(self) -> int:
        return self.rows * self.lanes

    @classmethod
    def from_policy(cls, policy: BlockPolicy, **kw) -> "Schedule":
        return cls(rows=policy.rows, lanes=policy.lanes, **kw)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["axis_order"] = list(self.axis_order) if self.axis_order else None
        d["stream_depths"] = (list(self.stream_depths)
                              if self.stream_depths else None)
        d["cut_edges"] = (list(self.cut_edges)
                          if self.cut_edges is not None else None)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "Schedule":
        ao = d.get("axis_order")
        sd = d.get("stream_depths")
        ce = d.get("cut_edges")
        return cls(rows=int(d["rows"]), lanes=int(d["lanes"]),
                   lanes_tile_factor=int(d.get("lanes_tile_factor",
                                               _LANES_TILE_FACTOR)),
                   rows_tile_factor=int(d.get("rows_tile_factor",
                                              _ROWS_TILE_FACTOR)),
                   axis_order=tuple(int(a) for a in ao) if ao else None,
                   acc_dtype=str(d.get("acc_dtype", "float32")),
                   buffer_depth=int(d.get("buffer_depth", 2)),
                   stream_depths=(tuple(int(x) for x in sd)
                                  if sd else None),
                   cut_edges=(tuple(int(x) for x in ce)
                              if ce is not None else None))


DEFAULT_SCHEDULE = Schedule()


def _depths_for(sched: Schedule, n_in: int):
    """The ``buffer_depth`` argument for ``ssr_pallas``: the uniform depth,
    or the schedule's per-stream override (one entry per read stream, in
    allocation order — satellite of the asymmetric-depth search)."""
    if sched.stream_depths is None:
        return sched.buffer_depth
    if len(sched.stream_depths) != n_in:
        raise LoweringError(
            f"schedule.stream_depths has {len(sched.stream_depths)} entries "
            f"for {n_in} read streams; give one depth per stream "
            "(allocation order)")
    return tuple(sched.stream_depths)


def _resolve_schedule(policy: BlockPolicy,
                      schedule: Optional[Schedule]) -> Schedule:
    """``schedule`` wins; a bare policy is promoted to a default Schedule."""
    if schedule is not None:
        return schedule
    if policy is DEFAULT_POLICY:
        return DEFAULT_SCHEDULE
    return Schedule.from_policy(policy)


@dataclasses.dataclass(frozen=True)
class LoweredStream:
    """One allocation lowered to block granularity.

    ``logical_shape`` is the operand view the lowering expects *before*
    padding (``None`` = take the array as-is, e.g. loop-invariant streams);
    ``prepare`` turns the user's flat/logical array into the 2-D padded
    layout whose row-blocks the ``index_map`` addresses.
    """

    name: str
    stream: BlockStream
    spec: StreamSpec                       # compiler allocation, for oracles
    logical_shape: Optional[Tuple[int, ...]]
    padded_last: int                       # innermost extent after padding
    policy: BlockPolicy
    offset: int = 0                        # base-pointer shift (AGU `base`)

    def prepare(self, arr: jax.Array) -> jax.Array:
        """Pad + reshape ``arr`` into the (rows, lanes) layout streamed."""
        lanes = self.policy.lanes
        if self.logical_shape is None:      # loop-invariant: one block
            # The AGU base pointer shifts the view: element 0 of the block
            # is data[offset], exactly what the spec's repeat walk emits.
            flat = arr.reshape(-1)[self.offset:]
            pad = (-flat.shape[0]) % lanes if flat.shape[0] else lanes
            if pad:
                flat = jnp.pad(flat, (0, pad))
            return flat.reshape(-1, lanes)
        want = math.prod(self.logical_shape)
        flat = arr.reshape(-1)
        if flat.shape[0] != want:
            raise ValueError(
                f"stream '{self.name}': operand has {flat.shape[0]} elements, "
                f"plan expects logical shape {self.logical_shape}")
        view = flat.reshape(self.logical_shape)
        pad = self.padded_last - self.logical_shape[-1]
        if pad:
            view = jnp.pad(view, [(0, 0)] * (view.ndim - 1) + [(0, pad)])
        return view.reshape(-1, lanes)


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """A StreamPlan turned into a launchable Pallas schedule."""

    plan: StreamPlan
    policy: BlockPolicy
    grid: Tuple[int, ...]
    in_streams: Tuple[LoweredStream, ...]
    out_streams: Tuple[LoweredStream, ...]
    schedule: Schedule = DEFAULT_SCHEDULE

    @property
    def steps(self) -> int:
        return math.prod(self.grid)


def _inner_steps(nest: LoopNest, policy: BlockPolicy) -> int:
    return _inner_steps_of(nest.bounds, policy)


def _inner_steps_of(bounds: Tuple[int, ...], policy: BlockPolicy) -> int:
    return -(-bounds[-1] // policy.block_elems)


def _lower_allocation(alloc: Allocation, nest: LoopNest,
                      policy: BlockPolicy) -> LoweredStream:
    """Turn one lane's affine access into a BlockStream over row-blocks."""
    coeffs = alloc.ref.coeffs
    assert coeffs is not None  # only affine refs are ever allocated
    d = len(nest.bounds)
    E = policy.block_elems
    steps_inner = _inner_steps(nest, policy)
    padded_inner = steps_inner * E

    varying = [k for k, c in enumerate(coeffs) if c != 0]
    if not varying:
        # Loop-invariant operand: one block revisited by every grid step —
        # the repeat register driven to its limit.
        def invariant_map(*_g):
            return (0, 0)

        return LoweredStream(
            name=alloc.ref.name,
            stream=BlockStream(block_shape=(1, policy.lanes),
                               index_map=invariant_map,
                               direction=alloc.ref.kind,
                               name=alloc.ref.name),
            spec=alloc.spec, logical_shape=None, padded_last=policy.lanes,
            policy=policy, offset=alloc.ref.offset)

    if varying[-1] != d - 1 or coeffs[d - 1] != 1:
        raise LoweringError(
            f"stream '{alloc.ref.name}': innermost coefficient "
            f"{coeffs[d - 1]} is not a unit-stride walk of the innermost "
            "loop — the word-granular AGU supports it, whole-block DMA does "
            "not; use a hand-scheduled 2-D block kernel")

    # Dense row-major check for the outer varying levels: each coefficient
    # must equal the extent-product of the faster-varying levels, so the
    # operand is a plain (L_a, …, L_inner) array we can pad on its last dim.
    extents: Dict[int, int] = {d - 1: nest.bounds[d - 1]}
    expect = nest.bounds[d - 1]
    for k in reversed(varying[:-1]):
        if coeffs[k] != expect:
            raise LoweringError(
                f"stream '{alloc.ref.name}': level-{k} coefficient "
                f"{coeffs[k]} != dense row-major stride {expect}; the "
                "operand layout is not a contiguous array over its varying "
                "loops")
        extents[k] = nest.bounds[k]
        expect *= nest.bounds[k]
    if alloc.ref.offset % E:
        raise LoweringError(
            f"stream '{alloc.ref.name}': base offset {alloc.ref.offset} is "
            f"not block-aligned (block = {E} elements)")

    logical_shape = tuple(nest.bounds[k] for k in varying)
    # Padded flat stride of each varying outer level (innermost padded to a
    # whole number of blocks), expressed in *row-blocks*.
    base_block = alloc.ref.offset // E
    block_coeff: Dict[int, int] = {}
    stride_blocks = steps_inner
    for k in reversed(varying[:-1]):
        block_coeff[k] = stride_blocks
        stride_blocks *= nest.bounds[k]

    def index_map(*g):
        # grid axes = (outer nest levels …, tiled innermost); levels with a
        # zero coefficient simply don't appear — Pallas sees an unchanged
        # index and skips the re-fetch (repeat register).
        row = base_block + g[d - 1]
        for k, bc in block_coeff.items():
            row = row + g[k] * bc
        return (row, 0)

    return LoweredStream(
        name=alloc.ref.name,
        stream=BlockStream(block_shape=policy.block_shape,
                           index_map=index_map,
                           direction=alloc.ref.kind,
                           name=alloc.ref.name),
        spec=alloc.spec,
        logical_shape=logical_shape,
        padded_last=padded_inner,
        policy=policy)


def _canonical_grid(bounds: Tuple[int, ...],
                    policy: BlockPolicy) -> Tuple[int, ...]:
    """Grid for a nest's iteration space, innermost level tiled by blocks.

    Derived through :func:`agu.block_grid` on the canonical dense row-major
    spec so the schedule provably *is* the AGU pattern at block granularity.
    """
    E = policy.block_elems
    padded_inner = _inner_steps_of(bounds, policy) * E
    padded_bounds = tuple(bounds[:-1]) + (padded_inner,)
    canonical = StreamSpec(bounds=padded_bounds,
                           strides=_dense_strides(padded_bounds))
    return agu.block_grid(canonical, (E,))


def lower_plan(plan: StreamPlan,
               policy: BlockPolicy = DEFAULT_POLICY, *,
               schedule: Optional[Schedule] = None) -> LoweredPlan:
    """Lower every allocated lane of ``plan`` to Pallas block schedules.

    The grid is the nest's loop structure with the innermost level tiled by
    the policy block — computed through :func:`agu.block_grid` on the nest's
    canonical (dense row-major) iteration-space spec, so the kernel's block
    schedule provably *is* the AGU pattern at block granularity.

    ``schedule`` (when given) wins over ``policy``; the flat path honours
    only its block geometry — ``axis_order`` permutes *loop levels*, which
    this path has already flattened, so a non-``None`` order is rejected.
    """
    resilience.inject("lowering")
    sched = _resolve_schedule(policy, schedule)
    if sched.axis_order is not None:
        raise LoweringError(
            "schedule.axis_order applies to the level-mapped path "
            "(lower_nest) only; the flat schedule's grid IS the AGU walk")
    policy = sched.policy
    if not plan.allocations:
        raise LoweringError(
            "plan has no stream allocations (Eq. (3) verdict was 'keep "
            "baseline'); lower the force=True plan for the runtime-decision "
            "path")
    nest = plan.nest
    for r in nest.refs:
        if r.is_indirect():
            raise LoweringError(
                f"indirect ref '{r.name}': gathers take the level-mapped "
                "nest path (lower_nest); give the nest an explicit WRITE "
                "ref so ssr_call routes it there")
    grid = _canonical_grid(nest.bounds, policy)

    lowered = [_lower_allocation(a, nest, policy) for a in plan.allocations]
    ins = tuple(s for s in lowered if s.stream.direction == Direction.READ)
    outs = tuple(s for s in lowered if s.stream.direction == Direction.WRITE)
    return LoweredPlan(plan=plan, policy=policy, grid=grid,
                       in_streams=ins, out_streams=outs, schedule=sched)


# --------------------------------------------------------------------------
# Level-mapped lowering: multi-level nests with contraction axes.
#
# The flattened schedule above serves read-only map/reduce nests whose
# operands walk the iteration space in loop order.  The general §3.2
# pattern — GEMM is the flagship — needs more: operands whose storage
# order *permutes* the loop order (B is stored (k, n) while the loops run
# (m, n, k)), read streams revisited across inner levels (the repeat
# register at block granularity), and an output WRITE ref revisited across
# a contraction level, which the paper's accumulator register absorbs.
#
# ``lower_nest`` maps each loop level to its own grid axis (tiled by a
# per-level block factor), derives every allocated lane's block walk from
# its dense storage order (``nest_analysis.storage_order``), and lowers the
# single output WRITE ref to a VMEM scratch accumulator that initialises on
# the first visit of the contraction axes and drains on the last.  A READ
# ref whose coefficient is zero on an inner grid axis simply drops that
# axis from its index_map: Pallas sees an unchanged block index and skips
# the re-fetch, exactly as the FIFO re-emits a repeated datum.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NestStream:
    """One allocation lowered to a level-mapped block schedule.

    ``levels`` is the ref's dense storage order (outermost first, possibly
    a permutation of the loop order); ``logical_shape``/``padded_shape``
    the operand array before/after per-level padding; ``layout_shape`` the
    (at least 2-D) array the BlockStream actually addresses — rank-1 refs
    gain a leading singleton, loop-invariant refs collapse to a flat
    ``(rows, lanes)`` view served as one revisited block.
    """

    name: str
    stream: BlockStream
    levels: Tuple[int, ...]
    logical_shape: Tuple[int, ...]
    padded_shape: Tuple[int, ...]
    layout_shape: Tuple[int, ...]
    policy: BlockPolicy
    offset: int = 0

    def prepare(self, arr: jax.Array) -> jax.Array:
        """Pad + reshape ``arr`` into the layout the index_map addresses."""
        if not self.levels:                 # loop-invariant: one block
            flat = arr.reshape(-1)[self.offset:]
            if flat.shape[0] == 0:
                raise ValueError(
                    f"stream '{self.name}': loop-invariant operand has no "
                    f"elements past offset {self.offset} — the block would "
                    "be all padding")
            if flat.shape[0] > self.policy.lanes:
                # The stream serves exactly ONE block; silently windowing a
                # larger constant would drop data the body never sees.
                # (The flat lower_plan path keeps its documented
                # base-pointer-window semantics; this path is stricter.)
                raise ValueError(
                    f"stream '{self.name}': loop-invariant operand has "
                    f"{flat.shape[0]} elements past offset {self.offset}, "
                    f"but an invariant stream serves one "
                    f"(1, {self.policy.lanes}) block; give the operand a "
                    "varying loop level instead")
            pad = (-flat.shape[0]) % self.policy.lanes
            if pad:
                flat = jnp.pad(flat, (0, pad))
            return flat.reshape(-1, self.policy.lanes)
        want = math.prod(self.logical_shape)
        flat = arr.reshape(-1)
        if flat.shape[0] != want:
            raise ValueError(
                f"stream '{self.name}': operand has {flat.shape[0]} "
                f"elements, plan expects logical shape {self.logical_shape}")
        view = flat.reshape(self.logical_shape)
        pads = [(0, p - l) for l, p in zip(self.logical_shape,
                                           self.padded_shape)]
        if any(p for _, p in pads):
            view = jnp.pad(view, pads)
        return view.reshape(self.layout_shape)


@dataclasses.dataclass(frozen=True)
class IndirectGather:
    """One indirect ref lowered to an in-kernel gather (arXiv 2011.08070).

    The index stream arrives as a normal dense block (``index_pos`` names
    its position in ``in_streams``); the gather *table* (the indirectly
    addressed operand) rides along whole in VMEM as a trailing invariant
    block.  The kernel body computes, per index-block element::

        addr = scale · index_value + Σ_l coeffs[l]·(program_id(grid_pos[l])
                                                    ·tiles[l] + intra_l)
               + offset

    and serves the body a ``jnp.take`` gather of the flattened table —
    each affine additive level prepends one block dimension.
    """

    name: str
    index_of: str
    index_pos: int               # index stream's slot in in_streams
    scale: int
    offset: int
    levels: Tuple[int, ...]      # affine additive levels (outermost first)
    grid_pos: Tuple[int, ...]    # grid-axis position per level
    tiles: Tuple[int, ...]       # tile extent per level
    coeffs: Tuple[int, ...]      # address coefficient per level


@dataclasses.dataclass(frozen=True)
class HaloRead:
    """One windowed READ ref lowered to shifted streams + in-kernel taps.

    A ref with ``window[l] = w > 1`` revisits ``w - 1`` neighbouring
    elements per step on level ``l`` — the stencil halo.  Whole-block DMA
    cannot fetch a block-and-a-bit, so the lowering emits ``2**k`` copies
    of the stream (``k`` halo'd levels): slot bit ``j`` adds a +1 grid
    shift on halo level ``j``.  The kernel concatenates each shifted pair
    along the level's block axis and slices the first ``tile + w - 1``
    columns — the widened block the body sees (DESIGN.md §13).

    ``slots`` are the shifted streams' positions in ``in_streams`` (binary
    order, bit 0 first); ``axes``/``tiles``/``windows`` are per halo'd
    level, in slot-bit order.
    """

    name: str
    slots: Tuple[int, ...]
    axes: Tuple[int, ...]
    tiles: Tuple[int, ...]
    windows: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class LoweredNest:
    """A StreamPlan with an output ref, lowered level-by-level.

    ``grid[k]`` covers loop level ``axis_order[k]`` (padded bound / tile;
    ``axis_order`` is the identity unless the schedule permutes it);
    ``tiles``/``padded_bounds`` stay in *loop-level* order.
    ``contraction_axes`` are the output's revisited levels as **grid-axis
    positions** — declared ``arbitrary`` (sequential) so the accumulator
    carries, every other axis ``parallel``.  ``gathers`` are the plan's
    indirect refs: the body receives their gathered blocks *after* the
    ``in_streams`` blocks, in declaration order.
    """

    plan: StreamPlan
    policy: BlockPolicy
    grid: Tuple[int, ...]
    tiles: Tuple[int, ...]
    in_streams: Tuple[NestStream, ...]
    out_stream: NestStream
    contraction_axes: Tuple[int, ...]
    schedule: Schedule = DEFAULT_SCHEDULE
    axis_order: Tuple[int, ...] = ()
    padded_bounds: Tuple[int, ...] = ()
    gathers: Tuple[IndirectGather, ...] = ()
    halos: Tuple[HaloRead, ...] = ()
    rescale: bool = False

    @property
    def semantics(self) -> Tuple[str, ...]:
        return tuple("arbitrary" if l in self.contraction_axes else "parallel"
                     for l in range(len(self.grid)))

    @property
    def steps(self) -> int:
        return math.prod(self.grid)


def _storage_order_or_raise(ref, nest: LoopNest) -> Tuple[int, ...]:
    order = nest_analysis.storage_order(ref, nest)
    if order is None:
        raise LoweringError(
            f"stream '{ref.name}': coefficients {ref.coeffs} admit no dense "
            "row-major storage order — overlapping or strided layouts are "
            "word-granular AGU territory, not whole-block DMA")
    return order


def _nest_tiles(nest: LoopNest, orders: Dict[str, Tuple[int, ...]],
                sched: Schedule) -> Tuple[Tuple[int, ...],
                                          Tuple[int, ...]]:
    """Per-level (tile, padded bound) from the streams' storage roles.

    A level that is the *last* storage dim of any stream is a lanes level
    (tile aligned to ``sched.lanes``, target ``lanes·lanes_tile_factor``);
    a level appearing only in outer positions is a sublane level (aligned
    to ``sched.rows``, target ``rows·rows_tile_factor``); a level no
    stream varies with is a pure iteration axis (tile 1).

    A halo'd level (some ref reads a ``window[l] = w > 1`` neighbourhood)
    additionally needs ``w - 1`` overlap columns served by ONE +1-shifted
    neighbour block, so its tile target is raised to at least the aligned
    overlap — candidates whose tile still undershoots it fail loudly in
    :func:`_lower_halo_streams` and are filtered by the autotuner.
    """
    policy = sched.policy
    roles: Dict[int, str] = {}
    for order in orders.values():
        if order:
            roles[order[-1]] = "lanes"
    for order in orders.values():
        for lvl in order[:-1]:
            roles.setdefault(lvl, "sublane")
    halo_need: Dict[int, int] = {}
    for ref in nest.refs:
        if ref.name in orders and ref.has_window():
            for lvl, w in enumerate(ref.window):
                if w > 1:
                    halo_need[lvl] = max(halo_need.get(lvl, 0), w - 1)
    tiles, padded = [], []
    for lvl, b in enumerate(nest.bounds):
        role = roles.get(lvl)
        if role == "lanes":
            align = policy.lanes
            target = policy.lanes * sched.lanes_tile_factor
        elif role == "sublane":
            align = policy.rows
            target = policy.rows * sched.rows_tile_factor
        else:
            tiles.append(1)
            padded.append(b)
            continue
        need = halo_need.get(lvl, 0)
        if need:
            target = max(target, -(-need // align) * align)
        pb = -(-b // align) * align
        tiles.append(auto_block(pb, target, align))
        padded.append(pb)
    return tuple(tiles), tuple(padded)


def _grid_axis_order(sched: Schedule, d: int,
                     zaxes: Tuple[int, ...]) -> Tuple[int, ...]:
    """Validated grid-axis order: a permutation keeping contractions last.

    The accumulator lowering requires every revisit of one output block to
    be *consecutive* grid steps, i.e. the contraction axes must be the
    fastest-varying (trailing) grid axes — any permutation of the parallel
    axes ahead of them is legal and only changes traversal locality.
    """
    order = sched.axis_order
    if order is None:
        return tuple(range(d))
    if sorted(order) != list(range(d)):
        raise LoweringError(
            f"schedule.axis_order {order} is not a permutation of the "
            f"{d} loop levels")
    if zaxes and set(order[d - len(zaxes):]) != set(zaxes):
        raise LoweringError(
            f"schedule.axis_order {order} does not keep the contraction "
            f"axes {zaxes} trailing; the accumulator's revisits must be "
            "consecutive grid steps")
    return tuple(order)


def _lower_nest_stream(alloc: Allocation, nest: LoopNest,
                       tiles: Tuple[int, ...], padded: Tuple[int, ...],
                       policy: BlockPolicy,
                       pos: Dict[int, int]) -> NestStream:
    """One lane's level-mapped block walk.

    ``pos[lvl]`` is the grid-axis position of loop level ``lvl`` (identity
    unless the schedule permutes the axis order).
    """
    ref = alloc.ref
    order = _storage_order_or_raise(ref, nest)
    if not order:
        # Loop-invariant: a read is one block revisited by every grid step;
        # a write is the scalar accumulator drained once at the end.
        shape = (1, 1) if ref.kind == Direction.WRITE else (1, policy.lanes)
        return NestStream(
            name=ref.name,
            stream=BlockStream(shape, lambda *_g: (0, 0),
                               direction=ref.kind, name=ref.name),
            levels=(), logical_shape=(), padded_shape=(),
            layout_shape=shape, policy=policy, offset=ref.offset)
    if ref.offset:
        raise LoweringError(
            f"stream '{ref.name}': base offset {ref.offset} cannot shift a "
            "level-mapped block walk; fold it into the operand view")
    logical = tuple(nest.bounds[l] for l in order)
    pad_shape = tuple(padded[l] for l in order)
    if len(order) == 1:
        lvl = order[0]
        block = (1, tiles[lvl])
        layout = (1, pad_shape[0])

        def index_map(*g, _p=pos[lvl]):
            return (0, g[_p])
    else:
        block = tuple(tiles[l] for l in order)
        layout = pad_shape

        def index_map(*g, _ps=tuple(pos[l] for l in order)):
            return tuple(g[p] for p in _ps)

    return NestStream(
        name=ref.name,
        stream=BlockStream(block, index_map, direction=ref.kind,
                           name=ref.name),
        levels=order, logical_shape=logical, padded_shape=pad_shape,
        layout_shape=layout, policy=policy)


def _lower_halo_streams(alloc: Allocation, nest: LoopNest,
                        tiles: Tuple[int, ...], padded: Tuple[int, ...],
                        policy: BlockPolicy, pos: Dict[int, int]
                        ) -> Tuple[list, Tuple[int, ...], Tuple[int, ...],
                                   Tuple[int, ...]]:
    """Lower one windowed READ ref to ``2**k`` shifted streams.

    Whole-block DMA cannot deliver a ``tile + w - 1`` widened block
    directly (index_maps address whole tiles), so each halo'd level
    doubles the stream: the copy's index_map is shifted +1 grid step on
    that level, and the kernel stitches ``block ++ shifted`` back into the
    widened view (:func:`_halo_widen`).  The operand layout is padded by
    one extra tile per halo'd level so the shifted walk stays in range at
    the grid edge.

    Returns ``(streams, axes, halo_tiles, windows)`` — the shifted
    :class:`NestStream`\\ s in binary slot order plus the per-halo'd-level
    metadata for :class:`HaloRead` (block axis, tile, window width).
    """
    ref = alloc.ref
    order = _storage_order_or_raise(ref, nest)
    if ref.offset:
        raise LoweringError(
            f"stream '{ref.name}': base offset {ref.offset} cannot shift a "
            "level-mapped block walk; fold it into the operand view")
    halo_lvls = tuple(lvl for lvl, w in enumerate(ref.window) if w > 1)
    for lvl in halo_lvls:
        w = ref.window[lvl]
        if w - 1 > tiles[lvl]:
            raise LoweringError(
                f"stream '{ref.name}': halo window {ref.window} needs "
                f"{w - 1} overlap columns on level {lvl}, but the block "
                f"tile is only {tiles[lvl]} wide; widen the tile so one "
                "block plus its +1-shifted neighbour covers the window")
    halo_set = set(halo_lvls)
    logical = tuple(nest_analysis.level_extent(ref, nest, l) for l in order)
    pad_shape = tuple(padded[l] + (tiles[l] if l in halo_set else 0)
                      for l in order)
    streams = []
    for m in range(1 << len(halo_lvls)):
        shift = {lvl: (m >> j) & 1 for j, lvl in enumerate(halo_lvls)}
        if len(order) == 1:
            lvl = order[0]
            block = (1, tiles[lvl])
            layout = (1, pad_shape[0])

            def index_map(*g, _p=pos[lvl], _s=shift[lvl]):
                return (0, g[_p] + _s)
        else:
            block = tuple(tiles[l] for l in order)
            layout = pad_shape

            def index_map(*g, _ps=tuple(pos[l] for l in order),
                          _ss=tuple(shift.get(l, 0) for l in order)):
                return tuple(g[p] + s for p, s in zip(_ps, _ss))

        streams.append(NestStream(
            name=ref.name,
            stream=BlockStream(block, index_map, direction=ref.kind,
                               name=ref.name),
            levels=order, logical_shape=logical, padded_shape=pad_shape,
            layout_shape=layout, policy=policy))
    axes = tuple(1 if len(order) == 1 else order.index(lvl)
                 for lvl in halo_lvls)
    return (streams, axes, tuple(tiles[lvl] for lvl in halo_lvls),
            tuple(ref.window[lvl] for lvl in halo_lvls))


def lower_nest(plan: StreamPlan,
               policy: BlockPolicy = DEFAULT_POLICY, *,
               schedule: Optional[Schedule] = None) -> LoweredNest:
    """Lower a plan with an output WRITE ref to a level-mapped schedule.

    Requirements (each a :class:`LoweringError` otherwise):

    * exactly one WRITE ref, affine and *allocated* (it needs a lane);
    * every allocated ref has a dense storage order (possibly permuting
      the loop order — GEMM's B);
    * the output's contraction axes are the innermost loop levels, so all
      revisits of one output block are consecutive grid steps and a single
      VMEM accumulator carries them (init on first, drain on last).

    ``schedule`` (when given) wins over ``policy`` and additionally sets
    the per-level tile targets, the grid-axis order (parallel axes may
    permute; contraction axes stay trailing) and the accumulator dtype.
    """
    resilience.inject("lowering")
    sched = _resolve_schedule(policy, schedule)
    policy = sched.policy
    nest = plan.nest
    try:
        out_ref = nest_analysis.output_ref(nest)
    except ValueError as e:
        raise LoweringError(str(e)) from e
    if out_ref is None:
        raise LoweringError(
            "nest has no WRITE ref; use lower_plan with an ssr_call "
            "map/reduce mode to synthesise the output")
    if not out_ref.is_affine():
        raise LoweringError(
            f"output ref '{out_ref.name}' is not affine; it cannot be "
            "served by a write stream")
    out_allocs = [a for a in plan.allocations
                  if a.ref.kind == Direction.WRITE]
    if not out_allocs:
        raise LoweringError(
            f"output ref '{out_ref.name}' was not allocated a lane "
            f"({len(plan.allocations)} lanes used); raise num_lanes so the "
            "write stream gets a data mover")

    zaxes = nest_analysis.contraction_axes(out_ref, nest)
    out_varying = nest_analysis.varying_levels(out_ref)
    if zaxes and out_varying and max(out_varying) > min(zaxes):
        raise LoweringError(
            f"output ref '{out_ref.name}': contraction axes {zaxes} are not "
            f"the innermost levels (output varies with {out_varying}); the "
            "accumulator would be drained and re-initialised mid-reduction")

    rescale = out_ref.acc_kind == "online_softmax"
    if rescale:
        if len(zaxes) != 1:
            raise LoweringError(
                f"output ref '{out_ref.name}': online_softmax needs exactly "
                f"one contraction axis to carry the (m, l, acc) triple "
                f"across, got {zaxes}")
        if set(out_varying) | set(zaxes) != set(range(len(nest.bounds))):
            raise LoweringError(
                f"output ref '{out_ref.name}': online_softmax requires the "
                "output plus the contraction axis to cover every loop "
                f"level (output varies with {out_varying}, contraction "
                f"{zaxes}, nest depth {len(nest.bounds)})")

    for r in plan.residual:
        if r.is_indirect():
            raise LoweringError(
                f"indirect ref '{r.name}' was not allocated a lane; a "
                "gather cannot stay residual on the block path — raise "
                "num_lanes so every indirect ref gets a data mover")
    ind_allocs = [a for a in plan.allocations if a.ref.is_indirect()]
    dense_allocs = [a for a in plan.allocations if not a.ref.is_indirect()]

    orders = {a.ref.name: _storage_order_or_raise(a.ref, nest)
              for a in dense_allocs}
    tiles, padded = _nest_tiles(nest, orders, sched)
    axis_order = _grid_axis_order(sched, len(nest.bounds), zaxes)
    pos = {lvl: k for k, lvl in enumerate(axis_order)}
    grid = tuple(padded[l] // tiles[l] for l in axis_order)

    if rescale:
        out_order = orders[out_ref.name]
        if len(out_order) != 2:
            raise LoweringError(
                f"output ref '{out_ref.name}': online_softmax carries a "
                "(rows, lanes) accumulator block, so the output needs "
                f"exactly two varying levels, got storage order {out_order}")
        lanes_lvl = out_order[-1]
        if padded[lanes_lvl] != tiles[lanes_lvl]:
            raise LoweringError(
                f"output ref '{out_ref.name}': online_softmax needs the "
                f"lanes level {lanes_lvl} served in one grid step "
                f"(padded {padded[lanes_lvl]} vs tile {tiles[lanes_lvl]}); "
                "the rescaled accumulator cannot split its lane dim")
        if jnp.dtype(sched.acc_dtype) != jnp.dtype("float32"):
            raise LoweringError(
                f"output ref '{out_ref.name}': online_softmax pins "
                f"acc_dtype=float32 (running max/sum rescaling is not "
                f"stable in {sched.acc_dtype}); adjust the schedule")

    ins_list: list = []
    outs: list = []
    halos: list = []
    in_slot: Dict[str, int] = {}
    for a in dense_allocs:
        ref = a.ref
        if ref.kind == Direction.READ and ref.has_window():
            streams, axes, htiles, hwins = _lower_halo_streams(
                a, nest, tiles, padded, policy, pos)
            slots = tuple(range(len(ins_list),
                                len(ins_list) + len(streams)))
            in_slot.setdefault(ref.name, slots[0])
            ins_list.extend(streams)
            halos.append(HaloRead(name=ref.name, slots=slots, axes=axes,
                                  tiles=htiles, windows=hwins))
        else:
            s = _lower_nest_stream(a, nest, tiles, padded, policy, pos)
            if s.stream.direction == Direction.WRITE:
                outs.append(s)
            else:
                in_slot.setdefault(s.name, len(ins_list))
                ins_list.append(s)
    ins = tuple(ins_list)

    gathers = []
    for a in ind_allocs:
        ref = a.ref
        if ref.index_of not in in_slot:
            raise LoweringError(
                f"indirect ref '{ref.name}': its index stream "
                f"'{ref.index_of}' must itself be an allocated read "
                "stream — the gather addresses come off that lane")
        idx_ref = nest_analysis.index_stream_of(ref, nest)
        affine_lvls = tuple(k for k, c in enumerate(ref.coeffs) if c != 0)
        overlap = set(affine_lvls) & set(
            nest_analysis.varying_levels(idx_ref))
        if overlap:
            raise LoweringError(
                f"indirect ref '{ref.name}': affine additive levels "
                f"{sorted(overlap)} coincide with the index stream's "
                "varying levels; the gather address would double-count "
                "those loop indices")
        gathers.append(IndirectGather(
            name=ref.name, index_of=ref.index_of,
            index_pos=in_slot[ref.index_of],
            scale=ref.index_scale, offset=ref.offset,
            levels=affine_lvls,
            grid_pos=tuple(pos[l] for l in affine_lvls),
            tiles=tuple(tiles[l] for l in affine_lvls),
            coeffs=tuple(ref.coeffs[l] for l in affine_lvls)))

    return LoweredNest(plan=plan, policy=policy, grid=grid, tiles=tiles,
                       in_streams=ins, out_stream=outs[0],
                       contraction_axes=tuple(sorted(pos[z] for z in zaxes)),
                       schedule=sched, axis_order=axis_order,
                       padded_bounds=tuple(padded), gathers=tuple(gathers),
                       halos=tuple(halos), rescale=rescale)


# --------------------------------------------------------------------------
# Stream chaining: a ChainedPlan lowers to ONE Pallas kernel whose
# intermediates live in VMEM scratch blocks and never touch HBM.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LoweredChain:
    """A ChainedPlan *or* ChainDAG turned into one launchable schedule.

    All stages share one grid (the unified iteration space, innermost level
    tiled by the policy block).  ``stage_in_streams[k]`` are stage k's
    external read streams; the link intermediates have *no* streams at all —
    they exist only as VMEM scratch inside the kernel.  For a ChainDAG the
    scratch slots are refcounted: a produced block's slot is reused once
    its last consumer stage has read it (see ``_dag_slots``).
    """

    chained: Any                    # ChainedPlan | ChainDAG
    policy: BlockPolicy
    grid: Tuple[int, ...]
    stage_in_streams: Tuple[Tuple[LoweredStream, ...], ...]
    schedule: Schedule = DEFAULT_SCHEDULE

    @property
    def in_streams(self) -> Tuple[LoweredStream, ...]:
        return tuple(s for stage in self.stage_in_streams for s in stage)

    @property
    def steps(self) -> int:
        return math.prod(self.grid)


def lower_chain(chained, policy: BlockPolicy = DEFAULT_POLICY, *,
                schedule: Optional[Schedule] = None) -> LoweredChain:
    """Lower a chain (or chain DAG) to one fused Pallas schedule.

    Accepts a linear :class:`ChainedPlan` or a :class:`ChainDAG` — both
    expose ``stages``/``links``/``bounds``, and the stage emission is
    topologically ordered either way (a linear chain is the special case
    where stage k's only consumer is stage k+1).

    Block-granular chaining requires each link to walk the canonical dense
    row-major pattern of the shared iteration space: then grid step ``g``'s
    produced block is *exactly* the block the consumer eats at step ``g``,
    so the intermediate can live in a VMEM scratch block instead of an HBM
    buffer.  Anything else (strided/offset intermediate layouts) raises
    :class:`LoweringError` — the word-granular chaining hardware could
    stagger streams, whole-block fusion cannot.
    """
    resilience.inject("lowering")
    sched = _resolve_schedule(policy, schedule)
    if sched.axis_order is not None:
        raise LoweringError(
            "schedule.axis_order applies to the level-mapped path "
            "(lower_nest) only; a chain's grid IS the unified AGU walk")
    policy = sched.policy
    bounds = chained.bounds
    dense = _dense_strides(bounds)
    for link in chained.links:
        if link.coeffs != dense or link.offset != 0:
            raise LoweringError(
                f"link '{link.name}': intermediate walk {link.coeffs}+"
                f"{link.offset} is not the dense row-major walk {dense}+0 of "
                "the iteration space — the producer's block is not the "
                "consumer's block, so the intermediate cannot stay in VMEM")
    if not any(p.allocations for p in chained.stages):
        raise LoweringError(
            "chained plan has no stream allocations (every stage kept the "
            "baseline verdict); chain with force=True for the "
            "runtime-decision path")

    stage_streams = []
    for k, plan in enumerate(chained.stages):
        lowered = [_lower_allocation(a, plan.nest, policy)
                   for a in plan.allocations]
        writes = [s.name for s in lowered
                  if s.stream.direction == Direction.WRITE]
        if writes:
            raise LoweringError(
                f"chain stage {k} carries write streams {writes}; only the "
                "final output (synthesised from the call mode) may leave "
                "the fused region")
        stage_streams.append(tuple(lowered))

    return LoweredChain(chained=chained, policy=policy,
                        grid=_canonical_grid(bounds, policy),
                        stage_in_streams=tuple(stage_streams),
                        schedule=sched)


# --------------------------------------------------------------------------
# End-to-end execution: ssr_call / ssr_chain_call
# --------------------------------------------------------------------------


#: One bound for every lowering-layer cache: the plan/lowered caches below
#: and the built-kernel cache share it, so sizing is tuned in one place and
#: ``clear_caches()`` provably empties the whole layer.
CACHE_MAX = 256


@functools.lru_cache(maxsize=CACHE_MAX)
def _plan_for(nest: LoopNest, num_lanes: int) -> StreamPlan:
    """Plan cache keyed on the nest signature (frozen dataclass hash).

    ``force=True`` is the paper's runtime-decision path: the caller asked to
    *execute* the streamed variant, so allocation must happen regardless of
    the static Eq. (3) verdict (which remains available via ``plan_stats``).
    """
    return ssrify(nest, num_lanes=num_lanes, force=True)


@functools.lru_cache(maxsize=CACHE_MAX)
def plan_stats(nest: LoopNest, num_lanes: int = 2) -> StreamPlan:
    """The static-verdict plan (no force) — Eq. (1)–(3) cost accounting."""
    return ssrify(nest, num_lanes=num_lanes)


@functools.lru_cache(maxsize=CACHE_MAX)
def _chain_for(nests: Tuple[LoopNest, ...],
               num_lanes: Optional[int]) -> ChainedPlan:
    """Chained-plan cache (force=True: the caller asked to execute fused)."""
    return chain(nests, num_lanes=num_lanes, force=True)


@functools.lru_cache(maxsize=CACHE_MAX)
def _dag_for(nests: Tuple[LoopNest, ...],
             num_lanes: Optional[int]) -> ChainDAG:
    """Chain-DAG cache (force=True: the caller asked to execute fused)."""
    return chain_dag(nests, num_lanes=num_lanes, force=True)


@functools.lru_cache(maxsize=CACHE_MAX)
def _lowered_for(plan: StreamPlan, sched: Schedule, nested: bool):
    """Lowered-schedule cache: the pure-Python lowering per (plan, sched)."""
    if nested:
        return lower_nest(plan, schedule=sched)
    return lower_plan(plan, schedule=sched)


@functools.lru_cache(maxsize=CACHE_MAX)
def _lowered_chain_for(chained: ChainedPlan,
                       sched: Schedule) -> LoweredChain:
    return lower_chain(chained, schedule=sched)


#: Every LRU in this layer, for clear/inspection: the plan caches…
_PLAN_CACHES = (_plan_for, plan_stats, _chain_for, _dag_for, _lowered_for,
                _lowered_chain_for)


#: Dispatch-layer instrumentation.  ``builds`` counts jitted pipelines
#: constructed, ``traces`` counts actual jit traces of those pipelines
#: (incremented *inside* the traced function, so it only moves when XLA
#: re-traces), ``calls`` counts ``ssr_call``/``ssr_chain_call`` entries.
#: A second identical call must move ``calls`` only — that is the
#: zero-overhead-dispatch contract the tests assert.  The resilience
#: family: ``fallbacks`` counts schedule lookups abandoned for the
#: default (cache I/O fault before any kernel was built), ``degraded``
#: counts committed tuned schedules that failed to lower/compile/run and
#: were quarantined + re-dispatched on the default schedule.  Healthy
#: runs keep both at zero.
DISPATCH_STATS: Dict[str, int] = {"builds": 0, "traces": 0, "calls": 0,
                                  "fallbacks": 0, "degraded": 0}


def _record_fallback(site: str, error: BaseException, *,
                     from_schedule: str, to_schedule: str,
                     key: Optional[str] = None,
                     counter: str = "fallbacks") -> None:
    """Count + log one degradation step (see ``core/resilience.py``)."""
    DISPATCH_STATS[counter] += 1
    resilience.record_fallback(seam=resilience.classify(error), site=site,
                               error=error, from_schedule=from_schedule,
                               to_schedule=to_schedule, key=key)


def reset_dispatch_stats() -> None:
    for k in DISPATCH_STATS:
        DISPATCH_STATS[k] = 0


def _body_key(body: Callable) -> Any:
    """Stable cache identity for a block body.

    Keying on the function *object* is a footgun: an inline lambda is a
    fresh object every call, so every call silently rebuilds (and re-jits)
    the kernel.  Python compiles the lambda's code object once per source
    location, so ``(code, bound self, closure values, defaults,
    kw-defaults)`` identifies the body's behaviour — two lambdas from the
    same line with equal closures share a kernel, while bound methods of
    *different* instances (per-instance state lives on ``__self__``, not
    in the code or closure) and factories varying a keyword-only default
    do not collide.  Unhashable contents (e.g. captured arrays) fall back
    to object identity: never stale, just uncached across re-creations.
    """
    code = getattr(body, "__code__", None)
    if code is None:
        return body
    cells = getattr(body, "__closure__", None) or ()
    kwdefs = getattr(body, "__kwdefaults__", None) or {}
    try:
        key = (code, getattr(body, "__self__", None),
               tuple(c.cell_contents for c in cells),
               getattr(body, "__defaults__", None) or (),
               tuple(sorted(kwdefs.items())))
        hash(key)
    except (TypeError, ValueError):  # unhashable content / empty cell
        return body
    return key


# …and the built-kernel cache, LRU-bounded by the same CACHE_MAX.  Keys
# include the body's ``_body_key``: inline lambdas hit the cache as long as
# their closure values are hashable and equal (see the footgun note above).
_KERNEL_CACHE_MAX = CACHE_MAX
_kernel_cache: "collections.OrderedDict[Any, Callable]" = \
    collections.OrderedDict()


def _kernel_cache_get(key):
    fn = _kernel_cache.get(key)
    if fn is not None:
        _kernel_cache.move_to_end(key)
    return fn


def _kernel_cache_put(key, fn) -> None:
    _kernel_cache[key] = fn
    _kernel_cache.move_to_end(key)
    while len(_kernel_cache) > _KERNEL_CACHE_MAX:
        _kernel_cache.popitem(last=False)


def clear_caches() -> None:
    """Empty every lowering-layer cache: plans, chains, built kernels."""
    for c in _PLAN_CACHES:
        c.cache_clear()
    _kernel_cache.clear()


def _first_last(grid: Tuple[int, ...]):
    """Predicates for the first/last step of a (possibly multi-dim) grid."""
    from jax.experimental import pallas as pl

    first = pl.program_id(0) == 0
    last = pl.program_id(0) == pl.num_programs(0) - 1
    for k in range(1, len(grid)):
        first = jnp.logical_and(first, pl.program_id(k) == 0)
        last = jnp.logical_and(last, pl.program_id(k) == pl.num_programs(k) - 1)
    return first, last


def _assemble_kernel(grid: Tuple[int, ...], policy: BlockPolicy,
                     in_streams: Sequence[BlockStream],
                     compute: Callable, n_links: int, mode: str,
                     out_dtype, part_shape: Optional[Tuple[int, ...]],
                     interpret: Optional[bool],
                     buffer_depth: int = 2,
                     uniforms: Sequence[jax.ShapeDtypeStruct] = ()) -> Callable:
    """Shared kernel assembler for single-nest and chained plans.

    ``compute(in_refs, link_refs)`` returns the per-step value; ``n_links``
    VMEM scratch blocks hold chained intermediates (zero for plain plans).
    ``uniforms`` appends whole-array operands (weights, tables) delivered
    to every grid step as ONE block — a loop-invariant stream whose block
    *is* the array, fetched once (the pipelined emitter already special-
    cases invariant streams).  Their refs trail the streamed inputs in
    ``in_refs``.
    Reduce mode accumulates into a *vector* accumulator when the partial is
    a multi-element 2-D block — the whole (rows, lanes) vreg adds every
    step, folded to the scalar exactly once on the last step — and keeps
    the legacy scalar ``(1, 1)`` accumulator for scalar partials.  Map-mode
    grid axes are independent and declared ``parallel``; only reduce mode
    needs sequential (``arbitrary``) semantics for its carried accumulator.
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    if uniforms:
        def _whole(nd: int):
            return lambda *_g: (0,) * nd

        in_streams = list(in_streams) + [
            BlockStream(tuple(u.shape), _whole(len(u.shape)),
                        Direction.READ, name=f"_uniform{i}")
            for i, u in enumerate(uniforms)]
        if isinstance(buffer_depth, tuple):
            buffer_depth = buffer_depth + (2,) * len(uniforms)
    n_in = len(in_streams)
    link_scratch = [pltpu.VMEM(policy.block_shape, out_dtype)
                    for _ in range(n_links)]

    if mode == "reduce":
        vector_acc = (part_shape is not None and len(part_shape) == 2
                      and math.prod(part_shape) > 1)
        acc_shape = tuple(part_shape) if vector_acc else (1, 1)

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            links = refs[n_in + 1:n_in + 1 + n_links]
            acc_ref = refs[n_in + 1 + n_links]
            first, last = _first_last(grid)

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            part = jnp.asarray(compute(in_refs, links), out_dtype)
            acc_ref[...] += part.reshape(acc_shape)

            @pl.when(last)
            def _write():
                if vector_acc:
                    o_ref[...] = jnp.sum(acc_ref[...]).reshape(1, 1)
                else:
                    o_ref[...] = acc_ref[...]

        out_streams = [BlockStream((1, 1), lambda *g: (0, 0),
                                   Direction.WRITE, name="acc")]
        out_shapes = [jax.ShapeDtypeStruct((1, 1), out_dtype)]
        scratch = link_scratch + [pltpu.VMEM(acc_shape, out_dtype)]
        semantics = ("arbitrary",) * len(grid)
    elif mode == "map":
        steps = math.prod(grid)
        # Output walks the grid dense row-major: one block per step.
        place = _dense_strides(grid)

        def out_map(*g):
            row = g[0] * place[0]
            for k in range(1, len(g)):
                row = row + g[k] * place[k]
            return (row, 0)

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            links = refs[n_in + 1:n_in + 1 + n_links]
            o_ref[...] = jnp.asarray(
                compute(in_refs, links), out_dtype
            ).reshape(policy.block_shape)

        out_streams = [BlockStream(policy.block_shape, out_map,
                                   Direction.WRITE, name="out")]
        out_shapes = [jax.ShapeDtypeStruct(
            (steps * policy.rows, policy.lanes), out_dtype)]
        scratch = list(link_scratch)
        semantics = ("parallel",) * len(grid)
    else:
        raise ValueError(f"unknown ssr_call mode {mode!r}")

    return ssr_pallas(
        kernel, grid=grid,
        in_streams=list(in_streams), out_streams=out_streams,
        out_shapes=out_shapes, scratch_shapes=scratch,
        interpret=interpret,
        dimension_semantics=semantics,
        buffer_depth=buffer_depth,
    )


def _probe_part_shape(fn: Callable, in_shapes: Sequence[Tuple[int, ...]],
                      dtype) -> Tuple[int, ...]:
    """Trace ``fn`` on abstract blocks to learn the partial's shape."""
    structs = [jax.ShapeDtypeStruct(s, dtype) for s in in_shapes]
    return tuple(jax.eval_shape(lambda *xs: fn(*xs), *structs).shape)


def _build_kernel(lowered: LoweredPlan, body: Callable, mode: str,
                  out_dtype, interpret: Optional[bool],
                  uniforms: Sequence[jax.ShapeDtypeStruct] = ()) -> Callable:
    """Wrap a block-level ``body`` into a full ssr_pallas kernel.

    ``body(*stream_blocks, *uniform_arrays)`` — uniform refs trail the
    streamed inputs in ``in_refs``, so the pass-through read below hands
    the body exactly that order.
    """
    part_shape = None
    if mode == "reduce":
        part_shape = _probe_part_shape(
            body, [s.stream.block_shape for s in lowered.in_streams]
            + [tuple(u.shape) for u in uniforms],
            out_dtype)

    def compute(in_refs, _links):
        return body(*[r[...] for r in in_refs])

    return _assemble_kernel(lowered.grid, lowered.policy,
                            [s.stream for s in lowered.in_streams],
                            compute, 0, mode, out_dtype, part_shape,
                            interpret,
                            _depths_for(lowered.schedule,
                                        len(lowered.in_streams)),
                            uniforms=uniforms)


def _table_view_shape(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """The ≥2-D view a gather table occupies in VMEM (rank-1 gains a row)."""
    return shape if len(shape) >= 2 else (1,) + tuple(shape)


def _gather_block(pl, gather: IndirectGather, idx_block, table_ref):
    """Materialise one indirect ref's block from its VMEM-resident table.

    ``idx_block`` is the index stream's dense block for this grid step; each
    affine additive level of the gather prepends one block dimension (the
    level's tile extent), so the body sees a
    ``(tile_l0, ..., *idx_block.shape)`` gather result.
    """
    addr = idx_block.astype(jnp.int32) * gather.scale + gather.offset
    for p, tile, coeff in zip(reversed(gather.grid_pos),
                              reversed(gather.tiles),
                              reversed(gather.coeffs)):
        intra = jax.lax.broadcasted_iota(jnp.int32, (tile,) + addr.shape, 0)
        addr = addr[None] + coeff * (pl.program_id(p) * tile + intra)
    table = table_ref[...].reshape(-1)
    # Padded grid steps can address past the table; clip keeps them in
    # range — their products land only in trimmed output padding.
    return jnp.take(table, addr.reshape(-1), mode="clip").reshape(addr.shape)


def _halo_widen(parts, halo: HaloRead):
    """Stitch a HaloRead's ``2**k`` shifted blocks into one widened block.

    Pass ``j`` pairs blocks differing only in halo bit ``j`` (adjacent
    after earlier passes), concatenates each pair along that level's
    block axis and keeps the first ``tile + w - 1`` columns — the
    in-kernel slice taps of DESIGN.md §13.
    """
    for ax, t, w in zip(halo.axes, halo.tiles, halo.windows):
        nxt = []
        for m in range(0, len(parts), 2):
            cat = jnp.concatenate([parts[m], parts[m + 1]], axis=ax)
            nxt.append(jax.lax.slice_in_dim(cat, 0, t + w - 1, axis=ax))
        parts = nxt
    return parts[0]


def _build_nest_kernel(lowered: LoweredNest, body: Callable,
                       out_dtype, interpret: Optional[bool],
                       tables: Sequence[jax.ShapeDtypeStruct] = ()
                       ) -> Callable:
    """Wrap a block-level ``body`` into a level-mapped ssr_pallas kernel.

    ``body(*read_blocks)`` returns the output block's partial for one grid
    step.  With contraction axes the partial accumulates into a VMEM
    scratch block: zeroed on the first visit of the contraction axes,
    drained to the write stream on the last — the paper's accumulator
    register at block granularity (GEMM's ``C += A·B`` k-walk).  Without
    contraction axes every step owns its output block and writes directly.

    With ``lowered.gathers``, ``tables`` carries one ShapeDtypeStruct per
    gather (the whole indirectly addressed operand, normalised to ≥2-D);
    each rides as a revisited invariant block and the body receives the
    gathered blocks appended after the streamed ones.
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    n_in = len(lowered.in_streams)
    gathers = lowered.gathers
    assert len(tables) == len(gathers), "one table operand per gather"
    zaxes = lowered.contraction_axes
    acc_shape = lowered.out_stream.stream.block_shape

    # Halo refs arrive as 2**k shifted copies in in_streams ("raw" slots);
    # the body sees ONE widened block per ref, stitched in-kernel.
    halos = lowered.halos
    halo_at = {h.slots[0]: h for h in halos}
    halo_skip = {s for h in halos for s in h.slots[1:]}

    def _blocks(in_refs, tab_refs):
        raw = [r[...] for r in in_refs]
        blocks = []
        for k, b in enumerate(raw):
            if k in halo_skip:
                continue
            h = halo_at.get(k)
            blocks.append(b if h is None
                          else _halo_widen([raw[s] for s in h.slots], h))
        blocks += [_gather_block(pl, g, raw[g.index_pos], t)
                   for g, t in zip(gathers, tab_refs)]
        return blocks

    # The accumulator defaults to the f32 compute width (the MXU/VPU
    # accumulation dtype — the repo-wide policy), regardless of the storage
    # out_dtype: accumulating k-tile partials in bf16 would compound
    # rounding across grid steps.  The cast to out_dtype happens once, at
    # the drain.  The schedule may widen it (e.g. f64 on CPU interpret
    # runs) — a searched knob like the rest of the geometry.
    acc_dtype = jnp.dtype(lowered.schedule.acc_dtype)

    if lowered.rescale:
        # Online-rescaled accumulator (flash-attention recurrence): the
        # kernel carries a (max, sum, acc) triple in VMEM across the
        # contraction walk.  ``body(*blocks, offs)`` returns the raw score
        # block ``s`` (rows × contraction-tile, already scaled AND masked —
        # ``offs`` gives the per-level global offsets for the mask iotas)
        # and the value block ``v`` (contraction-tile × lanes); the kernel
        # owns the m/l rescaling:  m' = max(m, rowmax(s)); α = e^{m−m'};
        # l' = αl + Σ e^{s−m'}; acc' = α·acc + e^{s−m'}·v; drain acc/l.
        z = zaxes[0]
        d = len(lowered.tiles)
        pos_of = {lvl: k for k, lvl in enumerate(lowered.axis_order)}
        n_rows = acc_shape[0]

        def kernel(*refs):
            in_refs = refs[:n_in]
            tab_refs = refs[n_in:n_in + len(gathers)]
            o_ref = refs[n_in + len(gathers)]
            m_ref = refs[n_in + len(gathers) + 1]
            l_ref = refs[n_in + len(gathers) + 2]
            acc_ref = refs[n_in + len(gathers) + 3]
            first = pl.program_id(z) == 0
            last = pl.program_id(z) == pl.num_programs(z) - 1

            @pl.when(first)
            def _init():
                m_ref[...] = jnp.full_like(m_ref, -1e30)
                l_ref[...] = jnp.zeros_like(l_ref)
                acc_ref[...] = jnp.zeros_like(acc_ref)

            offs = tuple(pl.program_id(pos_of[l]) * lowered.tiles[l]
                         for l in range(d))
            s, v = body(*_blocks(in_refs, tab_refs), offs)
            s = jnp.asarray(s, acc_dtype)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1,
                                                      keepdims=True)
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
                p, jnp.asarray(v, acc_dtype),
                (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype)
            m_ref[...] = m_new

            @pl.when(last)
            def _drain():
                o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                              ).astype(o_ref.dtype)

        scratch = [pltpu.VMEM((n_rows, 1), acc_dtype),
                   pltpu.VMEM((n_rows, 1), acc_dtype),
                   pltpu.VMEM(acc_shape, acc_dtype)]
    elif zaxes:
        def kernel(*refs):
            in_refs = refs[:n_in]
            tab_refs = refs[n_in:n_in + len(gathers)]
            o_ref = refs[n_in + len(gathers)]
            acc_ref = refs[n_in + len(gathers) + 1]
            first = pl.program_id(zaxes[0]) == 0
            last = pl.program_id(zaxes[0]) == pl.num_programs(zaxes[0]) - 1
            for z in zaxes[1:]:
                first = jnp.logical_and(first, pl.program_id(z) == 0)
                last = jnp.logical_and(
                    last, pl.program_id(z) == pl.num_programs(z) - 1)

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            part = jnp.asarray(body(*_blocks(in_refs, tab_refs)), acc_dtype)
            acc_ref[...] += part.reshape(acc_shape)

            @pl.when(last)
            def _drain():
                o_ref[...] = acc_ref[...].astype(o_ref.dtype)

        scratch = [pltpu.VMEM(acc_shape, acc_dtype)]
    else:
        def kernel(*refs):
            in_refs = refs[:n_in]
            tab_refs = refs[n_in:n_in + len(gathers)]
            o_ref = refs[n_in + len(gathers)]
            o_ref[...] = jnp.asarray(
                body(*_blocks(in_refs, tab_refs)), out_dtype
            ).reshape(acc_shape)

        scratch = []

    # Gather tables ride whole as revisited invariant blocks: every grid
    # step sees the same (full) table, exactly like the flat path's
    # uniforms — the "dense index block in VMEM" of the indirection papers.
    table_streams = [
        BlockStream(tuple(t.shape), lambda *_g, _nd=len(t.shape): (0,) * _nd,
                    direction=Direction.READ, name=g.name)
        for g, t in zip(gathers, tables)]

    depths = _depths_for(lowered.schedule, len(lowered.in_streams))
    if gathers:
        if isinstance(depths, int):
            depths = (depths,) * len(lowered.in_streams)
        depths = tuple(depths) + (2,) * len(gathers)

    return ssr_pallas(
        kernel, grid=lowered.grid,
        in_streams=[s.stream for s in lowered.in_streams] + table_streams,
        out_streams=[lowered.out_stream.stream],
        out_shapes=[jax.ShapeDtypeStruct(lowered.out_stream.layout_shape,
                                         out_dtype)],
        scratch_shapes=scratch,
        interpret=interpret,
        dimension_semantics=lowered.semantics,
        buffer_depth=depths,
    )


def _trim_nest_output(out: jax.Array, lowered: LoweredNest) -> jax.Array:
    """Drop per-level padding; return the output's logical nd array."""
    ns = lowered.out_stream
    if not ns.levels:
        return out[0, 0]
    if len(ns.levels) == 1:
        return out[0, :ns.logical_shape[0]]
    return out[tuple(slice(0, e) for e in ns.logical_shape)]


def _chain_stage_shapes(lowered: LoweredChain, bodies: Sequence[Callable],
                        out_dtype,
                        require_final_block: bool = False) -> Tuple[int, ...]:
    """Shape-check every stage and return the final partial's shape.

    Each linked intermediate must fill exactly one policy block — that is
    the VMEM scratch the next stage reads.  ``require_final_block`` extends
    the check to the last stage (map mode, where the value feeds the dense
    write stream).
    """
    policy = lowered.policy
    cur: Any = None
    for k, stage in enumerate(lowered.stage_in_streams):
        ins = [jax.ShapeDtypeStruct(s.stream.block_shape, out_dtype)
               for s in stage]
        if k == 0:
            cur = jax.eval_shape(lambda *xs: bodies[0](*xs), *ins)
        else:
            carried = jax.ShapeDtypeStruct(policy.block_shape, out_dtype)
            cur = jax.eval_shape(
                lambda c, *xs, _b=bodies[k]: _b(c, *xs), carried, *ins)
        must_block = k < len(bodies) - 1 or require_final_block
        if must_block and math.prod(cur.shape) != policy.block_elems:
            what = ("a linked intermediate" if k < len(bodies) - 1
                    else "the map-mode output")
            raise LoweringError(
                f"chain stage {k} body returns shape {cur.shape} "
                f"({math.prod(cur.shape)} elements); {what} "
                f"must fill one {policy.block_shape} VMEM block")
    return tuple(cur.shape)


def _build_chain_kernel(lowered: LoweredChain, bodies: Sequence[Callable],
                        mode: str, out_dtype,
                        interpret: Optional[bool]) -> Callable:
    """Fuse all stage bodies into ONE Pallas kernel.

    Per grid step: stage 0 computes its block from its read streams; each
    intermediate is written to a VMEM scratch block (never HBM) and read
    back by the next stage's body the same step; the final stage's value
    feeds the usual map/reduce epilogue.
    """
    policy = lowered.policy
    counts = [len(stage) for stage in lowered.stage_in_streams]
    offsets = [0]
    for c in counts[:-1]:
        offsets.append(offsets[-1] + c)
    n_links = len(lowered.chained.links)

    final_shape = _chain_stage_shapes(lowered, bodies, out_dtype,
                                      require_final_block=(mode == "map"))
    part_shape = final_shape if mode == "reduce" else None

    def compute(in_refs, link_refs):
        vals = [r[...] for r in in_refs[:counts[0]]]
        cur = bodies[0](*vals)
        for k in range(1, len(bodies)):
            s_ref = link_refs[k - 1]
            s_ref[...] = jnp.asarray(cur, out_dtype).reshape(
                policy.block_shape)
            args = [r[...] for r in
                    in_refs[offsets[k]:offsets[k] + counts[k]]]
            cur = bodies[k](s_ref[...], *args)
        return cur

    return _assemble_kernel(lowered.grid, policy,
                            [s.stream for s in lowered.in_streams],
                            compute, n_links, mode, out_dtype, part_shape,
                            interpret,
                            _depths_for(lowered.schedule,
                                        len(lowered.in_streams)))


def _dag_slots(dag: ChainDAG) -> Tuple[Dict[str, int], int]:
    """Refcounted VMEM scratch-slot assignment for a ChainDAG's values.

    Walking the stages in (topological) order: a produced block takes a
    slot; once its *last* consumer stage has read it the slot returns to
    the free list and the next producer reuses it.  Reuse within one stage
    is safe because bodies receive block *values* (``ref[...]`` copies) —
    every read of a dying slot happens before the producing write.  Returns
    ``(slot_of, n_slots)``: the per-intermediate slot index and the peak
    number of live blocks (what the kernel actually allocates — a diamond
    needs 2 slots, not one per edge).
    """
    slot_of: Dict[str, int] = {}
    free: list = []
    n_slots = 0
    n = len(dag.stages)
    for k in range(n):
        for name in sorted({e.name for e in dag.in_edges(k)}):
            if dag.last_consumer(name) == k:
                free.append(slot_of[name])
        if k == n - 1:
            continue        # the final value exits via the call epilogue
        produced = sorted({e.name for e in dag.out_edges(k)})
        if len(produced) != 1:
            raise LoweringError(
                f"dag stage {k} produces intermediates {produced}; a stage "
                "body returns one block, so each non-final stage must "
                "write exactly one intermediate")
        if free:
            slot_of[produced[0]] = free.pop()
        else:
            slot_of[produced[0]] = n_slots
            n_slots += 1
    return slot_of, n_slots


def _dag_stage_shapes(lowered: LoweredChain, bodies: Sequence[Callable],
                      out_dtype, require_final_block: bool = False,
                      uniforms: Sequence[jax.ShapeDtypeStruct] = ()
                      ) -> Tuple[int, ...]:
    """Shape-check every DAG stage and return the final partial's shape.

    Stage ``k``'s body receives one carried block per incoming edge (in
    ``ChainDAG.in_edges`` order) followed by its external stream blocks,
    then every uniform array; every non-final stage must return exactly
    one policy block — the VMEM scratch its consumers read.
    """
    policy = lowered.policy
    dag = lowered.chained
    cur: Any = None
    for k, stage in enumerate(lowered.stage_in_streams):
        carried = [jax.ShapeDtypeStruct(policy.block_shape, out_dtype)
                   for _ in dag.in_edges(k)]
        ins = [jax.ShapeDtypeStruct(s.stream.block_shape, out_dtype)
               for s in stage]
        cur = jax.eval_shape(lambda *xs, _b=bodies[k]: _b(*xs),
                             *carried, *ins, *uniforms)
        must_block = k < len(bodies) - 1 or require_final_block
        if must_block and math.prod(cur.shape) != policy.block_elems:
            what = ("a dag intermediate" if k < len(bodies) - 1
                    else "the map-mode output")
            raise LoweringError(
                f"dag stage {k} body returns shape {cur.shape} "
                f"({math.prod(cur.shape)} elements); {what} "
                f"must fill one {policy.block_shape} VMEM block")
    return tuple(cur.shape)


def _build_dag_kernel(lowered: LoweredChain, bodies: Sequence[Callable],
                      mode: str, out_dtype, interpret: Optional[bool],
                      uniforms: Sequence[jax.ShapeDtypeStruct] = ()
                      ) -> Callable:
    """Fuse a ChainDAG's stage bodies into ONE Pallas kernel.

    Topologically-ordered stage emission: per grid step each stage reads
    its carried blocks from the refcounted VMEM scratch slots
    (:func:`_dag_slots`), computes, and (when non-final) writes its product
    block into its own slot.  A multi-consumer intermediate is written once
    and read by every consumer stage from the same slot — the store and all
    K loads that an unfused composition would pay never touch HBM.
    """
    policy = lowered.policy
    dag = lowered.chained
    counts = [len(stage) for stage in lowered.stage_in_streams]
    offsets = [0]
    for c in counts[:-1]:
        offsets.append(offsets[-1] + c)
    slot_of, n_slots = _dag_slots(dag)
    n_stream = len(lowered.in_streams)

    final_shape = _dag_stage_shapes(lowered, bodies, out_dtype,
                                    require_final_block=(mode == "map"),
                                    uniforms=uniforms)
    part_shape = final_shape if mode == "reduce" else None

    def compute(in_refs, link_refs):
        uni = [r[...] for r in in_refs[n_stream:]]
        cur: Any = None
        for k in range(len(bodies)):
            carried = [link_refs[slot_of[e.name]][...]
                       for e in dag.in_edges(k)]
            ext = [r[...] for r in
                   in_refs[offsets[k]:offsets[k] + counts[k]]]
            cur = bodies[k](*carried, *ext, *uni)
            if k < len(bodies) - 1:
                name = dag.out_edges(k)[0].name
                link_refs[slot_of[name]][...] = jnp.asarray(
                    cur, out_dtype).reshape(policy.block_shape)
        return cur

    return _assemble_kernel(lowered.grid, policy,
                            [s.stream for s in lowered.in_streams],
                            compute, n_slots, mode, out_dtype, part_shape,
                            interpret,
                            _depths_for(lowered.schedule,
                                        len(lowered.in_streams)),
                            uniforms=uniforms)


def _uniform_items(uniforms: Optional[Dict[str, jax.Array]]
                   ) -> Tuple[Tuple[str, jax.Array], ...]:
    """Normalise a uniforms dict to ``((name, array), ...)`` in dict order.

    1-D arrays gain a leading singleton (Pallas blocks are at least 2-D);
    scalars are rejected — a Python float in the body's closure is already
    hashable, cacheable, and free.
    """
    if not uniforms:
        return ()
    items = []
    for name, arr in uniforms.items():
        a = jnp.asarray(arr)
        if a.ndim == 0:
            raise ValueError(
                f"uniform {name!r} is a scalar; close over the Python "
                "value instead — scalar closures hash and cache fine")
        if a.ndim == 1:
            a = a.reshape(1, -1)
        items.append((name, a))
    return tuple(items)


def _uniform_sig(items: Tuple[Tuple[str, jax.Array], ...]) -> Tuple:
    return tuple((nm, tuple(a.shape), str(a.dtype)) for nm, a in items)


def ssr_call(nest: LoopNest, body: Callable[..., jax.Array],
             operands: Dict[str, jax.Array], *,
             mode: str = "reduce",
             out_dtype=jnp.float32,
             policy: BlockPolicy = DEFAULT_POLICY,
             schedule: Optional[Schedule] = None,
             num_lanes: Optional[int] = None,
             interpret: Optional[bool] = None,
             uniforms: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
    """Execute a :class:`LoopNest` as a streamed Pallas kernel.

    ``body(*blocks)`` is the pure compute region: it receives one VMEM block
    per allocated read stream (in allocation order — deepest-first, i.e. the
    order ``plan.allocations`` lists them) and returns

    * ``mode="reduce"`` — a partial accumulated across all grid steps (the
      Fig. 4 ``%x`` accumulator register).  A *block-shaped* partial (e.g.
      ``lambda a, b: a * b``) uses a vectorised (rows, lanes) accumulator
      folded to the scalar once on the last step — the whole VPU vreg adds
      every step; a scalar partial (e.g. ``jnp.sum(a * b)``) keeps the
      legacy (1, 1) accumulator;
    * ``mode="map"`` — one output block, written to a dense write stream
      walking the grid (the output AGU); the result is trimmed to the
      nest's iteration count.

    A nest with an explicit output WRITE ref (e.g. :func:`compiler.gemm_nest`)
    takes the **level-mapped** path instead: ``mode`` is ignored, the body
    returns one output-block partial per grid step, contraction axes
    accumulate in VMEM (see :func:`lower_nest`), and the result comes back
    in the output ref's logical nd shape (``(m, n)`` for GEMM).

    ``operands`` maps :class:`MemRef` names to arrays.  Zero padding is
    applied per stream, so bodies must be padding-neutral for ``reduce``
    and for contraction axes (sum/dot-style bodies are).  Plans are cached
    on the nest signature, built kernels on (nest, schedule, mode, body
    key, dtypes, interpret) — see :func:`_body_key`: inline lambdas hit
    the cache as long as their closure values are hashable and equal.

    **Zero-overhead dispatch**: prepare (pad/reshape) → engine → trim fuse
    into ONE cached jitted callable, so the padding traffic compiles into
    the same XLA program as the Pallas kernel instead of dispatching
    eagerly per call.  A repeated call with the same (nest, schedule,
    shapes, body) is a dict hit plus one jitted-function invocation.

    **Transparent tuning**: with no explicit ``schedule`` (and the default
    ``policy``), the autotuner's persistent cache is consulted — every
    entry point (direct ``ssr_call``, ``NestKernel``, ``cluster_call``)
    resolves the same winner for the same problem, so they stay
    bit-identical to each other before and after a tuner commit.
    """
    tuned_key: Optional[str] = None
    if schedule is None and policy is DEFAULT_POLICY:
        from . import autotune as _autotune

        try:
            schedule = _autotune.lookup(nest, operands, mode=mode,
                                        out_dtype=str(jnp.dtype(out_dtype)))
        except resilience.fallback_error_types() as e:
            _record_fallback("ssr_call", e, from_schedule="tuned-lookup",
                             to_schedule="default")
            schedule = DEFAULT_SCHEDULE
        else:
            if schedule != DEFAULT_SCHEDULE:
                tuned_key = _autotune.cache_key(
                    nest, operands, mode=mode,
                    out_dtype=str(jnp.dtype(out_dtype)))
    num_lanes = nest_analysis.auto_lanes(nest, num_lanes)
    plan = _plan_for(nest, num_lanes)
    has_output = any(r.kind == Direction.WRITE for r in nest.refs)
    if has_output:
        mode = "nest"          # the output ref, not the mode, shapes the call
    uni = _uniform_items(uniforms)
    if uni and has_output:
        raise LoweringError(
            "uniform operands are not supported on the level-mapped "
            "(explicit WRITE ref) path; use a map/reduce nest")
    DISPATCH_STATS["calls"] += 1

    def _dispatch(sched: Schedule) -> jax.Array:
        lowered = _lowered_for(plan, sched, has_output)
        gathers = lowered.gathers if has_output else ()
        missing = [s.name for s in lowered.in_streams
                   if s.name not in operands]
        missing += [g.name for g in gathers if g.name not in operands]
        if missing:
            raise ValueError(f"missing operands for streams {missing}")
        arrays = [operands[s.name] for s in lowered.in_streams]
        # Gather tables travel after the streamed operands, normalised to
        # the ≥2-D VMEM view their invariant block addresses.
        tables = [jnp.reshape(operands[g.name],
                              _table_view_shape(tuple(operands[g.name]
                                                      .shape)))
                  for g in gathers]
        key = (nest, sched, mode, _body_key(body), str(jnp.dtype(out_dtype)),
               tuple((tuple(a.shape), str(a.dtype)) for a in arrays + tables),
               _uniform_sig(uni), num_lanes, interpret)
        fn = _kernel_cache_get(key)
        if fn is None:
            if has_output:
                kernel = _build_nest_kernel(
                    lowered, body, jnp.dtype(out_dtype), interpret,
                    tables=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                                 for a in tables))
            else:
                kernel = _build_kernel(
                    lowered, body, mode, jnp.dtype(out_dtype), interpret,
                    uniforms=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                                   for _, a in uni))

            def pipeline(*arrs, _lowered=lowered, _kernel=kernel,
                         _sched=sched):
                DISPATCH_STATS["traces"] += 1   # moves only while tracing
                ns = len(_lowered.in_streams)
                prepared = [s.prepare(a)
                            for s, a in zip(_lowered.in_streams, arrs[:ns])]
                out = _kernel(*prepared, *arrs[ns:])
                if has_output:
                    return _trim_nest_output(out, _lowered)
                return _trim_output(out, nest.bounds, mode, _sched.policy)

            resilience.inject("compile")
            fn = jax.jit(pipeline)
            DISPATCH_STATS["builds"] += 1
            _kernel_cache_put(key, fn)
        return fn(*arrays, *tables, *[a for _, a in uni])

    if tuned_key is None:
        return _dispatch(_resolve_schedule(policy, schedule))
    try:
        return _dispatch(_resolve_schedule(policy, schedule))
    except resilience.fallback_error_types() as e:
        from . import autotune as _autotune

        _autotune.global_cache().quarantine(tuned_key)
        _record_fallback("ssr_call", e, from_schedule="tuned",
                         to_schedule="default", key=tuned_key,
                         counter="degraded")
        return _dispatch(DEFAULT_SCHEDULE)


def _trim_output(out: jax.Array, bounds: Tuple[int, ...], mode: str,
                 policy: BlockPolicy) -> jax.Array:
    if mode == "reduce":
        return out[0, 0]
    # map: drop the inner-level padding (it interleaves for d > 1 nests),
    # then flatten back to one value per nest iteration.
    padded_inner = _inner_steps_of(bounds, policy) * policy.block_elems
    out_nd = out.reshape(*bounds[:-1], padded_inner)
    return out_nd[..., :bounds[-1]].reshape(-1)


def ssr_chain_call(nests: Sequence[LoopNest],
                   bodies: Sequence[Callable[..., jax.Array]],
                   operands: Dict[str, jax.Array], *,
                   mode: str = "map",
                   out_dtype=jnp.float32,
                   policy: BlockPolicy = DEFAULT_POLICY,
                   schedule: Optional[Schedule] = None,
                   num_lanes: Optional[int] = None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Execute a producer→consumer chain of nests as ONE Pallas kernel.

    ``nests[k]`` and ``nests[k+1]`` must be chainable (see
    :func:`repro.core.compiler.chain`): the producer's WRITE ref unifies
    with the consumer's READ ref over one shared iteration space.  The
    intermediates live in VMEM scratch blocks — they are never stored to
    (or re-loaded from) HBM, which is the whole point.

    ``bodies[0](*stage0_blocks)`` computes the first intermediate;
    ``bodies[k](carried_block, *stagek_blocks)`` receives the previous
    stage's block first.  ``mode`` applies to the *final* stage, with the
    same contract as :func:`ssr_call` — including the vectorised reduce
    accumulator when the last body returns a block-shaped partial.  Reduce
    bodies must be padding-neutral at every stage: the padded tail flows
    through the whole chain.
    """
    nests = tuple(nests)
    bodies = tuple(bodies)
    if len(bodies) != len(nests):
        raise ValueError(
            f"need one body per nest, got {len(bodies)} bodies for "
            f"{len(nests)} nests")
    tuned_key: Optional[str] = None
    if schedule is None and policy is DEFAULT_POLICY:
        # chains key on their stage-0 nest + the full operand signature,
        # matching the cluster layer's per-core lookup convention
        from . import autotune as _autotune

        try:
            schedule = _autotune.lookup(nests[0], operands, mode=mode,
                                        out_dtype=str(jnp.dtype(out_dtype)))
        except resilience.fallback_error_types() as e:
            _record_fallback("ssr_chain_call", e,
                             from_schedule="tuned-lookup",
                             to_schedule="default")
            schedule = DEFAULT_SCHEDULE
        else:
            if schedule != DEFAULT_SCHEDULE:
                tuned_key = _autotune.cache_key(
                    nests[0], operands, mode=mode,
                    out_dtype=str(jnp.dtype(out_dtype)))
    chained = _chain_for(nests, num_lanes)
    DISPATCH_STATS["calls"] += 1

    def _dispatch(sched: Schedule) -> jax.Array:
        lowered = _lowered_chain_for(chained, sched)
        flat = lowered.in_streams
        missing = sorted({s.name for s in flat} - set(operands))
        if missing:
            raise ValueError(f"missing operands for streams {missing}")
        arrays = [operands[s.name] for s in flat]
        key = ("chain", nests, sched, mode,
               tuple(_body_key(b) for b in bodies), str(jnp.dtype(out_dtype)),
               tuple((tuple(a.shape), str(a.dtype)) for a in arrays),
               num_lanes, interpret)
        fn = _kernel_cache_get(key)
        if fn is None:
            kernel = _build_chain_kernel(lowered, bodies, mode,
                                         jnp.dtype(out_dtype), interpret)

            def pipeline(*arrs, _lowered=lowered, _kernel=kernel,
                         _sched=sched):
                DISPATCH_STATS["traces"] += 1   # moves only while tracing
                prepared = [s.prepare(a)
                            for s, a in zip(_lowered.in_streams, arrs)]
                out = _kernel(*prepared)
                return _trim_output(out, chained.bounds, mode, _sched.policy)

            resilience.inject("compile")
            fn = jax.jit(pipeline)
            DISPATCH_STATS["builds"] += 1
            _kernel_cache_put(key, fn)
        return fn(*arrays)

    if tuned_key is None:
        return _dispatch(_resolve_schedule(policy, schedule))
    try:
        return _dispatch(_resolve_schedule(policy, schedule))
    except resilience.fallback_error_types() as e:
        from . import autotune as _autotune

        _autotune.global_cache().quarantine(tuned_key)
        _record_fallback("ssr_chain_call", e, from_schedule="tuned",
                         to_schedule="default", key=tuned_key,
                         counter="degraded")
        return _dispatch(DEFAULT_SCHEDULE)


def _dag_components(dag: ChainDAG,
                    cut: frozenset) -> Tuple[Tuple[int, ...], ...]:
    """Connected stage components over the *non-cut* edges, ordered by
    their maximum stage index — a valid topological order of the
    partition (every cut edge points from one component's exit to a
    higher-indexed stage of a later component)."""
    parent = list(range(len(dag.stages)))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i, e in enumerate(dag.edges):
        if i not in cut:
            parent[find(e.producer_stage)] = find(e.consumer_stage)
    groups: Dict[int, list] = {}
    for s in range(len(dag.stages)):
        groups.setdefault(find(s), []).append(s)
    return tuple(sorted((tuple(sorted(g)) for g in groups.values()),
                        key=max))


def _component_exit(dag: ChainDAG, comp: Tuple[int, ...],
                    cut: frozenset) -> int:
    """The component's unique exit stage — the one whose value leaves.

    A value leaves through a cut out-edge or by being the whole DAG's
    final stage; a legal cut gives every component exactly one such stage
    (otherwise more than one HBM buffer would have to exit a single fused
    kernel, which the map/reduce epilogue cannot express).
    """
    inside = set(comp)
    exits = set()
    for i, e in enumerate(dag.edges):
        if i in cut and e.producer_stage in inside:
            exits.add(e.producer_stage)
    if len(dag.stages) - 1 in inside:
        exits.add(len(dag.stages) - 1)
    if len(exits) != 1:
        raise LoweringError(
            f"cut {tuple(sorted(cut))} gives component {comp} exit stages "
            f"{sorted(exits)}; a legal cut leaves each fused component "
            "exactly one stage whose value exits the kernel")
    return exits.pop()


def _reorder_body(body: Callable, callee_names: Sequence[str],
                  want_names: Sequence[str], where: str) -> Callable:
    """Adapt a DAG-convention body to a callee's block-argument order.

    ``callee_names`` is the order the executing kernel passes blocks in;
    ``want_names`` is the order ``body`` expects (incoming-edge blocks in
    ``in_edges`` order, then the stage's external streams in allocation
    order).  Cut edges turn carried blocks into external operand streams,
    so the two orders differ per partition — the adapter permutes by name.
    """
    pos = {nm: i for i, nm in enumerate(callee_names)}
    missing = [nm for nm in want_names if nm not in pos]
    if missing or len(callee_names) != len(want_names):
        raise LoweringError(
            f"{where}: body expects blocks {list(want_names)} but the "
            f"partitioned kernel streams {list(callee_names)}")
    perm = tuple(pos[nm] for nm in want_names)
    if perm == tuple(range(len(perm))):
        return body
    # trailing args beyond the named blocks (uniform arrays, appended
    # after every stage's streams) pass through unpermuted
    return lambda *blocks, _b=body, _p=perm: _b(
        *(blocks[i] for i in _p), *blocks[len(_p):])


def _stage_arg_names(dag: ChainDAG, k: int) -> list:
    """Stage ``k``'s body-argument names in the fused-DAG convention."""
    return ([e.name for e in dag.in_edges(k)]
            + [a.ref.name for a in dag.stages[k].allocations])


def _dag_partition_call(dag: ChainDAG, nests: Tuple[LoopNest, ...],
                        bodies: Tuple[Callable, ...],
                        operands: Dict[str, jax.Array], sched: Schedule, *,
                        mode: str, out_dtype, num_lanes: Optional[int],
                        interpret: Optional[bool],
                        uniforms: Optional[Dict[str, jax.Array]] = None
                        ) -> jax.Array:
    """Execute a ChainDAG under a committed cut: one kernel per component.

    Each cut edge materialises its intermediate as a flat HBM array (a
    map-mode output) that downstream components stream back in as a plain
    dense operand; within a component the DAG fuses as usual.  Components
    run in topological order, so by the time one launches every cut value
    it reads already exists.
    """
    cut = frozenset(sched.cut_edges or ())
    for i in cut:
        if not 0 <= i < len(dag.edges):
            raise LoweringError(
                f"schedule.cut_edges index {i} is out of range for a dag "
                f"with {len(dag.edges)} edges")
    # Sub-calls are separate kernels with their own stream counts; the
    # committed geometry/depth carries over, the partition fields do not.
    sub_sched = dataclasses.replace(sched, cut_edges=None,
                                    stream_depths=None)
    env = dict(operands)
    n = len(nests)
    result: Optional[jax.Array] = None
    for comp in _dag_components(dag, cut):
        exit_stage = _component_exit(dag, comp, cut)
        final = (n - 1) in comp
        comp_mode = mode if final else "map"
        exported = (None if final
                    else dag.out_edges(exit_stage)[0].name)
        sub_nests = []
        for s in comp:
            nest = nests[s]
            if exported is not None and s == exit_stage:
                refs = tuple(r for r in nest.refs
                             if not (r.kind == Direction.WRITE
                                     and r.name == exported))
                nest = dataclasses.replace(nest, refs=refs)
            sub_nests.append(nest)
        if len(comp) == 1:
            s = comp[0]
            nest = sub_nests[0]
            lanes = nest_analysis.auto_lanes(nest, num_lanes)
            lowered = _lowered_for(_plan_for(nest, lanes), sub_sched, False)
            body = _reorder_body(
                bodies[s], [st.name for st in lowered.in_streams],
                _stage_arg_names(dag, s), f"dag stage {s}")
            result = ssr_call(nest, body, env, mode=comp_mode,
                              out_dtype=out_dtype, schedule=sub_sched,
                              num_lanes=num_lanes, interpret=interpret,
                              uniforms=uniforms)
        else:
            sub_dag = _dag_for(tuple(sub_nests), num_lanes)
            sub_bodies = []
            for j, s in enumerate(comp):
                callee = ([e.name for e in sub_dag.in_edges(j)]
                          + [st.name for st in
                             _lowered_chain_for(sub_dag,
                                                sub_sched)
                             .stage_in_streams[j]])
                sub_bodies.append(_reorder_body(
                    bodies[s], callee, _stage_arg_names(dag, s),
                    f"dag stage {s}"))
            result = ssr_dag_call(tuple(sub_nests), tuple(sub_bodies), env,
                                  mode=comp_mode, out_dtype=out_dtype,
                                  schedule=sub_sched, num_lanes=num_lanes,
                                  interpret=interpret, uniforms=uniforms)
        if exported is not None:
            env[exported] = result
    assert result is not None
    return result


def ssr_dag_call(nests: Sequence[LoopNest],
                 bodies: Sequence[Callable[..., jax.Array]],
                 operands: Dict[str, jax.Array], *,
                 mode: str = "map",
                 out_dtype=jnp.float32,
                 policy: BlockPolicy = DEFAULT_POLICY,
                 schedule: Optional[Schedule] = None,
                 num_lanes: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 uniforms: Optional[Dict[str, jax.Array]] = None
                 ) -> jax.Array:
    """Execute a DAG of nests — diamonds included — as ONE Pallas kernel.

    Dataflow is discovered by name (see :func:`repro.core.compiler.chain_dag`):
    a ref WRITTEN by stage p and READ by any later stages becomes VMEM-
    carried edges; one producer may feed several consumers.  The carried
    intermediates live in refcounted VMEM scratch slots and never touch
    HBM.

    ``bodies[k]`` receives stage ``k``'s *incoming-edge blocks first* (in
    ``ChainDAG.in_edges`` order: sorted by producer stage, then name),
    followed by its external stream blocks in allocation order, then every
    ``uniforms`` array (in dict order).  Uniforms are whole arrays — MLP
    weights, lookup tables — delivered to the kernel as one loop-invariant
    block each and appended to EVERY stage body's arguments; Pallas
    forbids kernels closing over array constants, and block streams can't
    carry an operand that every grid step needs in full.  ``mode`` applies
    to the final stage with the :func:`ssr_call` contract; reduce bodies
    must be padding-neutral at every stage.

    **Transparent partitioning**: with no explicit ``schedule`` (and the
    default ``policy``) the autotuner's cache is consulted under the DAG's
    own key; a committed ``Schedule.cut_edges`` from the fusion search
    (``autotune.autotune_dag``) splits the graph into several kernels with
    the cut intermediates materialised in HBM — the cost model and
    measurements decide where fusion stops paying, dispatch just follows.
    """
    nests = tuple(nests)
    bodies = tuple(bodies)
    if len(bodies) != len(nests):
        raise ValueError(
            f"need one body per nest, got {len(bodies)} bodies for "
            f"{len(nests)} nests")
    dag = _dag_for(nests, num_lanes)
    uni = _uniform_items(uniforms)
    if uni:
        clash = sorted({nm for nm, _ in uni} & set(operands))
        if clash:
            raise ValueError(
                f"uniform names {clash} collide with streamed operands; "
                "uniforms are a separate argument namespace")
    tuned_key: Optional[str] = None
    if schedule is None and policy is DEFAULT_POLICY:
        from . import autotune as _autotune

        try:
            schedule = _autotune.lookup_dag(
                nests, operands, mode=mode,
                out_dtype=str(jnp.dtype(out_dtype)), uniforms=dict(uni))
        except resilience.fallback_error_types() as e:
            _record_fallback("ssr_dag_call", e, from_schedule="tuned-lookup",
                             to_schedule="default")
            schedule = DEFAULT_SCHEDULE
        else:
            if schedule != DEFAULT_SCHEDULE:
                tuned_key = _autotune.dag_cache_key(
                    nests, operands, mode=mode,
                    out_dtype=str(jnp.dtype(out_dtype)), uniforms=dict(uni))

    def _dispatch(resolved: Schedule) -> jax.Array:
        sched = resolved
        if sched.cut_edges:
            return _dag_partition_call(dag, nests, bodies, operands, sched,
                                       mode=mode, out_dtype=out_dtype,
                                       num_lanes=num_lanes,
                                       interpret=interpret,
                                       uniforms=dict(uni))
        if sched.cut_edges is not None:  # () — all-fused, same kernel as None
            sched = dataclasses.replace(sched, cut_edges=None)
        lowered = _lowered_chain_for(dag, sched)
        flat = lowered.in_streams
        missing = sorted({s.name for s in flat} - set(operands))
        if missing:
            raise ValueError(f"missing operands for streams {missing}")
        arrays = [operands[s.name] for s in flat]

        DISPATCH_STATS["calls"] += 1
        key = ("dag", nests, sched, mode,
               tuple(_body_key(b) for b in bodies), str(jnp.dtype(out_dtype)),
               tuple((tuple(a.shape), str(a.dtype)) for a in arrays),
               _uniform_sig(uni), num_lanes, interpret)
        fn = _kernel_cache_get(key)
        if fn is None:
            kernel = _build_dag_kernel(
                lowered, bodies, mode, jnp.dtype(out_dtype), interpret,
                uniforms=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                               for _, a in uni))

            def pipeline(*arrs, _lowered=lowered, _kernel=kernel,
                         _sched=sched):
                DISPATCH_STATS["traces"] += 1   # moves only while tracing
                ns = len(_lowered.in_streams)
                prepared = [s.prepare(a)
                            for s, a in zip(_lowered.in_streams, arrs[:ns])]
                out = _kernel(*prepared, *arrs[ns:])
                return _trim_output(out, dag.bounds, mode, _sched.policy)

            resilience.inject("compile")
            fn = jax.jit(pipeline)
            DISPATCH_STATS["builds"] += 1
            _kernel_cache_put(key, fn)
        return fn(*arrays, *[a for _, a in uni])

    if tuned_key is None:
        return _dispatch(_resolve_schedule(policy, schedule))
    try:
        return _dispatch(_resolve_schedule(policy, schedule))
    except resilience.fallback_error_types() as e:
        from . import autotune as _autotune

        _autotune.global_cache().quarantine(tuned_key)
        _record_fallback("ssr_dag_call", e, from_schedule="tuned",
                         to_schedule="default", key=tuned_key,
                         counter="degraded")
        return _dispatch(DEFAULT_SCHEDULE)
