"""Vectorised address-generation unit (AGU) model (paper §3.1, Fig. 3).

``StreamSpec.addresses()`` is the plain-Python oracle; this module provides the
JAX-native equivalents used by kernels, the compiler pass, and property tests.
The AGU is the heart of the paper's data mover (§3.1): it turns the
``bound/stride/repeat`` configuration into the address sequence that feeds the
FIFO.  On TPU we use it two ways:

* **verification** — enumerate the exact element addresses a stream touches and
  gather them with ``jnp.take`` to produce the reference operand sequence;
* **kernel construction** — derive Pallas ``grid`` and affine ``index_map``
  functions from the same spec (see ``core/ssr.py``), so the kernel's block
  schedule *is* the AGU pattern at block granularity.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .stream import StreamSpec


def address_sequence(spec: StreamSpec) -> jax.Array:
    """All emitted addresses (including ``repeat``) as an int32 vector.

    Vectorised odometer: iteration ``t``'s multi-index is the mixed-radix
    decomposition of ``t`` over ``bounds`` (innermost fastest), so

        addr(t) = base + Σ_k ((t // Π_{j>k} L_j) mod L_k) · stride_k
    """
    bounds = np.asarray(spec.bounds, dtype=np.int64)
    strides = np.asarray(spec.strides, dtype=np.int64)
    n = int(np.prod(bounds))
    # suffix products: place value of each loop dimension
    place = np.concatenate([np.cumprod(bounds[::-1])[::-1][1:], [1]])
    t = jnp.arange(n, dtype=jnp.int64)
    digits = (t[:, None] // jnp.asarray(place)) % jnp.asarray(bounds)
    addrs = spec.base + digits @ jnp.asarray(strides)
    if spec.repeat > 1:
        addrs = jnp.repeat(addrs, spec.repeat)
    return addrs.astype(jnp.int32)


def gather_stream(data: jax.Array, spec: StreamSpec) -> jax.Array:
    """Materialise the operand sequence a read stream would deliver.

    This is the oracle for "what does the core see when it reads ft0/ft1" —
    kernels' ``ref.py`` files express their semantics in terms of it.
    """
    flat = data.reshape(-1)
    return jnp.take(flat, address_sequence(spec), axis=0)


def scatter_stream(out_size: int, values: jax.Array,
                   spec: StreamSpec) -> jax.Array:
    """Materialise the memory image a write stream produces.

    Later writes to the same address win (the FIFO drains in order).
    """
    addrs = address_sequence(spec)
    out = jnp.zeros((out_size,), dtype=values.dtype)
    return out.at[addrs].set(values.reshape(-1), mode="drop")


def block_grid(spec: StreamSpec, block: Tuple[int, ...]) -> Tuple[int, ...]:
    """Grid extent when the innermost dims of ``spec`` are tiled by ``block``.

    The TPU adaptation streams VMEM blocks instead of words; the AGU loop nest
    splits into (outer grid) × (intra-block lanes).  ``block`` must tile the
    innermost ``len(block)`` bounds exactly.
    """
    if len(block) > spec.ndim:
        raise ValueError("block rank exceeds stream rank")
    inner = spec.bounds[spec.ndim - len(block):]
    for b, blk in zip(inner, block):
        if blk <= 0 or b % blk != 0:
            raise ValueError(f"block {block} does not tile bounds {inner}")
    outer = spec.bounds[: spec.ndim - len(block)]
    tiled = tuple(b // blk for b, blk in zip(inner, block))
    return tuple(outer) + tiled


def affine_coefficients(index_map, grid: Tuple[int, ...]):
    """Probe an index_map and return (offset, coeffs) if affine, else None.

    Used by property tests and ``ssr_pallas`` to *prove* that a kernel's block
    schedule is expressible by the paper's AGU (which only supports affine
    patterns).  Probes f(0), f(e_i) and verifies f(g) == f(0) + Σ g_i·c_i on a
    corner sample.
    """
    zero = tuple(0 for _ in grid)
    f0 = np.asarray(index_map(*zero), dtype=np.int64)
    coeffs = []
    for i in range(len(grid)):
        if grid[i] <= 1:
            coeffs.append(np.zeros_like(f0))
            continue
        e = list(zero)
        e[i] = 1
        coeffs.append(np.asarray(index_map(*e), dtype=np.int64) - f0)
    # verify on corners (full extent and a mixed corner)
    probes = [tuple(g - 1 for g in grid)]
    probes.append(tuple((g - 1) if i % 2 == 0 else 0 for i, g in enumerate(grid)))
    for p in probes:
        want = f0 + sum(np.asarray(p[i]) * coeffs[i] for i in range(len(grid)))
        got = np.asarray(index_map(*p), dtype=np.int64)
        if not np.array_equal(want, got):
            return None
    return f0, coeffs
