"""Core SSR library: the paper's contribution as composable JAX modules.

Paper-section map (details per module, full table in DESIGN.md §2):
``stream`` (§2/§3.1 AGU config registers), ``agu`` (§3.1 address
generation), ``ssr`` (§2 stream-semantic operand delivery), ``compiler``
(§3.2 SSR-ification pass + chaining), ``lowering`` (§3.2 step 4–5: config
emission and region execution), ``autotune`` (schedule search: cost-model
prune + measured winners in a persistent cache), ``isa`` (§4/§5 exact cost
models), ``region`` (§2.2.2 ``ssrcfg`` CSR).
"""

from .stream import (  # noqa: F401
    Direction,
    MAX_DIMS,
    StreamSpec,
    contiguous,
    strided_2d,
    validate_no_race,
)
from .agu import (  # noqa: F401
    address_sequence,
    affine_coefficients,
    block_grid,
    gather_stream,
    scatter_stream,
)
from .isa import (  # noqa: F401
    HotLoop,
    KernelModel,
    Table2Row,
    breakeven_lhs,
    breakeven_rhs,
    cluster_time,
    equivalent_cores,
    fig4_dot_product,
    kernel_suite,
    min_side_length,
    n_base,
    n_ssr,
    ssr_profitable,
    table2,
    utilization_class,
    utilization_limit_dot,
    utilization_reduction,
)
from .ssr import (  # noqa: F401
    BlockStream,
    StreamReport,
    VMEM_BUDGET_BYTES,
    auto_block,
    check_mxu_alignment,
    ssr_pallas,
)
from . import nest_analysis  # noqa: F401
from .compiler import (  # noqa: F401
    Allocation,
    COMBINE_COST,
    ChainDAG,
    ChainError,
    ChainLink,
    ChainedPlan,
    ClusterReport,
    CoreCost,
    DagEdge,
    LoopNest,
    MemRef,
    StreamPlan,
    attention_nest,
    chain,
    chain_dag,
    cluster_cost,
    dot_product_nest,
    elementwise_nest,
    gemm_nest,
    gemv_nest,
    iso_performance_cores,
    spmm_nest,
    spmv_nest,
    ssrify,
    stencil2d_nest,
    stencil_nest,
)
from .lowering import (  # noqa: F401
    BlockPolicy,
    DEFAULT_POLICY,
    DEFAULT_SCHEDULE,
    LoweredChain,
    LoweredNest,
    LoweredPlan,
    LoweredStream,
    LoweringError,
    NestStream,
    Schedule,
    lower_chain,
    lower_nest,
    lower_plan,
    plan_stats,
    ssr_call,
    ssr_chain_call,
    ssr_dag_call,
)
from . import autotune  # noqa: F401
from .autotune import (  # noqa: F401
    ScheduleCache,
    TuneResult,
    candidate_schedules,
    schedule_is_legal,
)
from .region import ssr_enabled, ssr_region, set_ssr  # noqa: F401
