"""Schedule autotuner: cost-model-guided block-policy search + dispatch cache.

The paper's speedup comes from matching the loop schedule to the memory
system (Snitch tunes the same ISA to 2× more on the right schedule —
PAPERS.md); our lowering used to pin every kernel, shape and core count to
the single hard-coded ``DEFAULT_POLICY``.  This module makes the schedule a
**searched artifact**:

1. **candidate generation** — :func:`candidate_schedules` enumerates legal
   :class:`~repro.core.lowering.Schedule` variants for a nest: block
   geometries (rows × lanes), per-level tile targets, and grid-axis orders.
   Legality (:func:`schedule_is_legal`) is decided by the lowering itself
   (a candidate the §3.2 pipeline rejects is discarded), plus the hardware
   constraints the lowering does not own: lane divisibility (a TPU lane is
   128 wide) and the *depth-aware* VMEM working-set budget
   (``buffer_depth`` buffers per stream block via :func:`repro.core.ssr.
   stream_vmem_bytes` + kernel-resident scratch, the :class:`~repro.core.
   ssr.StreamReport` accounting);
2. **model prune** — :func:`model_cost` ranks candidates with the
   Eq. (1)–(3) instruction model (``ssrify`` on the *padded* iteration
   space, so padding blowup is charged) plus a per-grid-step dispatch
   charge, and :func:`rank_candidates` keeps a deterministic top-K;
3. **measure** — :func:`autotune` wall-clocks the survivors (the default
   schedule always races) and commits the winner;
4. **persist** — a :class:`ScheduleCache`: JSON-per-key files under a
   cache directory (``REPRO_SCHEDULE_CACHE`` env var, else
   ``~/.cache/repro-ssr``), an in-memory LRU in front, explicit
   invalidation, and a version stamp so stale formats never load.  Keys
   are :func:`cache_key`: nest signature + operand shapes/dtypes + mode +
   out dtype + backend + cores.

Dispatch integration: ``kernels.frontend.NestKernel`` and
``parallel.cluster.cluster_call`` consult :func:`lookup` when no explicit
schedule is passed, so ``ops.py`` callers get tuned schedules transparently
once a cache entry exists; :func:`epoch` lets their built-pipeline caches
invalidate when the tuner commits a new winner.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import json
import math
import os
import tempfile
import time

import numpy as np
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import nest_analysis, resilience
from .compiler import ChainDAG, LoopNest, _fused_region_count, ssrify
from .lowering import (DEFAULT_SCHEDULE, LoweredChain, LoweredNest,
                       LoweredPlan, LoweringError, Schedule, _plan_for)
from .nest_analysis import auto_lanes
from .ssr import (DEFAULT_BUFFER_DEPTH, MAX_BUFFER_DEPTH, VMEM_BUDGET_BYTES,
                  stream_vmem_bytes)
from .stream import Direction

#: Bump when the on-disk entry format (or the meaning of a schedule's
#: fields) changes: old entries are ignored, never mis-parsed.
SCHEDULE_CACHE_VERSION = 1

_ENV_CACHE_DIR = "REPRO_SCHEDULE_CACHE"

#: Eq. (1)-style charge per grid step: loop bookkeeping + DMA descriptor
#: issue for the double-buffered block fetches.  This is what makes the
#: model prefer fewer/bigger blocks until padding waste outweighs it.
STEP_COST = 32

#: Search space of the generic generator (kernels with bespoke geometry,
#: e.g. the waivered stencil, pass their own ``candidates=``).
_ROWS_CHOICES = (4, 8, 16, 32)
_LANES_CHOICES = (128, 256, 512)
_LANES_FACTORS = (1, 2, 4)
_ROWS_FACTORS = (8, 32)
_QUICK_ROWS = (8, 16)
_QUICK_LANES = (128, 256)
#: Data-mover FIFO depths the generator explores (2 = the synchronous
#: Pallas double-buffer; deeper = explicit N-deep DMA rotation).
_DEPTH_CHOICES = (2, 3, 4)
_QUICK_DEPTHS = (2, 3)


def default_cache_dir() -> str:
    env = os.environ.get(_ENV_CACHE_DIR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-ssr")


# --------------------------------------------------------------------------
# Cache keys: nest signature + shapes + backend + cores
# --------------------------------------------------------------------------


def nest_signature(nest: LoopNest) -> str:
    """Canonical text form of a nest — the schedule cache's identity.

    Any change to bounds, refs (name/kind/coeffs/offset, plus the index
    stream + scale of an indirect ref, a halo window, or a non-default
    accumulator kind) or per-level compute yields a different signature,
    so editing a kernel's nest invalidates its cached schedules by
    construction.  Refs without the newer features keep their older text
    form, so existing cached schedules stay addressable.
    """
    def _ref_sig(r) -> str:
        sig = f"{r.name}:{r.kind.name}:{r.coeffs}:{r.offset}"
        if r.is_indirect():
            sig += f":ix={r.index_of}*{r.index_scale}"
        if r.window is not None:
            sig += f":win={r.window}"
        if r.acc_kind != "sum":
            sig += f":acc={r.acc_kind}"
        return sig

    refs = ";".join(_ref_sig(r) for r in nest.refs)
    return f"b={nest.bounds}|refs={refs}|c={nest.compute_per_level}"


def operand_signature(operands: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    """Sorted (name, shape×dtype) pairs; accepts arrays or (shape, dtype)."""
    sig = []
    for name in sorted(operands):
        v = operands[name]
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            sig.append((name, f"{tuple(v.shape)}:{v.dtype}"))
        else:
            shape, dtype = v
            sig.append((name, f"{tuple(shape)}:{dtype}"))
    return tuple(sig)


def _backend() -> str:
    import jax

    try:
        return jax.default_backend()
    except RuntimeError:  # pragma: no cover - no backend at all
        return "none"


def cache_key(nest: LoopNest, operands: Dict[str, Any], *,
              mode: str = "reduce", out_dtype: str = "float32",
              backend: Optional[str] = None, cores: int = 1) -> str:
    """Stable hex digest identifying one tuning problem."""
    backend = backend or _backend()
    blob = json.dumps({
        "v": SCHEDULE_CACHE_VERSION,
        "nest": nest_signature(nest),
        "operands": operand_signature(operands),
        "mode": mode,
        "out_dtype": str(out_dtype),
        "backend": backend,
        "cores": int(cores),
    }, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


# --------------------------------------------------------------------------
# Persistent schedule cache: JSON-per-key + in-memory LRU + invalidation
# --------------------------------------------------------------------------


#: Sentinel for "generation never observed" — the first probe adopts the
#: on-disk token silently instead of spuriously invalidating local state.
_GEN_UNSET = object()

#: Bounded retry budget for transient I/O on the commit path.
_PUT_ATTEMPTS = 3


class ScheduleCache:
    """On-disk schedule store with an in-memory LRU in front.

    One JSON file per key under ``path`` (atomic tmp+rename writes), so
    concurrent tuners never corrupt each other's entries and per-key
    invalidation is an unlink.  Misses return ``None`` and are
    negative-cached **per epoch**: the transparent-dispatch hot path
    (``ssr_call`` with ``schedule=None``) probes on every call, and a
    filesystem miss per kernel invocation would tax exactly the path this
    layer exists to speed up.

    **Cross-process visibility.** Any commit/invalidate/quarantine —
    local *or* from another process — must bust stale negative-cache
    entries.  The local path bumps ``_EPOCH`` directly; cross-process
    changes are detected by stat'ing one ``GENERATION`` file every
    writer touches (atomic replace, so the (inode, mtime_ns) token
    changes on every write): when the token moves, the in-memory
    positive + negative caches drop and the epoch bumps, so
    built-pipeline caches keyed on :func:`epoch` rebuild too.  Cost on
    the hot path: one ``os.stat``.

    **Crash safety.** Torn/truncated/garbage/version-skewed entry files
    are treated as misses AND quarantined — renamed to
    ``<key>.json.corrupt`` (counted in :attr:`stats`) so they cannot
    shadow a later healthy commit; a subsequent :meth:`put` recovers the
    key.  Commits retry transient ``OSError`` with jittered backoff
    (bounded — see :func:`repro.core.resilience.retry`) and never leave
    a ``.tmp`` behind.  The ``cache.read``/``cache.write`` fault seams
    fire here.
    """

    def __init__(self, path: Optional[str] = None, max_entries: int = 256):
        self.path = path or default_cache_dir()
        self.max_entries = max_entries
        self._mem: "collections.OrderedDict[str, Schedule]" = \
            collections.OrderedDict()
        self._miss: Dict[str, int] = {}   # key -> epoch of the probed miss
        self._last_gen: Any = _GEN_UNSET  # last observed GENERATION token
        self.stats: Dict[str, int] = {"hits": 0, "misses": 0,
                                      "quarantined": 0, "retries": 0,
                                      "generation_busts": 0}

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.json")

    def _gen_file(self) -> str:
        return os.path.join(self.path, "GENERATION")

    def _disk_generation(self) -> Optional[Tuple[int, int]]:
        """Cheap change token of the cache dir: (inode, mtime_ns) of the
        GENERATION file, ``None`` while no writer has touched it yet.
        Every touch goes through an atomic replace, so the inode alone
        already changes per write — mtime_ns is belt and braces."""
        try:
            st = os.stat(self._gen_file())
        except OSError:
            return None
        return (st.st_ino, st.st_mtime_ns)

    def _touch_generation(self) -> None:
        """Advance the cross-process change token (atomic, retried)."""
        def _write() -> None:
            os.makedirs(self.path, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(f"{os.getpid()}:{time.time_ns()}\n")
                os.replace(tmp, self._gen_file())
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

        try:
            resilience.retry(_write, attempts=_PUT_ATTEMPTS,
                             on_retry=self._count_retry)
        except OSError:
            # the token is an optimisation for OTHER processes' negative
            # caches; local state is already correct, so a sick filesystem
            # must not fail the commit that just landed
            pass
        self._last_gen = self._disk_generation()
        self._miss.clear()

    def _count_retry(self, attempt: int, error: BaseException) -> None:
        self.stats["retries"] += 1

    def _sync_generation(self) -> None:
        """Adopt the on-disk token; on change, drop local caches so a
        commit/invalidate from another process becomes visible NOW (not
        after an unrelated local epoch bump — the staleness hole this
        probe closes)."""
        gen = self._disk_generation()
        if self._last_gen is _GEN_UNSET:
            self._last_gen = gen
            return
        if gen != self._last_gen:
            self._last_gen = gen
            self._miss.clear()
            self._mem.clear()
            self.stats["generation_busts"] += 1
            _bump_epoch()

    def _note_miss(self, key: str) -> None:
        if len(self._miss) >= 4096:
            self._miss.clear()
        self._miss[key] = _EPOCH

    def quarantine(self, key: str) -> bool:
        """Sideline one entry as poisoned: rename to ``.json.corrupt``
        (forensics survive; the key reads as a miss), negative-cache it,
        and advance the generation token so other processes re-probe.
        Returns True if a disk file was actually sidelined."""
        self._mem.pop(key, None)
        sidelined = False
        try:
            os.replace(self._file(key), self._file(key) + ".corrupt")
            sidelined = True
        except OSError:
            try:
                os.unlink(self._file(key))
                sidelined = True
            except OSError:
                pass
        if sidelined:
            self.stats["quarantined"] += 1
            self._touch_generation()
        _bump_epoch()
        self._note_miss(key)
        return sidelined

    def get(self, key: str) -> Optional[Schedule]:
        resilience.inject("cache.read")
        self._sync_generation()
        hit = self._mem.get(key)
        if hit is not None:
            self._mem.move_to_end(key)
            self.stats["hits"] += 1
            return hit
        if self._miss.get(key) == _EPOCH:
            self.stats["misses"] += 1
            return None
        try:
            with open(self._file(key)) as f:
                doc = json.load(f)
        except OSError:                  # absent file: a plain miss
            self._note_miss(key)
            self.stats["misses"] += 1
            return None
        except ValueError:               # torn/garbage JSON: quarantine
            self.quarantine(key)
            self.stats["misses"] += 1
            return None
        if doc.get("version") != SCHEDULE_CACHE_VERSION:
            self.quarantine(key)         # version skew: never mis-parse
            self.stats["misses"] += 1
            return None
        try:
            sched = Schedule.from_json(doc["schedule"])
        except (KeyError, TypeError, ValueError):
            self.quarantine(key)
            self.stats["misses"] += 1
            return None
        self._remember(key, sched)
        self.stats["hits"] += 1
        return sched

    def meta(self, key: str) -> Optional[Dict[str, Any]]:
        """The full stored document (schedule + provenance), or ``None``."""
        resilience.inject("cache.read")
        try:
            with open(self._file(key)) as f:
                doc = json.load(f)
        except OSError:
            return None
        except ValueError:
            self.quarantine(key)
            return None
        return doc if doc.get("version") == SCHEDULE_CACHE_VERSION else None

    def put(self, key: str, schedule: Schedule,
            meta: Optional[Dict[str, Any]] = None) -> None:
        doc = {"version": SCHEDULE_CACHE_VERSION,
               "schedule": schedule.to_json(),
               "committed_unix": time.time()}
        if meta:
            doc["meta"] = meta

        def _write() -> None:
            resilience.inject("cache.write")
            os.makedirs(self.path, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(doc, f, indent=1, sort_keys=True)
                os.replace(tmp, self._file(key))
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

        # transient OSError (full disk blip, NFS hiccup, injected
        # cache.write:oserror) is retried with jittered backoff; a typed
        # InjectedFault or a persistent failure propagates to the caller,
        # who degrades gracefully (see autotune()'s commit)
        resilience.retry(_write, attempts=_PUT_ATTEMPTS,
                         on_retry=self._count_retry)
        self._remember(key, schedule)
        self._touch_generation()
        _bump_epoch()

    def invalidate(self, key: str) -> bool:
        """Drop one entry (memory + disk); True if anything was removed."""
        self._miss.pop(key, None)
        dropped = self._mem.pop(key, None) is not None
        try:
            os.unlink(self._file(key))
            dropped = True
        except OSError:
            pass
        if dropped:
            self._touch_generation()
            _bump_epoch()
        return dropped

    def clear(self) -> int:
        """Drop every entry; returns the number of disk entries removed."""
        self._mem.clear()
        self._miss.clear()
        n = 0
        try:
            names = os.listdir(self.path)
        except OSError:
            names = []
        for name in names:
            if name.endswith(".json"):
                try:
                    os.unlink(os.path.join(self.path, name))
                    n += 1
                except OSError:
                    pass
        self._touch_generation()
        _bump_epoch()
        return n

    def keys(self) -> List[str]:
        try:
            return sorted(n[:-5] for n in os.listdir(self.path)
                          if n.endswith(".json"))
        except OSError:
            return []

    def _remember(self, key: str, sched: Schedule) -> None:
        self._mem[key] = sched
        self._mem.move_to_end(key)
        while len(self._mem) > self.max_entries:
            self._mem.popitem(last=False)


_GLOBAL_CACHE: Optional[ScheduleCache] = None
_EPOCH = 0


def global_cache() -> ScheduleCache:
    """The process-wide cache (respects ``REPRO_SCHEDULE_CACHE``)."""
    global _GLOBAL_CACHE
    if _GLOBAL_CACHE is None or \
            _GLOBAL_CACHE.path != default_cache_dir():
        _GLOBAL_CACHE = ScheduleCache()
    return _GLOBAL_CACHE


def epoch() -> int:
    """Monotonic commit counter — built-pipeline caches key on it so a
    newly tuned schedule takes effect without restarting the process."""
    return _EPOCH


def _bump_epoch() -> None:
    global _EPOCH
    _EPOCH += 1


def lookup(nest: LoopNest, operands: Dict[str, Any], *,
           mode: str = "reduce", out_dtype: str = "float32",
           cores: int = 1,
           cache: Optional[ScheduleCache] = None) -> Schedule:
    """Cache-only schedule resolution: tuned winner or the default.

    This is the transparent-dispatch hook — it never measures, so calling
    it on every kernel build costs one dict/file probe.
    """
    cache = cache or global_cache()
    key = cache_key(nest, operands, mode=mode, out_dtype=str(out_dtype),
                    cores=cores)
    return cache.get(key) or DEFAULT_SCHEDULE


# --------------------------------------------------------------------------
# Candidate generation + legality
# --------------------------------------------------------------------------


def _nest_has_output(nest: LoopNest) -> bool:
    return any(r.kind == Direction.WRITE for r in nest.refs)


def _lower_candidate(nest: LoopNest, sched: Schedule):
    """The lowering's own verdict on a candidate (raises LoweringError).

    Routed through the lowering layer's ``_lowered_for`` LRU: legality,
    cost-model and fingerprint checks all ask for the same (plan, sched)
    lowering, and a later ``ssr_call`` under the winner hits it warm.
    """
    from .lowering import _lowered_for

    plan = _plan_for(nest, auto_lanes(nest))
    return _lowered_for(plan, sched, _nest_has_output(nest))


def _depth_of(sched: Schedule, i: int, n_in: int) -> int:
    """Stream ``i``'s FIFO depth under ``sched`` (asymmetric when set)."""
    if sched.stream_depths and len(sched.stream_depths) == n_in:
        return sched.stream_depths[i]
    return sched.buffer_depth


def _max_depth(sched: Schedule) -> int:
    if sched.stream_depths:
        return max(sched.stream_depths)
    return sched.buffer_depth


def _operand_bytes(v: Any, itemsize: int = 4) -> int:
    """Whole-operand VMEM footprint; accepts arrays or (shape, dtype)."""
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        shape, dtype = tuple(v.shape), v.dtype
    else:
        shape, dtype = v
    try:
        size = np.dtype(dtype).itemsize
    except TypeError:
        size = itemsize
    return math.prod(tuple(shape)) * size


def _gather_table_bytes(lowered, operands: Optional[Dict[str, Any]],
                        itemsize: int = 4) -> int:
    """VMEM charge for indirect refs: the whole gather table is resident.

    This is the indirect-ref legality rule — index blocks stream like any
    other lane (charged above), but the indirectly addressed operand rides
    double-buffered as an invariant block, so its *full* extent counts
    against the budget.  Without operands the geometry-only charge is 0
    (table sizes are operand facts, not nest facts).
    """
    gathers = getattr(lowered, "gathers", ())
    if not gathers or not operands:
        return 0
    total = 0
    for g in gathers:
        if g.name in operands:
            total += stream_vmem_bytes(
                _operand_bytes(operands[g.name], itemsize), 2)
    return total


def _stream_block_bytes(lowered, itemsize: int = 4) -> int:
    """Depth-buffered stream blocks + kernel-resident scratch, in bytes.

    Mirrors :meth:`repro.core.ssr.StreamReport` accounting exactly — both
    route every stream block through :func:`repro.core.ssr.
    stream_vmem_bytes` at the schedule's (possibly per-stream) FIFO
    depths, so the budget the tuner enforces is the budget the emitter
    allocates (the depth knob cannot drift between them).  The
    contraction / reduce accumulator is single-buffered scratch
    (``scratch_bytes``); a fused chain/DAG additionally charges one VMEM
    block per *live* intermediate slot (refcounted — a diamond's peak is
    2 slots, not one per edge).
    """
    sched = lowered.schedule
    depth = sched.buffer_depth
    total = 0
    if isinstance(lowered, LoweredNest):
        n_in = len(lowered.in_streams)
        for i, s in enumerate(lowered.in_streams):
            total += stream_vmem_bytes(
                math.prod(s.stream.block_shape) * itemsize,
                _depth_of(sched, i, n_in))
        out_block = math.prod(lowered.out_stream.stream.block_shape)
        total += stream_vmem_bytes(out_block * itemsize, _max_depth(sched))
        if lowered.contraction_axes:     # the VMEM accumulator scratch
            total += out_block * itemsize
        return total
    if isinstance(lowered, LoweredChain):
        from .lowering import _dag_slots

        flat = lowered.in_streams
        n_in = len(flat)
        for i, s in enumerate(flat):
            total += stream_vmem_bytes(
                math.prod(s.stream.block_shape) * itemsize,
                _depth_of(sched, i, n_in))
        block = lowered.policy.rows * lowered.policy.lanes
        total += stream_vmem_bytes(block * itemsize, _max_depth(sched))
        if isinstance(lowered.chained, ChainDAG):
            _, n_slots = _dag_slots(lowered.chained)
        else:
            n_slots = len(lowered.chained.links)
        total += n_slots * block * itemsize  # intermediate scratch slots
        total += block * itemsize            # reduce accumulator scratch
        return total
    assert isinstance(lowered, LoweredPlan)
    n_in = len(lowered.in_streams)
    for i, s in enumerate(lowered.in_streams):
        total += stream_vmem_bytes(
            math.prod(s.stream.block_shape) * itemsize,
            _depth_of(sched, i, n_in))
    block = lowered.policy.rows * lowered.policy.lanes
    total += stream_vmem_bytes(block * itemsize,
                               _max_depth(sched))  # synthesised output
    total += block * itemsize            # reduce accumulator scratch
    return total


def schedule_is_legal(nest: LoopNest, sched: Schedule, *,
                      itemsize: int = 4,
                      operands: Optional[Dict[str, Any]] = None
                      ) -> Tuple[bool, str]:
    """(legal, reason).  Lowering + lane divisibility + VMEM budget.

    ``operands`` (when given) enables the indirect-ref rule: each gather
    table's full footprint joins the depth-buffered stream blocks against
    the VMEM budget — see :func:`_gather_table_bytes`.
    """
    if sched.lanes % 128 != 0 or sched.lanes < 128:
        return False, f"lanes {sched.lanes} not a multiple of the 128-wide " \
                      "hardware lane"
    if sched.rows < 1:
        return False, f"rows {sched.rows} < 1"
    if sched.lanes_tile_factor < 1 or sched.rows_tile_factor < 1:
        return False, "tile factors must be >= 1"
    if not DEFAULT_BUFFER_DEPTH <= sched.buffer_depth <= MAX_BUFFER_DEPTH:
        return False, (f"buffer_depth {sched.buffer_depth} outside "
                       f"[{DEFAULT_BUFFER_DEPTH}, {MAX_BUFFER_DEPTH}]")
    if sched.stream_depths is not None:
        for d in sched.stream_depths:
            if not DEFAULT_BUFFER_DEPTH <= d <= MAX_BUFFER_DEPTH:
                return False, (f"stream depth {d} outside "
                               f"[{DEFAULT_BUFFER_DEPTH}, "
                               f"{MAX_BUFFER_DEPTH}]")
    try:
        lowered = _lower_candidate(nest, sched)
    except LoweringError as e:
        return False, f"lowering rejected: {e}"
    except ValueError as e:              # MAX_DIMS / malformed nest
        return False, f"nest rejected: {e}"
    if sched.stream_depths is not None \
            and len(sched.stream_depths) != len(lowered.in_streams):
        return False, (f"stream_depths has {len(sched.stream_depths)} "
                       f"entries for {len(lowered.in_streams)} read "
                       "streams")
    vmem = _stream_block_bytes(lowered, itemsize)
    vmem += _gather_table_bytes(lowered, operands, itemsize)
    if vmem > VMEM_BUDGET_BYTES:
        return False, (f"VMEM working set {vmem / 2**20:.1f} MiB exceeds "
                       f"budget {VMEM_BUDGET_BYTES / 2**20:.0f} MiB")
    return True, "ok"


def _axis_orders(nest: LoopNest) -> List[Tuple[int, ...]]:
    """Legal grid-axis permutations: parallel axes shuffle, contractions
    stay trailing.  Bounded to 3-deep nests (≤ 2 extra orders)."""
    if not _nest_has_output(nest):
        return []
    try:
        out = nest_analysis.output_ref(nest)
    except ValueError:
        return []
    if out is None or out.coeffs is None:
        return []
    zaxes = nest_analysis.contraction_axes(out, nest)
    par = [l for l in range(len(nest.bounds)) if l not in zaxes]
    if len(par) < 2:
        return []
    orders = []
    for perm in itertools.permutations(par):
        order = tuple(perm) + tuple(z for z in range(len(nest.bounds))
                                    if z in zaxes)
        if order != tuple(range(len(nest.bounds))):
            orders.append(order)
    return orders[:2]


def candidate_schedules(nest: LoopNest, *, quick: bool = False,
                        max_candidates: Optional[int] = None,
                        operands: Optional[Dict[str, Any]] = None
                        ) -> List[Schedule]:
    """Legal candidates for a nest, default schedule always first.

    Enumerates block geometries (rows × lanes) and — for level-mapped
    nests — tile-factor and grid-axis-order variants, filtered through
    :func:`schedule_is_legal` (with ``operands``, gather tables count
    against the VMEM budget too).  Deterministic order (the generator is
    pure enumeration), so ranking + tie-breaks reproduce run to run.
    """
    rowses = _QUICK_ROWS if quick else _ROWS_CHOICES
    laneses = _QUICK_LANES if quick else _LANES_CHOICES
    depths = _QUICK_DEPTHS if quick else _DEPTH_CHOICES
    raw: List[Schedule] = [DEFAULT_SCHEDULE]
    for rows, lanes in itertools.product(rowses, laneses):
        raw.append(Schedule(rows=rows, lanes=lanes))
    if _nest_has_output(nest):
        factors = _LANES_FACTORS if not quick else _LANES_FACTORS[:2]
        for lf in factors:
            for rf in _ROWS_FACTORS:
                raw.append(Schedule(lanes_tile_factor=lf,
                                    rows_tile_factor=rf))
        for order in _axis_orders(nest):
            raw.append(Schedule(axis_order=order))
    # Depth × geometry cross: every geometry candidate at every FIFO
    # depth, so the tuner can trade run-ahead against tile size under the
    # depth-aware VMEM budget (a deep+large candidate that busts it is
    # simply filtered below).
    for s in list(raw):
        for d in depths:
            if d != s.buffer_depth:
                raw.append(dataclasses.replace(s, buffer_depth=d))
    if not quick:
        # Asymmetric per-stream FIFO depths (full runs only): deep
        # run-ahead for one operand, shallow for the other.  Only 2-read-
        # stream nests get the treatment — legality filters any schedule
        # whose entry count mismatches the lowered stream count.
        n_reads = sum(1 for r in nest.refs if r.kind == Direction.READ)
        if n_reads == 2:
            for sd in ((4, 2), (2, 4), (3, 2), (2, 3)):
                raw.append(Schedule(stream_depths=sd))

    seen, out = set(), []
    for s in raw:
        if s in seen:
            continue
        seen.add(s)
        if schedule_is_legal(nest, s, operands=operands)[0]:
            out.append(s)
    if max_candidates is not None:
        out = out[:max_candidates]
    return out


# --------------------------------------------------------------------------
# Model prune: Eq. (1)–(3) on the padded iteration space + step charge
# --------------------------------------------------------------------------


def _padded_bounds(nest: LoopNest, sched: Schedule) -> Tuple[Tuple[int, ...],
                                                             int]:
    """(padded bounds, grid steps) of the schedule — lowering-accurate
    where the lowering accepts the nest, closed-form otherwise."""
    try:
        lowered = _lower_candidate(nest, sched)
    except (LoweringError, ValueError):
        E = sched.block_elems
        inner = -(-nest.bounds[-1] // E) * E
        padded = tuple(nest.bounds[:-1]) + (inner,)
        return padded, math.prod(nest.bounds[:-1]) * (inner // E)
    if isinstance(lowered, LoweredNest):
        return lowered.padded_bounds, lowered.steps
    padded = tuple(lowered.plan.nest.bounds[:-1]) + (
        lowered.grid[-1] * sched.block_elems,)
    return padded, lowered.steps


def model_cost(nest: LoopNest, sched: Schedule, *,
               step_cost: int = STEP_COST) -> float:
    """Eq. (1) instruction count on the *padded* iteration space, plus a
    per-grid-step dispatch charge.

    The instruction model alone is block-geometry-blind (it counts loop
    iterations, not tiles); padding the bounds to what the schedule
    actually executes charges ragged-shape blowup, and the step charge
    models the per-block loop/DMA overhead that makes tiny blocks slow.
    Never raises for lane-legal candidates — geometry the lowering cannot
    express falls back to the closed-form block count.

    The step charge splits evenly into loop bookkeeping and DMA latency;
    the latency half shrinks as ``buffer_depth − 1`` in-flight fetches
    cover it (the data mover's run-ahead hides the fetch behind compute).
    At the default depth 2 the charge is exactly ``step_cost`` — the
    historical model — so deeper buffering is strictly cheaper per step
    and the tuner can justify smaller tiles at deeper FIFOs for
    bandwidth-bound nests.  Measurement still decides: the model only
    ranks who gets wall-clocked.
    """
    padded, steps = _padded_bounds(nest, sched)
    padded_nest = dataclasses.replace(nest, bounds=padded)
    plan = ssrify(padded_nest, num_lanes=auto_lanes(padded_nest), force=True)
    half = step_cost / 2.0
    per_step = half + half / (_max_depth(sched) - 1)
    return float(plan.n_ssr + per_step * steps)


def schedule_fingerprint(nest: LoopNest, sched: Schedule) -> Any:
    """What the schedule *lowers to*: grid, tiles, block shapes.

    Two schedules with the same fingerprint build byte-identical kernels
    (e.g. every tile-factor variant of a problem whose tiles all clamp to
    the padded dims), so measuring them separately would just race noise
    against itself.  Falls back to the schedule's own identity where the
    generic lowering cannot express the nest (hand-geometry kernels own
    their knob semantics).
    """
    try:
        lowered = _lower_candidate(nest, sched)
    except (LoweringError, ValueError):
        return ("sched", sched)
    if isinstance(lowered, LoweredNest):
        # Axis order only matters across axes that actually iterate:
        # permuting unit grid axes yields a byte-identical kernel.
        eff_order = tuple(l for k, l in enumerate(lowered.axis_order)
                          if lowered.grid[k] > 1)
        return ("nest", lowered.grid, lowered.tiles, eff_order,
                tuple(s.stream.block_shape for s in lowered.in_streams),
                lowered.out_stream.stream.block_shape, sched.acc_dtype,
                sched.buffer_depth, sched.stream_depths)
    return ("flat", lowered.grid,
            tuple(s.stream.block_shape for s in lowered.in_streams),
            sched.acc_dtype, sched.buffer_depth, sched.stream_depths)


def rank_candidates(nest: LoopNest, candidates: Sequence[Schedule], *,
                    top_k: int = 8,
                    step_cost: int = STEP_COST) -> List[Schedule]:
    """Deterministic model ranking; the default schedule always survives.

    Sort key is (model cost, schedule identity) so equal-cost candidates
    order reproducibly.  Candidates that lower to the same geometry
    (:func:`schedule_fingerprint`) collapse to one survivor — the default
    schedule claims its own fingerprint, so an equal-geometry variant can
    never displace it — and the default is re-inserted if the prune would
    drop it: the measurement phase must always race the baseline.
    """
    def ident(s: Schedule):
        return (s.rows, s.lanes, s.lanes_tile_factor, s.rows_tile_factor,
                s.axis_order or (), s.acc_dtype, s.buffer_depth,
                s.stream_depths or ())

    ranked = sorted(candidates,
                    key=lambda s: (model_cost(nest, s,
                                              step_cost=step_cost),
                                   ident(s)))
    default_fp = schedule_fingerprint(nest, DEFAULT_SCHEDULE) \
        if DEFAULT_SCHEDULE in candidates else None
    kept: List[Schedule] = []
    seen = set()
    for s in ranked:
        fp = schedule_fingerprint(nest, s)
        if fp in seen:
            continue
        seen.add(fp)
        kept.append(DEFAULT_SCHEDULE if fp == default_fp else s)
    kept = kept[:max(1, top_k)]
    if DEFAULT_SCHEDULE in candidates and DEFAULT_SCHEDULE not in kept:
        kept[-1] = DEFAULT_SCHEDULE
    return kept


# --------------------------------------------------------------------------
# Measure + commit
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one autotune run (or cache hit).

    ``degraded`` marks a run whose measurement phase hit a typed
    infrastructure failure and fell back to the default schedule without
    committing; ``committed`` is False when the winner was measured fine
    but the cache commit itself failed (the winner is still returned and
    used this process); ``stragglers`` counts timing samples the
    :class:`~repro.runtime.fault.StragglerMonitor` flagged and re-raced.
    """

    key: str
    schedule: Schedule
    tuned_us: float
    default_us: float
    candidates: int
    measured: int
    from_cache: bool = False
    degraded: bool = False
    committed: bool = True
    stragglers: int = 0

    @property
    def speedup(self) -> float:
        return self.default_us / self.tuned_us if self.tuned_us else 1.0

    @property
    def is_default(self) -> bool:
        return self.schedule == DEFAULT_SCHEDULE


def autotune(nest: LoopNest, body: Callable, operands: Dict[str, Any], *,
             mode: str = "reduce", out_dtype="float32",
             num_lanes: Optional[int] = None,
             interpret: Optional[bool] = None,
             call: Optional[Callable[[Schedule], Any]] = None,
             candidates: Optional[Sequence[Schedule]] = None,
             top_k: int = 8, warmup: int = 1, iters: int = 3,
             cores: int = 1,
             cache: Optional[ScheduleCache] = None,
             use_cache: bool = True, force: bool = False,
             clock: Optional[Callable[[], float]] = None,
             straggler: Optional[Any] = None) -> TuneResult:
    """Search → prune → measure → commit the winning schedule.

    ``call(schedule)`` executes the kernel under one candidate; the default
    routes through :func:`~repro.core.lowering.ssr_call` with ``nest``/
    ``body``/``operands``, but a whole-kernel callable (e.g. a registry
    entry's ``ssr`` function taking ``schedule=``) slots in so hand-
    scheduled kernels with their own geometry vocabulary tune through the
    same machinery.  The default schedule is always among the measured
    survivors, so the committed winner is never slower than the default
    *as measured* — the gate ``benchmarks/kernel_bench.py`` re-checks.

    **Straggler-hardened measurement**: every timed sample passes through
    a :class:`~repro.runtime.fault.StragglerMonitor` (injectable via
    ``straggler``; ``clock`` is the injectable time source, mirroring
    ``Supervisor.clock``).  A flagged sample — a GC pause, a noisy
    neighbour, an injected clock skew — is re-raced immediately instead
    of entering the race, so one poisoned timing cannot commit a
    slower-than-default winner.  A typed infrastructure failure during
    measurement (the ``measure`` seam) degrades the run to the default
    schedule without committing; a failed cache commit is recorded and
    tolerated (the measured winner still serves this process).

    A cache hit short-circuits everything unless ``force=True``.
    """
    from .lowering import ssr_call

    cache = cache or (global_cache() if use_cache else None)
    key = cache_key(nest, operands, mode=mode, out_dtype=str(out_dtype),
                    cores=cores)
    if cache is not None and not force:
        hit = cache.get(key)
        if hit is not None:
            meta = cache.meta(key) or {}
            m = meta.get("meta", {})
            return TuneResult(key=key, schedule=hit,
                              tuned_us=float(m.get("tuned_us", 0.0)),
                              default_us=float(m.get("default_us", 0.0)),
                              candidates=int(m.get("candidates", 0)),
                              measured=0, from_cache=True)

    if call is None:
        def call(sched: Schedule):
            return ssr_call(nest, body, operands, mode=mode,
                            out_dtype=out_dtype, schedule=sched,
                            num_lanes=num_lanes, interpret=interpret)

    cands = list(candidates) if candidates is not None \
        else candidate_schedules(nest, operands=operands)
    if DEFAULT_SCHEDULE not in cands:
        cands.insert(0, DEFAULT_SCHEDULE)
    survivors = rank_candidates(nest, cands, top_k=top_k)

    # Round-robin measurement: one timed call per survivor per round, so
    # machine drift (thermal, background load) hits every candidate
    # equally instead of biasing whichever was measured last.
    import jax

    clock = clock or time.perf_counter
    monitor = straggler
    if monitor is None:
        from repro.runtime.fault import StragglerMonitor

        # warmup = the first full round, so the baseline mixes every
        # candidate's step time before any sample can be flagged
        monitor = StragglerMonitor(warmup_steps=len(survivors))
    sample = 0
    stragglers = 0

    def _timed(sched: Schedule) -> float:
        nonlocal sample, stragglers
        resilience.inject("measure")
        t0 = clock()
        jax.block_until_ready(jax.tree.leaves(call(sched)))
        dt = clock() - t0
        if monitor.observe(sample, dt):
            # poisoned sample: re-race once rather than let a transient
            # stall decide (or distort) the committed winner
            stragglers += 1
            sample += 1
            resilience.inject("measure")
            t0 = clock()
            jax.block_until_ready(jax.tree.leaves(call(sched)))
            dt = clock() - t0
        sample += 1
        return dt

    best = [float("inf")] * len(survivors)
    try:
        for _ in range(max(0, warmup)):
            for sched in survivors:
                jax.block_until_ready(jax.tree.leaves(call(sched)))
        for _ in range(max(1, iters)):
            for i, sched in enumerate(survivors):
                best[i] = min(best[i], _timed(sched))
    except resilience.fallback_error_types() as e:
        resilience.record_fallback(
            seam=resilience.classify(e), site="autotune", error=e,
            from_schedule="measure", to_schedule="default", key=key)
        return TuneResult(key=key, schedule=DEFAULT_SCHEDULE, tuned_us=0.0,
                          default_us=0.0, candidates=len(cands), measured=0,
                          degraded=True, committed=False,
                          stragglers=stragglers)
    timings = [(us * 1e6, i, sched)
               for i, (us, sched) in enumerate(zip(best, survivors))]
    default_us = next(us for us, _, s in timings if s == DEFAULT_SCHEDULE)
    tuned_us, _, winner = min(timings)

    committed = False
    if cache is not None:
        try:
            cache.put(key, winner, meta={
                "tuned_us": tuned_us, "default_us": default_us,
                "candidates": len(cands), "measured": len(survivors),
                "stragglers": stragglers,
                "nest": nest_signature(nest), "mode": mode,
                "out_dtype": str(out_dtype), "cores": cores,
                "backend": _backend(),
            })
            committed = True
        except resilience.fallback_error_types() as e:
            # the winner is still valid for this process; only the
            # persistence failed — record it, don't crash the tuner
            resilience.record_fallback(
                seam=resilience.classify(e), site="autotune", error=e,
                from_schedule="winner", to_schedule="uncommitted", key=key)
    return TuneResult(key=key, schedule=winner, tuned_us=tuned_us,
                      default_us=default_us, candidates=len(cands),
                      measured=len(survivors), committed=committed,
                      stragglers=stragglers)


def invalidate(nest: LoopNest, operands: Dict[str, Any], *,
               mode: str = "reduce", out_dtype: str = "float32",
               cores: int = 1,
               cache: Optional[ScheduleCache] = None) -> bool:
    """Explicitly drop the cached schedule for one tuning problem."""
    cache = cache or global_cache()
    return cache.invalidate(
        cache_key(nest, operands, mode=mode, out_dtype=str(out_dtype),
                  cores=cores))


def quarantine(nest: LoopNest, operands: Dict[str, Any], *,
               mode: str = "reduce", out_dtype: str = "float32",
               cores: int = 1,
               cache: Optional[ScheduleCache] = None) -> str:
    """Sideline the committed schedule for one tuning problem.

    Dispatch calls this when a *tuned* schedule fails to lower or compile:
    the entry is renamed to ``.corrupt`` (invalidate + negative-cache +
    cross-process generation bump), so the poisoned winner cannot be
    served again while the default schedule carries the traffic.  Returns
    the quarantined key.
    """
    cache = cache or global_cache()
    key = cache_key(nest, operands, mode=mode, out_dtype=str(out_dtype),
                    cores=cores)
    cache.quarantine(key)
    return key


def quarantine_dag(nests: Sequence[LoopNest], operands: Dict[str, Any], *,
                   mode: str = "map", out_dtype: str = "float32",
                   cores: int = 1,
                   cache: Optional[ScheduleCache] = None,
                   uniforms: Optional[Dict[str, Any]] = None) -> str:
    """DAG-keyed twin of :func:`quarantine` for ``ssr_dag_call`` dispatch."""
    cache = cache or global_cache()
    key = dag_cache_key(nests, operands, mode=mode,
                        out_dtype=str(out_dtype), cores=cores,
                        uniforms=uniforms)
    cache.quarantine(key)
    return key


# --------------------------------------------------------------------------
# DAG fusion search: enumerate legal graph cuts, prune by the Eq. (1)–(3)
# model + VMEM budget, measure survivors, commit the winning partition.
#
# A "cut" is a set of edge indices into ``ChainDAG.edges``.  Every cut
# edge materialises its intermediate as an HBM buffer (one kernel stops,
# another reloads); every fused edge keeps it in VMEM scratch and credits
# the eliminated store+loads exactly as ``chain_dag``'s accounting does.
# The committed winner lands in the same :class:`ScheduleCache` under a
# DAG-specific key, so ``ssr_dag_call`` resolves the best partitioning
# transparently on the next dispatch.
# --------------------------------------------------------------------------


def dag_cache_key(nests: Sequence[LoopNest], operands: Dict[str, Any], *,
                  mode: str = "map", out_dtype: str = "float32",
                  backend: Optional[str] = None, cores: int = 1,
                  uniforms: Optional[Dict[str, Any]] = None) -> str:
    """Stable hex digest identifying one DAG fusion problem."""
    backend = backend or _backend()
    blob = json.dumps({
        "v": SCHEDULE_CACHE_VERSION,
        "dag": [nest_signature(n) for n in nests],
        "operands": operand_signature(operands),
        "uniforms": operand_signature(uniforms or {}),
        "mode": mode,
        "out_dtype": str(out_dtype),
        "backend": backend,
        "cores": int(cores),
    }, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


def lookup_dag(nests: Sequence[LoopNest], operands: Dict[str, Any], *,
               mode: str = "map", out_dtype: str = "float32",
               cores: int = 1,
               cache: Optional[ScheduleCache] = None,
               uniforms: Optional[Dict[str, Any]] = None) -> Schedule:
    """Cache-only partition resolution for ``ssr_dag_call`` dispatch."""
    cache = cache or global_cache()
    key = dag_cache_key(nests, operands, mode=mode,
                        out_dtype=str(out_dtype), cores=cores,
                        uniforms=uniforms)
    return cache.get(key) or DEFAULT_SCHEDULE


def enumerate_cuts(dag: ChainDAG) -> List[Tuple[int, ...]]:
    """Every subset of edge indices, smallest cuts first.

    DAGs here have a handful of edges (a diamond has 3–4), so the 2^E
    enumeration is exact; the legality and model prunes below do the
    narrowing.  ``()`` (all-fused) is always first, the full cut
    (all-unfused) always last.
    """
    idx = range(len(dag.edges))
    cuts: List[Tuple[int, ...]] = []
    for r in range(len(dag.edges) + 1):
        cuts.extend(itertools.combinations(idx, r))
    return cuts


def dag_cut_is_legal(dag: ChainDAG, cut: Sequence[int], *,
                     sched: Schedule = DEFAULT_SCHEDULE,
                     itemsize: int = 4) -> Tuple[bool, str]:
    """(legal, reason) for one cut: single-exit components + VMEM budget.

    A fused component must have exactly one stage whose value leaves it
    (the map/reduce epilogue writes one output), and each component's
    depth-buffered working set — external streams + cut-edge reloads +
    refcounted intermediate slots — must fit the VMEM budget.
    """
    from .lowering import _component_exit, _dag_components

    cutset = frozenset(int(i) for i in cut)
    for i in cutset:
        if not 0 <= i < len(dag.edges):
            return False, (f"edge index {i} out of range for "
                           f"{len(dag.edges)} edges")
    comps = _dag_components(dag, cutset)
    block = sched.rows * sched.lanes * itemsize
    depth = _max_depth(sched)
    for comp in comps:
        try:
            _component_exit(dag, comp, cutset)
        except LoweringError as e:
            return False, str(e)
        inside = set(comp)
        n_ext = sum(len(dag.stages[s].allocations) for s in comp)
        n_cut_in = sum(1 for i, e in enumerate(dag.edges)
                       if i in cutset and e.consumer_stage in inside)
        intra = {e.name for i, e in enumerate(dag.edges)
                 if i not in cutset and e.producer_stage in inside}
        vmem = (stream_vmem_bytes(block, depth) * (n_ext + n_cut_in)
                + stream_vmem_bytes(block, depth)   # the output stream
                + len(intra) * block                # intermediate slots
                + block)                            # reduce accumulator
        if vmem > VMEM_BUDGET_BYTES:
            return False, (f"component {comp} working set "
                           f"{vmem / 2**20:.1f} MiB exceeds budget "
                           f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB")
    return True, "ok"


def dag_model_cost(dag: ChainDAG, cut: Sequence[int], *,
                   sched: Schedule = DEFAULT_SCHEDULE,
                   step_cost: int = STEP_COST) -> float:
    """Eq. (1)–(3) cost of executing the DAG under one cut.

    Each fused component is ONE stream region (:func:`repro.core.compiler.
    _fused_region_count`: single setup, bodies summed, union of lanes);
    each cut edge charges the store its producer pays and the load its
    consumer re-issues (2·ΠL explicit accesses — exactly the accesses
    ``chain_dag`` credits as eliminated when the edge fuses); and every
    component pays the per-grid-step dispatch charge of its own kernel.
    """
    from .lowering import _dag_components

    cutset = frozenset(int(i) for i in cut)
    comps = _dag_components(dag, cutset)
    elems = math.prod(dag.bounds)
    steps = -(-dag.bounds[-1] // sched.block_elems) * \
        math.prod(dag.bounds[:-1])
    half = step_cost / 2.0
    per_step = half + half / (_max_depth(sched) - 1)
    total = 0.0
    for comp in comps:
        total += _fused_region_count([dag.stages[s] for s in comp],
                                     dag.bounds)
        total += per_step * steps
    cut_names = {dag.edges[i].name for i in cutset}
    total += elems * (len(cutset) + len(cut_names))  # loads + stores
    return float(total)


def autotune_dag(nests: Sequence[LoopNest], bodies: Sequence[Callable],
                 operands: Dict[str, Any], *,
                 mode: str = "map", out_dtype="float32",
                 num_lanes: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 top_k: int = 4, warmup: int = 1, iters: int = 3,
                 cores: int = 1,
                 cache: Optional[ScheduleCache] = None,
                 use_cache: bool = True, force: bool = False,
                 uniforms: Optional[Dict[str, Any]] = None) -> TuneResult:
    """Search the DAG's legal cuts → prune by model → measure → commit.

    The all-fused cut ``()`` and the full cut (all edges materialised —
    the unfused composition) always race, so the committed partition is
    never slower than either endpoint *as measured* — the gate
    ``bench_dag`` re-checks.  The winner is committed as a
    :class:`Schedule` whose ``cut_edges`` records the partition, under
    :func:`dag_cache_key`, so a subsequent plain ``ssr_dag_call`` resolves
    it transparently.
    """
    import jax

    from .lowering import _dag_for, _uniform_items, ssr_dag_call

    nests = tuple(nests)
    bodies = tuple(bodies)
    dag = _dag_for(nests, num_lanes)
    cache = cache or (global_cache() if use_cache else None)
    # normalise exactly like ssr_dag_call so the committed key matches the
    # one its transparent dispatch looks up
    uniforms = dict(_uniform_items(uniforms))
    key = dag_cache_key(nests, operands, mode=mode,
                        out_dtype=str(out_dtype), cores=cores,
                        uniforms=uniforms)
    if cache is not None and not force:
        hit = cache.get(key)
        if hit is not None:
            meta = cache.meta(key) or {}
            m = meta.get("meta", {})
            return TuneResult(key=key, schedule=hit,
                              tuned_us=float(m.get("tuned_us", 0.0)),
                              default_us=float(m.get("default_us", 0.0)),
                              candidates=int(m.get("candidates", 0)),
                              measured=0, from_cache=True)

    legal = [c for c in enumerate_cuts(dag)
             if dag_cut_is_legal(dag, c)[0]]
    full = tuple(range(len(dag.edges)))
    ranked = sorted(legal, key=lambda c: (dag_model_cost(dag, c), c))
    survivors = ranked[:max(1, top_k)]
    for anchor in ((), full):            # both endpoints always race
        if anchor in legal and anchor not in survivors:
            survivors.append(anchor)

    def call(cut: Tuple[int, ...]):
        sched = dataclasses.replace(DEFAULT_SCHEDULE, cut_edges=cut)
        return ssr_dag_call(nests, bodies, operands, mode=mode,
                            out_dtype=out_dtype, schedule=sched,
                            num_lanes=num_lanes, interpret=interpret,
                            uniforms=uniforms)

    best = [float("inf")] * len(survivors)
    try:
        for _ in range(max(0, warmup)):
            for cut in survivors:
                jax.block_until_ready(jax.tree.leaves(call(cut)))
        for _ in range(max(1, iters)):
            for i, cut in enumerate(survivors):
                resilience.inject("measure")
                t0 = time.perf_counter()
                jax.block_until_ready(jax.tree.leaves(call(cut)))
                best[i] = min(best[i], time.perf_counter() - t0)
    except resilience.fallback_error_types() as e:
        resilience.record_fallback(
            seam=resilience.classify(e), site="autotune_dag", error=e,
            from_schedule="measure", to_schedule="default", key=key)
        return TuneResult(key=key, schedule=DEFAULT_SCHEDULE, tuned_us=0.0,
                          default_us=0.0, candidates=len(legal), measured=0,
                          degraded=True, committed=False)
    timings = [(us * 1e6, cut) for us, cut in zip(best, survivors)]
    fused_us = next((us for us, c in timings if c == ()), float("inf"))
    tuned_us, winner_cut = min(timings, key=lambda t: (t[0], t[1]))
    winner = dataclasses.replace(DEFAULT_SCHEDULE, cut_edges=winner_cut)

    committed = False
    if cache is not None:
        try:
            cache.put(key, winner, meta={
                "tuned_us": tuned_us, "default_us": fused_us,
                "candidates": len(legal), "measured": len(survivors),
                "dag": [nest_signature(n) for n in nests],
                "edges": len(dag.edges), "cut_edges": list(winner_cut),
                "mode": mode, "out_dtype": str(out_dtype), "cores": cores,
                "backend": _backend(),
            })
            committed = True
        except resilience.fallback_error_types() as e:
            resilience.record_fallback(
                seam=resilience.classify(e), site="autotune_dag", error=e,
                from_schedule="winner", to_schedule="uncommitted", key=key)
    return TuneResult(key=key, schedule=winner, tuned_us=tuned_us,
                      default_us=fused_us, candidates=len(legal),
                      measured=len(survivors), committed=committed)
