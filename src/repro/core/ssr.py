"""``ssr_pallas`` — lower stream-semantic operands to a Pallas TPU kernel.

This is the TPU-native embodiment of the SSR extension.  The correspondence
(DESIGN.md §2):

* **stream register**  → a kernel ``Ref`` whose delivery schedule is owned by
  the framework.  The compute body reads/writes whole blocks with *zero*
  address arithmetic — the invariant the paper buys with its register-file
  wrapper.
* **AGU (bound/stride/repeat)** → the Pallas ``grid`` plus an affine
  ``index_map``.  We *verify* affinity (``agu.affine_coefficients``): a
  schedule the paper's AGU could not generate is rejected.
* **data mover + FIFO prefetch** → Pallas's double-buffered HBM→VMEM DMA
  pipeline.  Block ``i+1`` is fetched while block ``i`` computes, exactly the
  "proactively performs memory reads" behaviour of §2.3.  The FIFO depth is
  a *schedule knob* (``Schedule.buffer_depth``): depth 2 is Pallas's own
  pipeline; depth > 2 emits an explicit N-deep rotation of VMEM scratch
  buffers driven by ``make_async_copy`` DMAs, prefetching grid step
  ``i+N−1`` while step ``i`` computes (see :func:`ssr_pallas`'s
  ``buffer_depth``).  ``pltpu.emit_pipeline`` is *not* the emitter because
  it exposes no buffer-depth knob — the rotation is hand-rolled so the
  depth is actually honoured.
* **repeat register** → an ``index_map`` that revisits the same block across
  consecutive grid steps (e.g. a GEMM A-panel reused for every N-tile); the
  pipeline recognises the unchanged index and skips the re-fetch, as the FIFO
  re-emits a datum.
* **ssrcfg CSR** → ``region.ssr_enabled()``: modules pick streamed kernels or
  plain XLA ops; semantics are identical either way (tested).

Word- vs block-granularity is the deliberate hardware adaptation: a TPU
"word" for streaming purposes is a VMEM tile (the MXU consumes 128×128
operand panels; the VPU (8,128) vregs), so ``block_shape`` plays the role of
the stream's element width.  All *structural* properties — affine pattern,
run-ahead prefetch, read/write exclusivity, no address math in the body —
are preserved.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math
import os
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import agu
from .stream import Direction

# TPU v5e VMEM is 128 MiB/core; we budget conservatively for double buffering.
VMEM_BUDGET_BYTES = 64 * 1024 * 1024
_LANE = 128
_SUBLANE = {4: 8, 2: 16, 1: 32}  # min sublane tile per dtype byte width

#: The data mover's FIFO depth bounds.  Depth 2 is the classic
#: double-buffered pipeline (Pallas's own); deeper buffering trades VMEM
#: for DMA-latency run-ahead.  ``MAX_BUFFER_DEPTH`` bounds the schedule
#: search and keeps a runaway depth from eating the whole VMEM budget.
DEFAULT_BUFFER_DEPTH = 2
MAX_BUFFER_DEPTH = 8


def stream_vmem_bytes(block_bytes: int,
                      depth: int = DEFAULT_BUFFER_DEPTH) -> int:
    """VMEM footprint of one stream's in-flight blocks: ``depth`` buffers.

    THE single source of truth for the per-stream working-set budget —
    :meth:`StreamReport` (here) and ``core/autotune.py``'s legality check
    both call it, so the depth-aware accounting cannot drift between the
    executor and the search.  Conservative by design: loop-invariant
    streams rotate through one slot at run time but are still budgeted at
    full depth.
    """
    return depth * block_bytes


def pipeline_supported() -> bool:
    """Whether the explicit N-deep DMA rotation can be emitted here.

    The rotation needs the Pallas TPU primitives (``make_async_copy``,
    DMA semaphores, VMEM scratch, the ANY memory space) — available on TPU
    *and* in interpret mode on this jax version.  Absent primitives, or an
    explicit ``REPRO_DISABLE_PIPELINE`` opt-out, fall back to the
    synchronous (depth-2 Pallas pipeline) path; semantics are identical.
    """
    if os.environ.get("REPRO_DISABLE_PIPELINE"):
        return False
    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415
    except ImportError:  # pragma: no cover - pallas always ships tpu here
        return False
    return all(hasattr(pltpu, attr) for attr in
               ("make_async_copy", "SemaphoreType", "VMEM", "TPUMemorySpace"))


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover - no backend
        return False


@dataclasses.dataclass(frozen=True)
class BlockStream:
    """One SSR lane at block granularity.

    ``index_map(*grid_indices) -> block indices`` must be affine — the AGU
    constraint.  ``count_reuse`` marks streams whose map revisits blocks
    (the repeat register), which the cost model credits as FIFO reuse.
    """

    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[Any, ...]]
    direction: Direction = Direction.READ
    name: str = "stream"

    def block_bytes(self, dtype) -> int:
        return math.prod(self.block_shape) * jnp.dtype(dtype).itemsize

    def spec(self) -> pl.BlockSpec:
        return pl.BlockSpec(self.block_shape, self.index_map)


@dataclasses.dataclass(frozen=True)
class StreamReport:
    """Static data-movement accounting for one ``ssr_pallas`` kernel.

    The software analogue of the paper's Fig. 8 right axis: bytes that the
    data movers stream per invocation, the VMEM working set (double-
    buffered), and the FIFO-reuse savings from repeat-style index maps.
    """

    grid: Tuple[int, ...]
    vmem_bytes: int
    hbm_bytes_streamed: int
    hbm_bytes_unique: int
    scratch_bytes: int = 0   # kernel-resident VMEM (accumulators, chain links)

    @property
    def reuse_factor(self) -> float:
        return self.hbm_bytes_streamed / max(1, self.hbm_bytes_unique)


def _validate_affine(stream: BlockStream, grid: Tuple[int, ...]) -> None:
    got = agu.affine_coefficients(stream.index_map, grid)
    if got is None:
        raise ValueError(
            f"stream '{stream.name}': index_map is not affine in the grid "
            "indices — not expressible by the SSR AGU (bound/stride model)"
        )


def _unique_blocks(stream: BlockStream, grid: Tuple[int, ...]) -> int:
    """Number of distinct blocks the AGU touches over the whole grid.

    Exact for affine maps: walk the (small) grid index space.  Grids here
    are kernel-tile counts (≤ a few thousand), so this stays cheap.
    """
    seen = set()
    total = 1
    for g in grid:
        total *= g
    if total > 65536:  # closed-form fallback for very large grids
        affine = agu.affine_coefficients(stream.index_map, grid)
        if affine is None:
            # Non-affine map (possible when the kernel was built with
            # validate=False): no closed form — count conservatively, as if
            # every grid step touched a fresh block (no FIFO reuse credit).
            return total
        _, coeffs = affine
        # distinct blocks = product over grid dims with nonzero coeff
        distinct = 1
        for dim, c in enumerate(coeffs):
            if any(int(x) != 0 for x in c):
                distinct *= grid[dim]
        return distinct
    for idx in itertools.product(*[range(g) for g in grid]):
        seen.add(tuple(int(x) for x in stream.index_map(*idx)))
    return len(seen)


def _compiler_params(dimension_semantics: Tuple[str, ...]):
    """TPU compiler params across jax versions (TPUCompilerParams is the
    0.4.x name; CompilerParams the newer one)."""
    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    cls = getattr(pltpu, "TPUCompilerParams",
                  getattr(pltpu, "CompilerParams", None))
    if cls is None:  # pragma: no cover - one of the two always exists
        return None
    return cls(dimension_semantics=dimension_semantics)


def _flat_strides(grid: Tuple[int, ...]) -> Tuple[int, ...]:
    """Row-major strides of the grid's flat step index."""
    strides = [1] * len(grid)
    for k in range(len(grid) - 2, -1, -1):
        strides[k] = strides[k + 1] * grid[k + 1]
    return tuple(strides)


def _stream_is_invariant(stream: BlockStream, grid: Tuple[int, ...]) -> bool:
    """True when the index map ignores every grid axis (repeat register)."""
    affine = agu.affine_coefficients(stream.index_map, grid)
    if affine is None:
        return False
    _, coeffs = affine
    return all(int(x) == 0 for dim in coeffs for x in dim)


def _pipelined_call(
    body: Callable[..., None],
    *,
    grid: Tuple[int, ...],
    in_streams: Sequence[BlockStream],
    out_streams: Sequence[BlockStream],
    out_shapes: Sequence[jax.ShapeDtypeStruct],
    scratch_shapes: Sequence[Any],
    buffer_depth: Tuple[int, ...],
    interpret: bool,
    extra_kwargs: dict,
) -> Callable[..., Any]:
    """Emit the explicit N-deep HBM→VMEM rotation (pipelined emission).

    Inputs move to the ANY memory space (no Pallas block pipeline); read
    stream ``i`` gets ``depths[i]`` rotating VMEM scratch buffers and a
    DMA semaphore array — depths are *per stream* (``buffer_depth`` is a
    tuple, one entry per input), so a strided operand that misses in HBM
    can run deep while a unit-stride one stays shallow.  At flat grid step
    ``s`` the kernel *starts* stream ``i``'s fetch of step
    ``s + depths[i] − 1`` (into slot ``(s+depths[i]−1) % depths[i]``),
    *waits* on slot ``s % depths[i]``, and hands the body that slot's
    block — so ``depths[i] − 1`` fetches are in flight per stream while
    one block computes, the paper's "proactively performs memory reads" at
    configurable per-stream run-ahead.  Step 0 primes each stream's first
    ``depths[i] − 1`` fetches.  Loop-invariant streams (the repeat
    register) are fetched ONCE at step 0 and re-read from slot 0 every
    step — no re-fetch traffic at all.  Other revisit patterns (e.g. a
    GEMM A-panel reused across N-tiles) re-fetch each step: the rotation
    trades the sync pipeline's unchanged-index elision for run-ahead
    depth.  Outputs keep their normal BlockSpecs — only operand *delivery*
    changes, so numerics are bit-identical to the sync path.

    The grid (and therefore ``pl.program_id``-based accumulator logic in
    bodies) is preserved; every axis is sequential (``arbitrary``) because
    the rotation state threads through consecutive steps.
    """
    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    n_in = len(in_streams)
    n_out = len(out_streams)
    steps = math.prod(grid)
    strides = _flat_strides(grid)
    depths = tuple(buffer_depth)
    invariant = tuple(_stream_is_invariant(s, grid) for s in in_streams)
    zeros = tuple(0 for _ in grid)

    def _slices(stream: BlockStream, g) -> Tuple[Any, ...]:
        idx = stream.index_map(*g)
        return tuple(pl.ds(i * b, b)
                     for i, b in zip(idx, stream.block_shape))

    def wrapped(*refs):
        hbm = refs[:n_in]
        outs = refs[n_in:n_in + n_out]
        sc = refs[n_in + n_out:]
        bufs, sems = sc[:n_in], sc[n_in:2 * n_in]
        rest = sc[2 * n_in:]
        ids = tuple(pl.program_id(k) for k in range(len(grid)))
        s = ids[0]
        for k in range(1, len(grid)):
            s = s * grid[k] + ids[k]

        def unflatten(step):
            # works for python ints (priming) and traced ints (run-ahead)
            return tuple((step // st) % g for st, g in zip(strides, grid))

        def start(i, step, slot):
            g = unflatten(step)
            pltpu.make_async_copy(
                hbm[i].at[_slices(in_streams[i], g)],
                bufs[i].at[slot], sems[i].at[slot]).start()

        @pl.when(s == 0)
        def _prime():
            for i in range(n_in):      # repeat register: one fetch, ever
                if invariant[i]:
                    copy = pltpu.make_async_copy(
                        hbm[i].at[_slices(in_streams[i], zeros)],
                        bufs[i].at[0], sems[i].at[0])
                    copy.start()
                    copy.wait()
                    continue
                for j in range(min(depths[i] - 1, steps)):
                    start(i, j, j)

        for i in range(n_in):          # per-stream run-ahead fetch
            if invariant[i]:
                continue
            nxt = s + depths[i] - 1

            @pl.when(nxt < steps)
            def _prefetch(i=i, nxt=nxt):
                start(i, nxt, nxt % depths[i])

        blocks = []
        for i in range(n_in):
            if invariant[i]:
                blocks.append(bufs[i].at[0])
                continue
            slot = s % depths[i]
            pltpu.make_async_copy(
                hbm[i].at[_slices(in_streams[i], ids)],
                bufs[i].at[slot], sems[i].at[slot]).wait()
            blocks.append(bufs[i].at[slot])
        body(*blocks, *outs, *rest)

    def run(*arrays):
        if len(arrays) != n_in:
            raise ValueError(
                f"pipelined kernel expects {n_in} operands, got "
                f"{len(arrays)}")
        for a, st in zip(arrays, in_streams):
            if a.ndim != len(st.block_shape):
                raise ValueError(
                    f"stream '{st.name}': operand rank {a.ndim} != block "
                    f"rank {len(st.block_shape)} — pipelined emission "
                    "slices the prepared layout directly")
        rot = [pltpu.VMEM((d, *st.block_shape), jnp.dtype(a.dtype))
               for d, st, a in zip(depths, in_streams, arrays)]
        dma_sems = [pltpu.SemaphoreType.DMA((d,)) for d in depths]
        call = pl.pallas_call(
            wrapped,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
                      for _ in in_streams],
            out_specs=[s.spec() for s in out_streams]
            if n_out != 1 else out_streams[0].spec(),
            out_shape=list(out_shapes) if len(out_shapes) != 1
            else out_shapes[0],
            scratch_shapes=rot + dma_sems + list(scratch_shapes),
            interpret=interpret,
            **extra_kwargs,
        )
        return call(*arrays)

    return jax.jit(run)


def ssr_pallas(
    body: Callable[..., None],
    *,
    grid: Tuple[int, ...],
    in_streams: Sequence[BlockStream],
    out_streams: Sequence[BlockStream],
    out_shapes: Sequence[jax.ShapeDtypeStruct],
    scratch_shapes: Sequence[Any] = (),
    interpret: Optional[bool] = None,
    dimension_semantics: Optional[Tuple[str, ...]] = None,
    validate: bool = True,
    cost_estimate: Optional[pl.CostEstimate] = None,
    buffer_depth=DEFAULT_BUFFER_DEPTH,
) -> Callable[..., Any]:
    """Build a streamed Pallas kernel from SSR-style block streams.

    ``body(*in_refs, *out_refs, *scratch_refs)`` is the pure compute region —
    the "SSR region" of Fig. 4 ③.  Returns a jitted callable; the attached
    ``.report(*, dtypes)`` computes the :class:`StreamReport`.

    ``buffer_depth`` sets the data mover's FIFO depth — a uniform ``int``,
    or a tuple with one depth per *input* stream (asymmetric run-ahead:
    deep for the strided operand, shallow for the unit-stride one).
    Depth 2 (default) is Pallas's own double-buffered pipeline; any depth
    > 2 emits the explicit N-deep rotation (:func:`_pipelined_call`) when
    the platform supports it (:func:`pipeline_supported`) and the grid has
    more than one step, falling back to the synchronous path otherwise —
    numerics are identical either way.  The attached ``fn.pipelined`` flag
    records which emitter actually ran; the VMEM report always budgets at
    the *requested* depths (:func:`stream_vmem_bytes`) — each input at its
    own depth, outputs at the maximum — so a schedule legal here is legal
    on the deepest path it might take.
    """
    for s in in_streams:
        if s.direction != Direction.READ:
            raise ValueError(f"input stream '{s.name}' must be a read stream")
    for s in out_streams:
        if s.direction != Direction.WRITE:
            raise ValueError(f"output stream '{s.name}' must be a write stream")
    if len(out_streams) != len(out_shapes):
        raise ValueError("one out_shape per output stream")
    if isinstance(buffer_depth, (tuple, list)):
        depths = tuple(int(d) for d in buffer_depth)
        if len(depths) != len(in_streams):
            raise ValueError(
                f"buffer_depth tuple has {len(depths)} entries for "
                f"{len(in_streams)} input streams; give one depth per "
                "stream")
        check = depths
    else:
        depths = (int(buffer_depth),) * len(in_streams)
        check = (int(buffer_depth),)
    for d in check:
        if not DEFAULT_BUFFER_DEPTH <= d <= MAX_BUFFER_DEPTH:
            raise ValueError(
                f"buffer_depth {d} outside "
                f"[{DEFAULT_BUFFER_DEPTH}, {MAX_BUFFER_DEPTH}] — depth < 2 "
                "cannot overlap fetch with compute, deeper than "
                f"{MAX_BUFFER_DEPTH} would eat the VMEM budget")
    max_depth = max(depths) if depths else DEFAULT_BUFFER_DEPTH
    if validate:
        for s in (*in_streams, *out_streams):
            _validate_affine(s, grid)

    if interpret is None:
        interpret = not _on_tpu()

    pipelined = (max_depth > DEFAULT_BUFFER_DEPTH
                 and pipeline_supported()
                 and len(grid) >= 1 and math.prod(grid) > 1)

    kwargs: dict = {}
    if pipelined:
        # rotation state threads through consecutive steps: every axis is
        # sequential regardless of the caller's declared semantics
        dimension_semantics = ("arbitrary",) * len(grid)
    if dimension_semantics is not None and not interpret:
        params = _compiler_params(dimension_semantics)
        if params is not None:
            kwargs["compiler_params"] = params
    if cost_estimate is not None:
        kwargs["cost_estimate"] = cost_estimate

    if pipelined:
        fn = _pipelined_call(
            body, grid=grid, in_streams=in_streams,
            out_streams=out_streams, out_shapes=out_shapes,
            scratch_shapes=scratch_shapes, buffer_depth=depths,
            interpret=interpret, extra_kwargs=kwargs)
    else:
        call = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[s.spec() for s in in_streams],
            out_specs=[s.spec() for s in out_streams]
            if len(out_streams) != 1
            else out_streams[0].spec(),
            out_shape=list(out_shapes) if len(out_shapes) != 1
            else out_shapes[0],
            scratch_shapes=list(scratch_shapes),
            interpret=interpret,
            **kwargs,
        )

        fn = jax.jit(call)

    def report(dtypes: Sequence[Any]) -> StreamReport:
        streams = (*in_streams, *out_streams)
        if len(dtypes) != len(streams):
            raise ValueError("one dtype per stream")
        steps = math.prod(grid)
        vmem = 0
        streamed = 0
        unique = 0
        for idx, (s, dt) in enumerate(zip(streams, dtypes)):
            bb = s.block_bytes(dt)
            # inputs at their own FIFO depth; outputs at the deepest
            d = depths[idx] if idx < len(in_streams) else max_depth
            vmem += stream_vmem_bytes(bb, d)
            streamed += bb * steps
            unique += bb * _unique_blocks(s, grid)
        # Kernel-resident scratch (reduce accumulators, chained-intermediate
        # blocks) is single-buffered but counts against the same budget.
        scratch = 0
        for sc in scratch_shapes:
            shape = getattr(sc, "shape", None)
            dt = getattr(sc, "dtype", None)
            if shape is not None and dt is not None:
                scratch += math.prod(shape) * jnp.dtype(dt).itemsize
        if vmem + scratch > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"VMEM working set {(vmem + scratch)/2**20:.1f} MiB exceeds "
                f"budget {VMEM_BUDGET_BYTES/2**20:.0f} MiB — shrink "
                "block_shape"
            )
        return StreamReport(grid=grid, vmem_bytes=vmem,
                            hbm_bytes_streamed=streamed,
                            hbm_bytes_unique=unique,
                            scratch_bytes=scratch)

    fn.report = report  # type: ignore[attr-defined]
    fn.grid = grid  # type: ignore[attr-defined]
    fn.buffer_depth = buffer_depth  # type: ignore[attr-defined]
    fn.pipelined = pipelined  # type: ignore[attr-defined]
    return fn


def check_mxu_alignment(block_shape: Tuple[int, ...], dtype) -> bool:
    """True if the trailing dims are hardware-aligned (lane=128, sublane)."""
    if len(block_shape) < 2:
        return block_shape[-1] % _LANE == 0
    itemsize = jnp.dtype(dtype).itemsize
    sub = _SUBLANE.get(itemsize, 8)
    return block_shape[-1] % _LANE == 0 and block_shape[-2] % sub == 0


def auto_block(dim: int, target: int, align: int) -> int:
    """Largest aligned block ≤ target that tiles ``dim`` exactly."""
    b = min(dim, max(align, (target // align) * align))
    while b > align and dim % b != 0:
        b -= align
    if dim % b != 0:
        b = math.gcd(dim, b) or dim
    return b
