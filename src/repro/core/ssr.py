"""``ssr_pallas`` — lower stream-semantic operands to a Pallas TPU kernel.

This is the TPU-native embodiment of the SSR extension.  The correspondence
(DESIGN.md §2):

* **stream register**  → a kernel ``Ref`` whose delivery schedule is owned by
  the framework.  The compute body reads/writes whole blocks with *zero*
  address arithmetic — the invariant the paper buys with its register-file
  wrapper.
* **AGU (bound/stride/repeat)** → the Pallas ``grid`` plus an affine
  ``index_map``.  We *verify* affinity (``agu.affine_coefficients``): a
  schedule the paper's AGU could not generate is rejected.
* **data mover + FIFO prefetch** → Pallas's double-buffered HBM→VMEM DMA
  pipeline.  Block ``i+1`` is fetched while block ``i`` computes, exactly the
  "proactively performs memory reads" behaviour of §2.3.
* **repeat register** → an ``index_map`` that revisits the same block across
  consecutive grid steps (e.g. a GEMM A-panel reused for every N-tile); the
  pipeline recognises the unchanged index and skips the re-fetch, as the FIFO
  re-emits a datum.
* **ssrcfg CSR** → ``region.ssr_enabled()``: modules pick streamed kernels or
  plain XLA ops; semantics are identical either way (tested).

Word- vs block-granularity is the deliberate hardware adaptation: a TPU
"word" for streaming purposes is a VMEM tile (the MXU consumes 128×128
operand panels; the VPU (8,128) vregs), so ``block_shape`` plays the role of
the stream's element width.  All *structural* properties — affine pattern,
run-ahead prefetch, read/write exclusivity, no address math in the body —
are preserved.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import agu
from .stream import Direction

# TPU v5e VMEM is 128 MiB/core; we budget conservatively for double buffering.
VMEM_BUDGET_BYTES = 64 * 1024 * 1024
_LANE = 128
_SUBLANE = {4: 8, 2: 16, 1: 32}  # min sublane tile per dtype byte width


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover - no backend
        return False


@dataclasses.dataclass(frozen=True)
class BlockStream:
    """One SSR lane at block granularity.

    ``index_map(*grid_indices) -> block indices`` must be affine — the AGU
    constraint.  ``count_reuse`` marks streams whose map revisits blocks
    (the repeat register), which the cost model credits as FIFO reuse.
    """

    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[Any, ...]]
    direction: Direction = Direction.READ
    name: str = "stream"

    def block_bytes(self, dtype) -> int:
        return math.prod(self.block_shape) * jnp.dtype(dtype).itemsize

    def spec(self) -> pl.BlockSpec:
        return pl.BlockSpec(self.block_shape, self.index_map)


@dataclasses.dataclass(frozen=True)
class StreamReport:
    """Static data-movement accounting for one ``ssr_pallas`` kernel.

    The software analogue of the paper's Fig. 8 right axis: bytes that the
    data movers stream per invocation, the VMEM working set (double-
    buffered), and the FIFO-reuse savings from repeat-style index maps.
    """

    grid: Tuple[int, ...]
    vmem_bytes: int
    hbm_bytes_streamed: int
    hbm_bytes_unique: int
    scratch_bytes: int = 0   # kernel-resident VMEM (accumulators, chain links)

    @property
    def reuse_factor(self) -> float:
        return self.hbm_bytes_streamed / max(1, self.hbm_bytes_unique)


def _validate_affine(stream: BlockStream, grid: Tuple[int, ...]) -> None:
    got = agu.affine_coefficients(stream.index_map, grid)
    if got is None:
        raise ValueError(
            f"stream '{stream.name}': index_map is not affine in the grid "
            "indices — not expressible by the SSR AGU (bound/stride model)"
        )


def _unique_blocks(stream: BlockStream, grid: Tuple[int, ...]) -> int:
    """Number of distinct blocks the AGU touches over the whole grid.

    Exact for affine maps: walk the (small) grid index space.  Grids here
    are kernel-tile counts (≤ a few thousand), so this stays cheap.
    """
    seen = set()
    total = 1
    for g in grid:
        total *= g
    if total > 65536:  # closed-form fallback for very large grids
        affine = agu.affine_coefficients(stream.index_map, grid)
        if affine is None:
            # Non-affine map (possible when the kernel was built with
            # validate=False): no closed form — count conservatively, as if
            # every grid step touched a fresh block (no FIFO reuse credit).
            return total
        _, coeffs = affine
        # distinct blocks = product over grid dims with nonzero coeff
        distinct = 1
        for dim, c in enumerate(coeffs):
            if any(int(x) != 0 for x in c):
                distinct *= grid[dim]
        return distinct
    for idx in itertools.product(*[range(g) for g in grid]):
        seen.add(tuple(int(x) for x in stream.index_map(*idx)))
    return len(seen)


def ssr_pallas(
    body: Callable[..., None],
    *,
    grid: Tuple[int, ...],
    in_streams: Sequence[BlockStream],
    out_streams: Sequence[BlockStream],
    out_shapes: Sequence[jax.ShapeDtypeStruct],
    scratch_shapes: Sequence[Any] = (),
    interpret: Optional[bool] = None,
    dimension_semantics: Optional[Tuple[str, ...]] = None,
    validate: bool = True,
    cost_estimate: Optional[pl.CostEstimate] = None,
) -> Callable[..., Any]:
    """Build a streamed Pallas kernel from SSR-style block streams.

    ``body(*in_refs, *out_refs, *scratch_refs)`` is the pure compute region —
    the "SSR region" of Fig. 4 ③.  Returns a jitted callable; the attached
    ``.report(*, dtypes)`` computes the :class:`StreamReport`.
    """
    for s in in_streams:
        if s.direction != Direction.READ:
            raise ValueError(f"input stream '{s.name}' must be a read stream")
    for s in out_streams:
        if s.direction != Direction.WRITE:
            raise ValueError(f"output stream '{s.name}' must be a write stream")
    if len(out_streams) != len(out_shapes):
        raise ValueError("one out_shape per output stream")
    if validate:
        for s in (*in_streams, *out_streams):
            _validate_affine(s, grid)

    if interpret is None:
        interpret = not _on_tpu()

    kwargs: dict = {}
    if dimension_semantics is not None and not interpret:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=dimension_semantics
        )
    if cost_estimate is not None:
        kwargs["cost_estimate"] = cost_estimate

    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[s.spec() for s in in_streams],
        out_specs=[s.spec() for s in out_streams]
        if len(out_streams) != 1
        else out_streams[0].spec(),
        out_shape=list(out_shapes) if len(out_shapes) != 1 else out_shapes[0],
        scratch_shapes=list(scratch_shapes),
        interpret=interpret,
        **kwargs,
    )

    fn = jax.jit(call)

    def report(dtypes: Sequence[Any]) -> StreamReport:
        streams = (*in_streams, *out_streams)
        if len(dtypes) != len(streams):
            raise ValueError("one dtype per stream")
        steps = math.prod(grid)
        vmem = 0
        streamed = 0
        unique = 0
        for s, dt in zip(streams, dtypes):
            bb = s.block_bytes(dt)
            vmem += 2 * bb  # double-buffered (data mover FIFO depth 2)
            streamed += bb * steps
            unique += bb * _unique_blocks(s, grid)
        # Kernel-resident scratch (reduce accumulators, chained-intermediate
        # blocks) is single-buffered but counts against the same budget.
        scratch = 0
        for sc in scratch_shapes:
            shape = getattr(sc, "shape", None)
            dt = getattr(sc, "dtype", None)
            if shape is not None and dt is not None:
                scratch += math.prod(shape) * jnp.dtype(dt).itemsize
        if vmem + scratch > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"VMEM working set {(vmem + scratch)/2**20:.1f} MiB exceeds "
                f"budget {VMEM_BUDGET_BYTES/2**20:.0f} MiB — shrink "
                "block_shape"
            )
        return StreamReport(grid=grid, vmem_bytes=vmem,
                            hbm_bytes_streamed=streamed,
                            hbm_bytes_unique=unique,
                            scratch_bytes=scratch)

    fn.report = report  # type: ignore[attr-defined]
    fn.grid = grid  # type: ignore[attr-defined]
    return fn


def check_mxu_alignment(block_shape: Tuple[int, ...], dtype) -> bool:
    """True if the trailing dims are hardware-aligned (lane=128, sublane)."""
    if len(block_shape) < 2:
        return block_shape[-1] % _LANE == 0
    itemsize = jnp.dtype(dtype).itemsize
    sub = _SUBLANE.get(itemsize, 8)
    return block_shape[-1] % _LANE == 0 and block_shape[-2] % sub == 0


def auto_block(dim: int, target: int, align: int) -> int:
    """Largest aligned block ≤ target that tiles ``dim`` exactly."""
    b = min(dim, max(align, (target // align) * align))
    while b > align and dim % b != 0:
        b -= align
    if dim % b != 0:
        b = math.gcd(dim, b) or dim
    return b
