"""StreamSpec: the software form of the SSR address-generator configuration.

The paper's AGU (Fig. 3) exposes ten memory-mapped registers: a status register
(pointer, #enabled dims, direction, done flag), a ``repeat`` register, and
``bound0-3`` / ``stride0-3`` for up to four nested loop dimensions.  A
:class:`StreamSpec` is exactly that configuration, expressed in elements rather
than bytes (the TPU adaptation streams *blocks*; see ``core/ssr.py``).

Conventions
-----------
* ``bounds``/``strides`` are ordered **outermost first** (``bounds[-1]`` is the
  innermost loop), matching the paper's ``L_1 .. L_d`` with ``i = 1`` outermost.
* ``repeat = r`` emits each datum ``r`` times back-to-back (the paper's repeat
  register, used when one loaded value feeds several compute instructions).
* A stream is read-only or write-only for its whole lifetime (paper §2.3: "a
  stream cannot be used to interleave read and write operations").
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterator, Sequence, Tuple

MAX_DIMS = 4  # the paper's AGU supports four nested loop dimensions (§3.1)


class Direction(enum.Enum):
    READ = "read"
    WRITE = "write"


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Affine address pattern for one SSR data-mover lane.

    Addresses are emitted (outermost-first iteration order)::

        for i_1 in range(bounds[0]):
          ...
            for i_d in range(bounds[-1]):
              addr = base + sum(i_k * strides[k])   # emitted ``repeat`` times
    """

    bounds: Tuple[int, ...]
    strides: Tuple[int, ...]
    base: int = 0
    repeat: int = 1
    direction: Direction = Direction.READ

    def __post_init__(self) -> None:
        if not (1 <= len(self.bounds) <= MAX_DIMS):
            raise ValueError(
                f"SSR AGU supports 1..{MAX_DIMS} loop dims, got {len(self.bounds)}"
            )
        if len(self.strides) != len(self.bounds):
            raise ValueError("bounds and strides must have equal length")
        if any(b <= 0 for b in self.bounds):
            raise ValueError(f"loop bounds must be positive, got {self.bounds}")
        if self.repeat < 1:
            raise ValueError("repeat must be >= 1")
        if self.direction == Direction.WRITE and self.repeat != 1:
            # Writing the same datum repeatedly is meaningless; the paper's
            # repeat register only applies to read streams.
            raise ValueError("write streams cannot use repeat > 1")

    # -- geometry ---------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.bounds)

    @property
    def num_iterations(self) -> int:
        """Total loop-nest iterations  Π L_i  (pattern length before repeat)."""
        return math.prod(self.bounds)

    @property
    def num_transactions(self) -> int:
        """Register-file transactions seen by the core ( Π L_i · repeat )."""
        return self.num_iterations * self.repeat

    @property
    def num_memory_accesses(self) -> int:
        """Memory-side accesses. Repeated data is fetched once (FIFO reuse)."""
        return self.num_iterations

    def addresses(self) -> Iterator[int]:
        """Reference enumeration of the emitted address sequence.

        This is the plain-Python oracle; ``core/agu.py`` provides the
        vectorised equivalent used inside kernels/tests.
        """
        idx = [0] * self.ndim
        total = self.num_iterations
        for _ in range(total):
            addr = self.base + sum(i * s for i, s in zip(idx, self.strides))
            for _ in range(self.repeat):
                yield addr
            # odometer increment, innermost fastest
            for k in reversed(range(self.ndim)):
                idx[k] += 1
                if idx[k] < self.bounds[k]:
                    break
                idx[k] = 0

    def address_range(self) -> Tuple[int, int]:
        """(min, max) element address touched — for overlap/race checks."""
        lo = self.base + sum(
            (b - 1) * s for b, s in zip(self.bounds, self.strides) if s < 0
        )
        hi = self.base + sum(
            (b - 1) * s for b, s in zip(self.bounds, self.strides) if s > 0
        )
        return lo, hi

    def touches(self, other: "StreamSpec") -> bool:
        """Conservative overlap test between two streams' address ranges."""
        a_lo, a_hi = self.address_range()
        b_lo, b_hi = other.address_range()
        return not (a_hi < b_lo or b_hi < a_lo)

    # -- derived views ----------------------------------------------------
    def with_direction(self, direction: Direction) -> "StreamSpec":
        return dataclasses.replace(self, direction=direction)

    def config_writes(self) -> int:
        """Number of memory-mapped config stores needed to program this lane.

        Paper Fig. 4 / Eq. (1): each lane is programmed with ``bound``/
        ``stride`` per enabled dim plus the status/trigger write.  Used by the
        ISA model's setup accounting.
        """
        return 2 * self.ndim + 1


def contiguous(n: int, *, base: int = 0,
               direction: Direction = Direction.READ) -> StreamSpec:
    """1-D unit-stride stream — the dot-product pattern of Fig. 4."""
    return StreamSpec(bounds=(n,), strides=(1,), base=base, direction=direction)


def strided_2d(rows: int, cols: int, row_stride: int, *, base: int = 0,
               col_stride: int = 1,
               direction: Direction = Direction.READ) -> StreamSpec:
    """2-D pattern (row-major matrix walk), e.g. GEMV operand streaming."""
    return StreamSpec(bounds=(rows, cols), strides=(row_stride, col_stride),
                      base=base, direction=direction)


def validate_no_race(reads: Sequence[StreamSpec],
                     writes: Sequence[StreamSpec]) -> None:
    """Enforce the paper's coherence rule (§2.3).

    The data mover prefetches ahead, so a write stream must not touch a memory
    range concurrently used by a read stream ("write operations shall not be
    performed on a memory range that is currently used in a read stream").
    """
    for w in writes:
        for r in reads:
            if w.touches(r):
                raise ValueError(
                    "SSR race: write stream overlaps a live read stream "
                    f"(write range {w.address_range()}, read range "
                    f"{r.address_range()}); the data mover's proactive "
                    "prefetch makes this incoherent (paper §2.3)"
                )
