"""Loop-nest IR + shared analysis for the §3.2 pipeline.

This module owns the compiler's input "MIR" — :class:`LoopNest` of affine
:class:`MemRef` accesses — and the analyses every stage of the pipeline
needs.  Before it existed, ``compiler.ssrify``, ``compiler.chain``,
``compiler.cluster_cost`` and ``lowering.ssr_call`` each re-derived the same
facts privately (ref depth, lane counts, residual instruction folding);
now there is exactly one answer per question:

* **depth / classification** — :func:`ref_depth`, :func:`reads`,
  :func:`writes`, :func:`affine_refs`, :func:`output_ref`;
* **lane inference** — :func:`auto_lanes`: the ``num_lanes=None``
  convention (allocate every affine ref) used by ``ssr_call``, ``chain``
  and ``cluster_cost``;
* **contraction detection** — :func:`contraction_axes`: the loop levels a
  ref is *revisited* across (coefficient 0 while the level iterates).  For
  a READ ref these are the repeat-register levels (§3.1); for the output
  WRITE ref they are the reduction loops whose partial sums the lowering
  must keep in an accumulator (init on first step, drain on last);
* **layout** — :func:`storage_order`: the permutation of varying levels
  that makes the ref a dense row-major array, or ``None`` when no such
  layout exists (the access is not expressible as whole-block DMA).

Everything here is pure Python over frozen dataclasses — importable by the
compiler, the lowering, the cluster layer and the benchmarks without any
jax dependency.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from .stream import Direction, MAX_DIMS


@dataclasses.dataclass(frozen=True)
class MemRef:
    """One load/store whose address is affine in the loop indices.

    ``coeffs[k]`` multiplies loop index ``k`` (outermost first); accesses with
    a non-affine address are represented by ``coeffs=None`` and are never
    SSR-ified (the MIR pattern-match fails — §3.2 step 2).

    **Indirect refs** (Indirection-SSR, arXiv 2011.08070 / Sparse SSR,
    arXiv 2305.05559): when ``index_of`` names another READ ref in the same
    nest, this ref's address is *data-dependent* — the value produced by the
    index stream at each step drives the address::

        addr = index_scale * value(index_of) + Σ_k coeffs[k]·i_k + offset

    ``coeffs`` then holds only the affine *additive* part (e.g. the dense
    column walk of SpMM's B operand); the gather base walks wherever the
    index stream points.  Indirect refs are not affine (:meth:`is_affine` is
    False — no static storage order exists), but they *are* streamable: the
    compiler allocates them a lane and the lowering serves them with an
    in-kernel gather from a VMEM-resident table.

    **Halo (overlapping-read) refs**: ``window[l] > 1`` declares that each
    iteration reads elements ``addr .. addr + (window[l]−1)·coeffs[l]``
    along level ``l`` — the overlapping stencil window the flat AGU model
    cannot express as disjoint blocks.  ``coeffs`` still describes the
    *base-corner* walk (one step per loop index), so the logical array is
    ``bounds[l] + window[l] − 1`` elements long on each windowed level.
    The lowering fetches the halo via shifted index_maps (DESIGN.md §13).

    **Online-rescaled accumulators**: ``acc_kind="online_softmax"`` on a
    WRITE ref revisited across a contraction axis asks the lowering for the
    flash-attention-style carried (max, sum, acc) triple instead of a plain
    sum accumulator — each contraction step rescales the running state by
    ``exp(m_old − m_new)``, so the softmax normaliser streams in one pass.
    """

    name: str
    kind: Direction
    coeffs: Optional[Tuple[int, ...]]  # None => not affine
    offset: int = 0
    depth: Optional[int] = None  # innermost loop level the access lives in
    index_of: Optional[str] = None  # name of the index stream driving addrs
    index_scale: int = 1  # elements per index step (row pitch of the table)
    window: Optional[Tuple[int, ...]] = None  # per-level read extents (halo)
    acc_kind: str = "sum"  # "sum" | "online_softmax" (WRITE refs only)

    def is_indirect(self) -> bool:
        return self.index_of is not None

    def is_affine(self) -> bool:
        return self.coeffs is not None and self.index_of is None

    def has_window(self) -> bool:
        """True when any level reads an overlapping (halo) window."""
        return self.window is not None and any(w > 1 for w in self.window)


@dataclasses.dataclass(frozen=True)
class LoopNest:
    """A perfect loop nest with known bounds (outermost first)."""

    bounds: Tuple[int, ...]
    refs: Tuple[MemRef, ...]
    compute_per_level: Tuple[int, ...]  # useful ops per body, per level

    def __post_init__(self) -> None:
        if len(self.bounds) > MAX_DIMS:
            raise ValueError(
                f"nest depth {len(self.bounds)} exceeds AGU dims ({MAX_DIMS}); "
                "outer levels must stay in software (paper §3.1)"
            )
        if len(self.compute_per_level) != len(self.bounds):
            raise ValueError("compute_per_level must match nest depth")
        by_name = {r.name: r for r in self.refs}
        for r in self.refs:
            if r.acc_kind not in ("sum", "online_softmax"):
                raise ValueError(
                    f"ref {r.name!r}: unknown acc_kind {r.acc_kind!r} "
                    "(expected 'sum' or 'online_softmax')")
            if r.acc_kind == "online_softmax" and r.kind != Direction.WRITE:
                raise ValueError(
                    f"ref {r.name!r}: acc_kind='online_softmax' only makes "
                    "sense on the output WRITE ref (the rescaled accumulator)")
            if r.window is not None:
                if not r.is_affine() or r.kind != Direction.READ:
                    raise ValueError(
                        f"ref {r.name!r}: halo windows are only supported on "
                        "affine READ refs")
                if len(r.window) != len(self.bounds):
                    raise ValueError(
                        f"ref {r.name!r}: window {r.window} must give one "
                        f"extent per loop level ({len(self.bounds)})")
                for l, w in enumerate(r.window):
                    if w < 1:
                        raise ValueError(
                            f"ref {r.name!r}: window extents must be >= 1, "
                            f"got {r.window}")
                    if w > 1 and r.coeffs[l] == 0:
                        raise ValueError(
                            f"ref {r.name!r}: window {r.window} opens on "
                            f"level {l}, whose coefficient is 0 — a halo "
                            "only widens levels the address varies with")
        for r in self.refs:
            if not r.is_indirect():
                continue
            idx = by_name.get(r.index_of)
            if idx is None:
                raise ValueError(
                    f"indirect ref {r.name!r} names index stream "
                    f"{r.index_of!r}, which is not a ref of this nest")
            if not idx.is_affine() or idx.kind != Direction.READ:
                raise ValueError(
                    f"indirect ref {r.name!r}: its index stream "
                    f"{r.index_of!r} must be an affine READ ref")
            if r.coeffs is None:
                raise ValueError(
                    f"indirect ref {r.name!r} needs coeffs for the affine "
                    "additive part of its address (all-zero for pure gather)")
            if r.kind != Direction.READ:
                raise ValueError(
                    f"indirect ref {r.name!r}: indirect WRITE (scatter) is "
                    "not supported — only gather streams are lowered")
            if r.index_scale < 1:
                raise ValueError(
                    f"indirect ref {r.name!r}: index_scale must be >= 1")

    @property
    def depth(self) -> int:
        return len(self.bounds)


# -- classification ----------------------------------------------------------


def reads(nest: LoopNest) -> Tuple[MemRef, ...]:
    return tuple(r for r in nest.refs if r.kind == Direction.READ)


def writes(nest: LoopNest) -> Tuple[MemRef, ...]:
    return tuple(r for r in nest.refs if r.kind == Direction.WRITE)


def affine_refs(nest: LoopNest) -> Tuple[MemRef, ...]:
    return tuple(r for r in nest.refs if r.is_affine())


def indirect_refs(nest: LoopNest) -> Tuple[MemRef, ...]:
    """Refs whose addresses are driven by an index stream (gathers)."""
    return tuple(r for r in nest.refs if r.is_indirect())


def streamable_refs(nest: LoopNest) -> Tuple[MemRef, ...]:
    """Every ref a data-mover lane can serve: affine walks plus gathers."""
    return tuple(r for r in nest.refs if r.is_affine() or r.is_indirect())


def index_stream_of(ref: MemRef, nest: LoopNest) -> MemRef:
    """The affine READ ref whose values drive ``ref``'s addresses."""
    assert ref.is_indirect(), f"ref {ref.name!r} is not indirect"
    return next(r for r in nest.refs if r.name == ref.index_of)


def output_ref(nest: LoopNest) -> Optional[MemRef]:
    """The nest's single output WRITE ref, or ``None`` for read-only nests.

    A nest with more than one write has no single-accumulator lowering;
    callers that need one (``ssr_call``'s nest-output path) treat that as a
    lowering failure.
    """
    ws = writes(nest)
    if not ws:
        return None
    if len(ws) > 1:
        raise ValueError(
            f"nest has {len(ws)} write refs "
            f"({[w.name for w in ws]}); expected at most one output")
    return ws[0]


def ref_depth(ref: MemRef, nest: LoopNest) -> int:
    """Deepest loop level whose index the address actually varies with.

    An indirect ref's address changes whenever its *index stream* advances
    or its own affine additive part varies, so its depth is the max of the
    two.
    """
    if ref.depth is not None:
        return ref.depth
    if ref.is_indirect():
        depth = ref_depth(index_stream_of(ref, nest), nest)
        for k, c in enumerate(ref.coeffs):
            if c != 0:
                depth = max(depth, k)
        return depth
    if not ref.is_affine():
        return -1
    depth = 0
    for k, c in enumerate(ref.coeffs):
        if c != 0:
            depth = k
    return depth


def varying_levels(ref: MemRef) -> Tuple[int, ...]:
    """Loop levels (outermost first) the ref's address varies with."""
    assert ref.coeffs is not None, "non-affine refs have no varying levels"
    return tuple(k for k, c in enumerate(ref.coeffs) if c != 0)


def contraction_axes(ref: MemRef, nest: LoopNest) -> Tuple[int, ...]:
    """Levels the ref is *revisited* across (coefficient 0, bound iterates).

    For a READ ref these are the repeat-register levels (§3.1: "a value
    loaded from memory is used as an operand multiple times"); for a WRITE
    ref they are the contraction (reduction) loops — the same address is
    written once per surrounding iteration, so the lowering must accumulate
    partials and drain only on the last revisit.
    """
    assert ref.coeffs is not None
    return tuple(k for k, c in enumerate(ref.coeffs)
                 if c == 0 and nest.bounds[k] > 1)


# -- lane inference ----------------------------------------------------------


def auto_lanes(nest: LoopNest, num_lanes: Optional[int] = None) -> int:
    """Data-mover lanes to allocate: every affine ref, unless overridden.

    This is the ``num_lanes=None`` convention shared by ``ssr_call``,
    ``chain`` and ``cluster_cost`` — the execution layer streams every
    pattern-matched access, leaving Eq. (3) to the *static* verdict only.
    """
    if num_lanes is not None:
        return num_lanes
    return max(1, len(streamable_refs(nest)))


# -- cost-model helpers ------------------------------------------------------


def instr_counts(nest: LoopNest,
                 residual: Sequence[MemRef] = ()) -> List[int]:
    """Per-level body instruction counts with residual accesses folded in.

    Residual (non-streamed) loads/stores stay in the body at their depth —
    the Eq. (1)/(2) accounting both ``ssrify`` and ``chain`` apply.  A
    residual *indirect* access costs two body instructions, not one: the
    address computation from the index value (pointer arithmetic) plus the
    data load itself — the index-handling overhead the indirection
    extensions (arXiv 2011.08070 / 2305.05559) exist to eliminate.
    """
    counts = list(nest.compute_per_level)
    for ref in residual:
        counts[max(0, ref_depth(ref, nest))] += 2 if ref.is_indirect() else 1
    return counts


def nest_compute(nest: LoopNest) -> int:
    """Useful ops of one nest execution: Σ_i I_i · Π_{n≤i} L_n."""
    prod, total = 1, 0
    for Li, Ii in zip(nest.bounds, nest.compute_per_level):
        prod *= Li
        total += Ii * prod
    return total


# -- layout ------------------------------------------------------------------


def level_extent(ref: MemRef, nest: LoopNest, level: int) -> int:
    """The ref's logical extent at ``level``: the loop bound, widened by the
    halo window when one is open (``bounds[l] + window[l] − 1``)."""
    w = 1 if ref.window is None else ref.window[level]
    return nest.bounds[level] + w - 1


def storage_order(ref: MemRef, nest: LoopNest) -> Optional[Tuple[int, ...]]:
    """Varying levels ordered outermost-first *in storage*, if dense.

    A ref is whole-block streamable when, sorted by descending coefficient,
    its varying levels form a dense row-major array: the fastest level has
    coefficient 1 and every slower level's coefficient equals the extent
    product of the faster ones.  The order may be any *permutation* of the
    loop order — GEMM's B operand walks the innermost loop (k) with stride
    n because its storage order is (k, n) while the loop order is
    (m, n, k).  Returns ``None`` when no dense layout exists (e.g. the
    overlapping windows of a stencil walk *without* a declared halo).

    A halo ref's density is judged against its *widened* extents: a 2-D
    stencil reading a (H+2r) × (W+2r) padded grid has row stride W+2r, and
    that is exactly ``level_extent`` of the faster level — the base-corner
    walk is dense over the widened array even though the per-iteration
    windows overlap.

    A bound-1 level multiplies the running extent by 1, so its coefficient
    *ties* the next-faster real level's; a naive coefficient sort can then
    pick the non-dense permutation and reject a valid layout (GEMM's B
    with n == 1 has coefficients (0, 1, 1): (k, n) is dense, (n, k) is
    not).  Ties break toward the fast side for bound-1 levels, where the
    running extent still equals their coefficient.
    """
    assert ref.coeffs is not None
    lv = varying_levels(ref)
    if not lv:
        return ()
    order = sorted(lv, key=lambda l: (-ref.coeffs[l],
                                      level_extent(ref, nest, l) == 1, l))
    expect = 1
    for l in reversed(order):
        if ref.coeffs[l] != expect:
            return None
        expect *= level_extent(ref, nest, l)
    return tuple(order)


def logical_shape(ref: MemRef, nest: LoopNest) -> Tuple[int, ...]:
    """The dense array shape implied by :func:`storage_order` — widened by
    the halo window on windowed levels."""
    order = storage_order(ref, nest)
    assert order is not None, f"ref {ref.name!r} has no dense storage order"
    return tuple(level_extent(ref, nest, l) for l in order)
