"""Exact ISA-level model of the paper's §4.1 performance analysis.

Everything here is closed-form and technology-independent ("Architectural
performance improvements in terms of cycles or unit utilization are technology
independent", §5.1), so we reproduce the paper's numbers *exactly* and assert
them in tests — this is the faithful-reproduction baseline demanded by the
brief.  Sources:

* Eq. (1)/(2): instruction-count model for SSR vs baseline loop nests.
* Eq. (3): amortization break-even  4d + 2 ≤ Σ_i Π_{n≤i} L_n.
* Table 2: hot-loop instruction counts, utilization η, speedup S for
  {standard RV32, +hardware loops, +post-increment} × {int32, fp32}.
* Fig. 4: dot product, N = 1000 → 3001 baseline vs 1012 SSR instructions.
* Fig. 6: η for reductions over d-dimensional hypercubes of side l.
* Eq. (5)/(6) & §5.6.1: utilization limits 33 % → 100 %; η(100)=93 %,
  η(1000)=99.3 % (the paper's "overhead 7, body 1" accounting).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

# --------------------------------------------------------------------------
# Eq. (1) / (2): executed-instruction model for a d-deep loop nest.
# Index convention follows the paper: i = 1 is the OUTERMOST level, so
# Π_{n=1..i} L_n grows toward the innermost loop.
# --------------------------------------------------------------------------


def _check(L: Sequence[int], I: Sequence[int]) -> None:
    if len(L) != len(I) or not L:
        raise ValueError("L and I must be equal-length, non-empty")
    if any(x < 1 for x in L) or any(x < 0 for x in I):
        raise ValueError("bounds must be >=1 and body counts >=0")


def n_ssr(L: Sequence[int], I: Sequence[int], s: int) -> int:
    """Eq. (1): N_ssr = (4ds + s + 2) + Σ_i (I_i + 1)·Π_{n≤i} L_n − Π_i L_i.

    (a) = 4ds + s + 2 is the one-time data-mover setup before the nest
    (Fig. 4 ①: per lane per dim a bound and a stride store, the stride
    immediate, the trigger store, plus the two ``csrwi`` enable/disable and
    the config-base ``la``).
    """
    _check(L, I)
    d = len(L)
    setup = 4 * d * s + s + 2
    prod = 1
    total = setup
    for Li, Ii in zip(L, I):
        prod *= Li
        total += (Ii + 1) * prod
    total -= prod
    return total


def n_base(L: Sequence[int], I: Sequence[int], s: int) -> int:
    """Eq. (2): N_base = 1 + Σ_i (I_i + 1 + s)·Π_{n≤i} L_n − Π_i L_i.

    (b) = s explicit memory instructions per iteration that SSR elides; the
    +1 per level is loop maintenance, cancelled for the innermost level by
    the trailing −Π L (hardware loops need no in-loop branch).
    """
    _check(L, I)
    prod = 1
    total = 1
    for Li, Ii in zip(L, I):
        prod *= Li
        total += (Ii + 1 + s) * prod
    total -= prod
    return total


def breakeven_lhs(d: int) -> int:
    """Eq. (3) LHS: 4d + 2."""
    return 4 * d + 2


def breakeven_rhs(L: Sequence[int]) -> int:
    """Eq. (3) RHS: Σ_i Π_{n≤i} L_n."""
    prod, total = 1, 0
    for Li in L:
        prod *= Li
        total += prod
    return total


def ssr_profitable(L: Sequence[int]) -> bool:
    """Eq. (3): SSR wins iff 4d + 2 ≤ Σ_i Π_{n≤i} L_n.

    Remarkably independent of both the per-level body size I_i and the
    data-mover count s (paper §4.1.1) — asserted by a hypothesis test.
    """
    return breakeven_lhs(len(L)) <= breakeven_rhs(L)


def min_side_length(d: int) -> int:
    """Smallest hypercube side l such that an l^d nest is SSR-profitable.

    Paper: "more than 5, 4, 1, or 1 overall iterations l^d for 1D, 2D, 3D,
    4D" → minimal sides 6, 3, 2, 2.
    """
    l = 1
    while not ssr_profitable([l] * d):
        l += 1
    return l


# --------------------------------------------------------------------------
# Utilization of a d-dimensional reduction (Fig. 6) and the §5.6.1 limits.
# --------------------------------------------------------------------------


def utilization_reduction(l: int, d: int, s: int = 2) -> float:
    """Useful utilization η for a reduction over an l^d hypercube with SSRs.

    One useful op (fmadd) per element; per-level body I = (0,…,0,1); Eq. (1)
    gives total instructions.  Fig. 6's family of curves.
    """
    L = [l] * d
    I = [0] * (d - 1) + [1]
    return (l ** d) / n_ssr(L, I, s)


def utilization_limit_dot(n: int, ssr: bool) -> float:
    """Eq. (5)/(6) with the paper's §5.6.1 accounting.

    Without SSR: overhead 2, body 3  → N/(2+3N) → 33 %.
    With SSR:    overhead 7, body 1  → N/(7+N)  → 100 %;
    η(100) = 93 %, η(1000) = 99.3 % (§5.6.1).  Note the paper uses a leaner
    setup accounting here (7) than Fig. 4's full count (12 = Eq. (1) setup
    with d=1, s=2); both yield the same limit.  We reproduce each where the
    paper uses it.
    """
    if ssr:
        return n / (7 + n)
    return n / (2 + 3 * n)


# --------------------------------------------------------------------------
# Table 2: hot-loop schedules.  Each row is an explicit instruction mix for
# one unrolled hot-loop body, from which N, η and S follow.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HotLoop:
    """One steady-state hot-loop body (per U unrolled iterations)."""

    loads: int
    stores: int
    ptr_arith: int   # pointer/counter arithmetic
    branches: int
    compute: int     # instructions that contribute to the result (fmadd/mac)

    @property
    def n(self) -> int:
        return self.loads + self.stores + self.ptr_arith + self.branches + self.compute

    @property
    def eta(self) -> float:
        return self.compute / self.n


@dataclasses.dataclass(frozen=True)
class Table2Row:
    kernel: str
    arith: str
    unroll: int
    base: HotLoop
    ssr: HotLoop

    @property
    def speedup(self) -> float:
        return self.base.n / self.ssr.n


def table2() -> Tuple[Table2Row, ...]:
    """The six rows of Table 2, as explicit schedules.

    * Standard RV32 (U=1): base = 2 loads + 2 pointer bumps + 1 mac + 1
      branch/counter = 6 (η=17 %); SSR elides loads & pointer bumps but the
      software loop remains: counter dec + mac + branch = 3 (η=33 %) → 2×.
    * +Hardware loops (int, U=1): base = 2 loads + 2 bumps + mac = 5 (η=20 %);
      SSR = mac alone = 1 (η=100 %) → 5×.
    * +Post-increment (int, U=2): base = 4 p.lw! + 2 mac = 6 (η=33 %);
      SSR = 2 mac (2-fold unroll hides the 2-cycle load latency) → 3×.
    * fp32 standard RV32: same counts as int32.
    * +HWL (fp, U=3): base = 6 flw + 2 ptr bumps (amortised over the unroll)
      + 3 fmadd = 11 (η=27 %); SSR = 3 fmadd (3-fold unroll hides the 3-cycle
      FMA latency on the accumulator) → 3.7×.
    * +Post-incr (fp, U=3): base = 6 p.flw! + 3 fmadd = 9 (η=33 %);
      SSR = 3 fmadd → 3×.
    """
    rows = (
        Table2Row("Standard RV32", "int32", 1,
                  HotLoop(loads=2, stores=0, ptr_arith=2, branches=1, compute=1),
                  HotLoop(loads=0, stores=0, ptr_arith=1, branches=1, compute=1)),
        Table2Row("+ Hardware Loops", "int32", 1,
                  HotLoop(loads=2, stores=0, ptr_arith=2, branches=0, compute=1),
                  HotLoop(loads=0, stores=0, ptr_arith=0, branches=0, compute=1)),
        Table2Row("+ Post-Increment", "int32", 2,
                  HotLoop(loads=4, stores=0, ptr_arith=0, branches=0, compute=2),
                  HotLoop(loads=0, stores=0, ptr_arith=0, branches=0, compute=2)),
        Table2Row("Standard RV32", "fp32", 1,
                  HotLoop(loads=2, stores=0, ptr_arith=2, branches=1, compute=1),
                  HotLoop(loads=0, stores=0, ptr_arith=1, branches=1, compute=1)),
        Table2Row("+ Hardware Loops", "fp32", 3,
                  HotLoop(loads=6, stores=0, ptr_arith=2, branches=0, compute=3),
                  HotLoop(loads=0, stores=0, ptr_arith=0, branches=0, compute=3)),
        Table2Row("+ Post-Increment", "fp32", 3,
                  HotLoop(loads=6, stores=0, ptr_arith=0, branches=0, compute=3),
                  HotLoop(loads=0, stores=0, ptr_arith=0, branches=0, compute=3)),
    )
    return rows


# --------------------------------------------------------------------------
# Kernel-suite schedules (§4.2 / Fig. 7 / Fig. 8).  Steady-state hot-loop
# models for the eight evaluated kernels on RI5CY (+HWL +post-increment
# baseline, the paper's strongest baseline) vs SSR.  The paper reports the
# resulting speedups as 2.0×–3.7×, "generally at or above 2×" — our models
# must land inside that band (asserted in tests, reported in benchmarks).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelModel:
    name: str
    problem: str
    base: HotLoop          # per steady-state body
    ssr: HotLoop
    iters: int             # hot-loop executions for the paper's problem size
    base_setup: int = 2
    ssr_setup: int = 12    # Eq.(1) setup with d=1, s=2 unless overridden

    @property
    def speedup(self) -> float:
        nb = self.base_setup + self.base.n * self.iters
        ns = self.ssr_setup + self.ssr.n * self.iters
        return nb / ns

    @property
    def eta_base(self) -> float:
        return self.base.eta

    @property
    def eta_ssr(self) -> float:
        nb = self.ssr_setup + self.ssr.n * self.iters
        return (self.ssr.compute * self.iters) / nb


def kernel_suite() -> Tuple[KernelModel, ...]:
    """The eight §4.2 kernels as steady-state schedules.

    Baseline = RI5CY with hardware loops + post-increment loads (the paper's
    own baseline).  Stores count like loads; SSR elides both.  Where a kernel
    keeps coefficients resident in registers we model the *hot* loop only, as
    the paper does ("implementations are fully optimized such that the loop
    bodies only consist of mandatory non-amortizable instructions").
    """
    return (
        # dot product over 2048: 2 loads + fmadd  →  fmadd          (3×)
        KernelModel("reduction", "dot product, n=2048",
                    HotLoop(2, 0, 0, 0, 1), HotLoop(0, 0, 0, 0, 1), 2048),
        # prefix sums over 4096: load + add + store → add           (3×)
        KernelModel("scan", "prefix sums, n=4096",
                    HotLoop(1, 1, 0, 0, 1), HotLoop(0, 0, 0, 0, 1), 4096),
        # 1D 11-point stencil: taps' coefficients reside in registers; per
        # output: 11 loads + 11 fmadd + 1 store → 11 fmadd (+ streamed store)
        KernelModel("stencil1d", "11-point star, n=1024",
                    HotLoop(11, 1, 0, 0, 11), HotLoop(0, 0, 0, 0, 11), 1024),
        # 2D 11-diameter star stencil (5+5+1 taps per axis → 11 taps): same
        # structure per output point over a 64×64 grid, 2-deep nest.
        KernelModel("stencil2d", "11-point star, 64×64",
                    HotLoop(11, 1, 0, 0, 11), HotLoop(0, 0, 0, 0, 11), 64 * 64,
                    ssr_setup=4 * 2 * 2 + 2 + 2),
        # GEMV 64×64: inner dot of 64 (2 loads + fmadd → fmadd), x streamed
        # with repeat; per row one store handled by write stream.  2-deep.
        KernelModel("gemv", "64×64 · 64",
                    HotLoop(2, 0, 0, 0, 1), HotLoop(0, 0, 0, 0, 1), 64 * 64,
                    base_setup=2 + 64,           # per-row store+ptr in base
                    ssr_setup=4 * 2 * 2 + 2 + 2),
        # GEMM 32×32×32: inner fmadd; A-element reuse via repeat register,
        # B streamed; C accumulated in registers per tile.  3-deep nest.
        KernelModel("gemm", "32×32 · 32×32",
                    HotLoop(2, 0, 0, 0, 1), HotLoop(0, 0, 0, 0, 1), 32 ** 3,
                    base_setup=2 + 32 * 32,      # C writebacks in base
                    ssr_setup=4 * 3 * 2 + 2 + 2 + 32 * 32),
        # ReLU over 1024: load + max + store → max                  (3×)
        KernelModel("relu", "max(0,x), n=1024",
                    HotLoop(1, 1, 0, 0, 1), HotLoop(0, 0, 0, 0, 1), 1024),
        # FFT radix-2 butterfly over 2048 pts, log2(n)=11 stages: per
        # butterfly 4 data loads + 2 twiddle loads + 4 stores vs 10 flops
        # (complex mul = 4 mul + 2 add, two complex adds = 4 add).  SSR
        # streams data+twiddles+results; index swizzle folded into AGU
        # strides per stage.
        KernelModel("fft", "radix-2, n=2048",
                    HotLoop(6, 4, 0, 0, 10), HotLoop(0, 0, 0, 0, 10),
                    (2048 // 2) * 11,
                    base_setup=11 * 4, ssr_setup=11 * (4 * 2 * 2 + 2 + 2)),
        # bitonic sort network over 1024: compare-exchange = 2 loads +
        # min + max + 2 stores → min + max.  log2(n)(log2(n)+1)/2 = 55
        # stages of n/2 comparators.
        KernelModel("bitonic", "sort network, n=1024",
                    HotLoop(2, 2, 0, 0, 2), HotLoop(0, 0, 0, 0, 2),
                    (1024 // 2) * 55,
                    base_setup=55 * 2, ssr_setup=55 * (4 * 1 * 2 + 2 + 2)),
    )


def fig4_dot_product(n: int = 1000, s: int = 2) -> Tuple[int, int]:
    """Fig. 4's headline counts: (baseline, SSR) executed instructions.

    n=1000 → (3001, 1012).
    """
    return n_base([n], [1], s), n_ssr([n], [1], s)


# --------------------------------------------------------------------------
# §5.3/5.4 cluster model: Amdahl with per-core SSR speedup.
# --------------------------------------------------------------------------


def cluster_time(n_cores: int, ssr: bool, *, work: float = 1.0,
                 sync_overhead: float = 0.0444,
                 ssr_speedup: float = 3.0) -> float:
    """Relative execution time of a kernel on an n-core cluster (§5.3/5.4).

    T(n) = σ·(1 − 1/n) + work / (n · speed): the compute is SSR-accelerated,
    but work-splitting/synchronisation (σ, the hardware-barrier/event-unit
    cost) is not — which is exactly why the paper's single-core 3× drops to
    ~2.2× on six cores (§5.4).  σ is calibrated to that 2.2× point; the same
    σ then *predicts* Fig. 11's equivalences (2 SSR cores ≈ 6 baseline cores
    for 3×-kernels, 3 cores for 2×-kernels) — asserted in tests.
    """
    speed = ssr_speedup if ssr else 1.0
    return sync_overhead * (1.0 - 1.0 / n_cores) + work / (n_cores * speed)


def equivalent_cores(target_cores: int = 6, *, ssr_speedup: float = 3.0,
                     sync_overhead: float = 0.0444) -> int:
    """Smallest SSR-core count matching an n-core non-SSR cluster (Fig. 11)."""
    t_target = cluster_time(target_cores, ssr=False,
                            sync_overhead=sync_overhead,
                            ssr_speedup=ssr_speedup)
    n = 1
    while cluster_time(n, ssr=True, sync_overhead=sync_overhead,
                       ssr_speedup=ssr_speedup) > t_target:
        n += 1
    return n


def utilization_class(issue_width: int, streaming: bool) -> float:
    """§5.6.1 "efficiency classes" on long reductions (Table 3's Util. Limit).

    Single-issue in-order: 33 %; dual-issue: 50 %; streaming/vector: 100 %.
    """
    if streaming:
        return 1.0
    if issue_width == 1:
        return 1.0 / 3.0
    if issue_width == 2:
        return 0.5
    return min(1.0, issue_width / 3.0)
