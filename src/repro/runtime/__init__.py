"""Subsystem package."""
