"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints store plain host arrays (checkpoint/manager.py), so elastic
restart is: build the new mesh → derive the sharding-policy specs for the
*same* config on the *new* mesh → ``restore(..., shardings=...)``.  Batch
size / microbatching are re-derived so the global batch is preserved when
the data-parallel size changes (gradient-equivalent rescale).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.models.config import ModelConfig
from repro.parallel import sharding as shd


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_devices: int
    new_devices: int
    microbatches: int          # re-derived grad-accumulation factor
    note: str


def plan_rescale(cfg: ModelConfig, global_batch: int, old_mesh: Mesh,
                 new_mesh: Mesh) -> ElasticPlan:
    old_dp = shd.dp_size(old_mesh)
    new_dp = shd.dp_size(new_mesh)
    # keep global batch: if dp shrank k×, accumulate k× more microbatches
    micro = max(1, cfg.microbatches * max(1, old_dp // max(new_dp, 1)))
    while global_batch % micro or (global_batch // micro) % max(new_dp, 1):
        micro -= 1
        if micro == 0:
            micro = 1
            break
    return ElasticPlan(
        old_devices=old_mesh.size, new_devices=new_mesh.size,
        microbatches=micro,
        note=(f"dp {old_dp}→{new_dp}; grad-accum ×{micro} preserves "
              f"global batch {global_batch}"))


def restore_on_mesh(ckpt: CheckpointManager, step: int, template: Any,
                    cfg: ModelConfig, mesh: Mesh,
                    params_key: str = "params") -> Any:
    """Restore ``{params, opt, ...}`` state resharded for ``mesh``."""
    pspecs = shd.param_spec_tree(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     template[params_key]), cfg, mesh)
    shardings = {
        params_key: shd.named(mesh, pspecs),
        "opt": {
            "m": shd.named(mesh, pspecs),
            "v": shd.named(mesh, pspecs),
            "count": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
        },
    }
    # leave any extra top-level entries replicated
    for k in template:
        if k not in shardings:
            shardings[k] = jax.tree.map(
                lambda _: jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()), template[k])
    return ckpt.restore(step, template, shardings=shardings)
