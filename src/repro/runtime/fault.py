"""Fault tolerance: failure injection, supervised restart, straggler watch.

On a real fleet the supervisor is an external agent watching heartbeats; in
this repo it is modelled in-process so the restart logic is *testable*:
``Supervisor.run`` drives a step function, a :class:`FailureInjector` raises
:class:`SimulatedFailure` at scheduled steps (standing in for a node loss /
preemption), and recovery restores the latest checkpoint and replays the
data stream from the restored step.  The same code path handles real
exceptions from the step function.

Straggler mitigation: :class:`StragglerMonitor` keeps an EWMA of step wall
time and flags steps slower than ``mean + k·σ``.  The mitigation hook is
pluggable; the default action records the event (on a real pod: trigger a
hot-spare swap / re-dispatch of the slow host's shard, which is a scheduler
action, not a JAX one).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.checkpoint.manager import CheckpointManager
from repro.core.resilience import InjectedFault, retry  # noqa: F401  (retry
# is re-exported: the dispatch-stack generalisation of this module lives in
# core/resilience.py — seam-keyed injection, typed fallback set, bounded
# retry — and its helpers are shared back here so fault tests use ONE
# implementation)


class SimulatedFailure(InjectedFault):
    """Stands in for a lost node / preempted slice.

    Derives from :class:`repro.core.resilience.InjectedFault` so one typed
    ``except`` clause covers both the step-indexed training injector below
    and the seam-keyed dispatch injector — the fallback machinery treats
    every *injected* failure identically.
    """

    def __init__(self, message: str, step: Optional[int] = None):
        RuntimeError.__init__(self, message)
        self.seam = "step"
        self.kind = "fault"
        self.step = step


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Sequence[int] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}",
                                   step=step)


@dataclasses.dataclass
class StragglerMonitor:
    """Per-step walltime watchdog: flags steps slower than mean + k·σ.

    Warmup samples seed the statistics; afterwards mean/variance drift by
    EWMA over *unflagged* steps only (a straggler must not poison the
    baseline).  σ is floored at ``rel_floor·mean`` so ultra-stable step
    times don't hair-trigger.
    """

    threshold_sigma: float = 3.0
    ewma_alpha: float = 0.1
    warmup_steps: int = 5
    rel_floor: float = 0.05
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    _warmup: List[float] = dataclasses.field(default_factory=list)
    events: List[Dict[str, float]] = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.n += 1
        if self.n <= self.warmup_steps:
            self._warmup.append(seconds)
            self.mean = sum(self._warmup) / len(self._warmup)
            self.var = sum((x - self.mean) ** 2 for x in self._warmup) \
                / max(len(self._warmup) - 1, 1)
            return False
        std = max(math.sqrt(max(self.var, 0.0)),
                  self.rel_floor * self.mean, 1e-9)
        flagged = seconds > self.mean + self.threshold_sigma * std
        if flagged:
            self.events.append({"step": step, "seconds": seconds,
                                "mean": self.mean, "std": std})
        else:
            a = self.ewma_alpha
            self.mean = (1 - a) * self.mean + a * seconds
            self.var = (1 - a) * self.var + a * (seconds - self.mean) ** 2
        return flagged


@dataclasses.dataclass
class Supervisor:
    """Checkpointed, restartable training driver."""

    ckpt: CheckpointManager
    checkpoint_every: int = 10
    max_restarts: int = 10
    injector: Optional[FailureInjector] = None
    straggler: Optional[StragglerMonitor] = None
    on_straggler: Optional[Callable[[int], None]] = None
    restarts: int = 0
    events: List[str] = dataclasses.field(default_factory=list)
    # injectable time source so straggler detection is testable without
    # depending on real wall-clock noise
    clock: Callable[[], float] = time.perf_counter

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            num_steps: int, *, start_step: int = 0,
            restore_fn: Optional[Callable[[int, Any], Any]] = None) -> Any:
        """Run ``step_fn`` for ``num_steps``, surviving injected failures.

        ``restore_fn(step, template_state) -> state`` defaults to the
        checkpoint manager's restore with the template's structure.
        """
        step = start_step
        if self.ckpt.latest_step() is None:
            # guarantee a restore point before any work: a failure before
            # the first periodic checkpoint must replay from the *initial*
            # state, not from a half-mutated one
            self.ckpt.save(start_step, state)
        while step < num_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                t0 = self.clock()
                state = step_fn(state, step)
                dt = self.clock() - t0
                if self.straggler is not None and self.straggler.observe(
                        step, dt):
                    self.events.append(f"straggler@{step}")
                    if self.on_straggler is not None:
                        self.on_straggler(step)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(step, state, blocking=False)
            except SimulatedFailure as e:
                self.restarts += 1
                self.events.append(f"failure@{step}: {e}")
                if self.restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step
                    continue  # restart from scratch
                if restore_fn is not None:
                    state = restore_fn(latest, state)
                else:
                    state = self.ckpt.restore(latest, state)
                step = latest
                self.events.append(f"restored@{latest}")
        self.ckpt.wait()
        return state
