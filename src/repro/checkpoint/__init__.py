"""Subsystem package."""
