"""Checkpointing: atomic, async, manifest'd, reshard-on-restore.

This is the framework's "precise exceptions" option (paper §2.4, DESIGN.md
§2 point 3): the full architectural state of a training stream — params,
optimizer moments, step, data cursor — is saved so the stream can be
interrupted (preemption, node failure) and resumed at will, *including onto
a different mesh* (elastic restart: leaves are stored as plain host arrays
and re-placed under the target sharding at load).

Layout::

    <dir>/step_<n>.tmp/ → write leaves (npz) + manifest.json → atomic rename
    <dir>/step_<n>/
    <dir>/LATEST        → "step_<n>" (written after the rename commits)

Async: ``save(..., blocking=False)`` snapshots to host, then writes on a
background thread; ``wait()`` joins.  Keep-last-k GC after each commit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf '{key}' shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, *, blocking: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> None:
        # snapshot to host *before* returning: training may mutate buffers
        flat = _flatten(state)
        meta = {"step": int(step), "extra": extra or {},
                "leaves": {k: [list(v.shape), str(v.dtype)]
                           for k, v in flat.items()}}
        if blocking:
            self._write(step, flat, meta)
        else:
            self.wait()
            self._pending = self._pool.submit(self._write, step, flat, meta)

    def _write(self, step: int, flat, meta) -> None:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.directory, name + ".tmp")
        final = os.path.join(self.directory, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{k: v for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        latest = os.path.join(self.directory, "LATEST.tmp")
        with open(latest, "w") as f:
            f.write(name)
        os.replace(latest, os.path.join(self.directory, "LATEST"))
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for n in os.listdir(self.directory):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        step = int(name.split("_")[1])
        return step if step in self.all_steps() else (
            self.all_steps()[-1] if self.all_steps() else None)

    def restore(self, step: int, template: Any,
                shardings: Optional[Any] = None) -> Any:
        """Load leaves and (optionally) re-place under target shardings.

        ``shardings`` may come from a *different* mesh than the one that
        saved — that is the elastic-restart path.
        """
        d = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(d, "leaves.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def manifest(self, step: int) -> Dict[str, Any]:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)
