"""Deterministic synthetic data pipeline (shard-aware, restart-stable).

Every batch is a pure function of (seed, step) — a restarted run resumes the
exact token stream from its checkpointed step, which the fault-tolerance
tests rely on.  For LM archs the "dataset" is a Zipf-ish token distribution
with a learnable structure (next token correlates with the current one) so a
few hundred steps show a genuinely decreasing loss.  Audio/VLM frontends get
matching synthetic frame/patch embeddings per the stub contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


def _lm_tokens(key, cfg: ModelConfig, dcfg: DataConfig) -> jax.Array:
    """Markov-ish stream: t_{i+1} = (a·t_i + noise) mod V, Zipf-biased."""
    b, s, v = dcfg.global_batch, dcfg.seq_len + 1, cfg.vocab_size
    k1, k2 = jax.random.split(key)
    base = jax.random.categorical(
        k1, -0.9 * jnp.log1p(jnp.arange(v, dtype=jnp.float32)), shape=(b, s))
    noise = jax.random.randint(k2, (b, s), 0, 5)
    step_sizes = base // max(v // 64, 1) + noise   # low-entropy increments
    mixed = jnp.cumsum(step_sizes, axis=1) % v
    return mixed.astype(jnp.int32)


def make_batch(cfg: ModelConfig, dcfg: DataConfig, step: int
               ) -> Dict[str, jax.Array]:
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    batch: Dict[str, jax.Array] = {}
    if cfg.frontend == "audio":
        k1, k2 = jax.random.split(key)
        batch["embeds"] = jax.random.normal(
            k1, (dcfg.global_batch, dcfg.seq_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.1
        batch["labels"] = jax.random.randint(
            k2, (dcfg.global_batch, dcfg.seq_len), 0, cfg.vocab_size)
        return batch
    toks = _lm_tokens(key, cfg, dcfg)
    batch["tokens"] = toks[:, :-1]
    batch["labels"] = toks[:, 1:]
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 7),
            (dcfg.global_batch, cfg.frontend_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.1
        # labels cover only the text tail (model aligns logits accordingly)
    return batch


def iterate(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0
            ) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, dcfg, step)
        step += 1


def input_specs(cfg: ModelConfig, dcfg: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run contract)."""
    b, s = dcfg.global_batch, dcfg.seq_len
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "audio":
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend == "vision":
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), cd)
    return out
