"""Subsystem package."""
