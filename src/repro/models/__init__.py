"""Composable model stack for the assigned architectures."""

from .config import (  # noqa: F401
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    ScanGroup,
    XLSTMConfig,
    smoke_variant,
    uniform_dense_groups,
)
from .model import (  # noqa: F401
    decode_step,
    forward,
    init_caches,
    init_params,
    loss_fn,
)
