"""Shared layer primitives: norms, RoPE, FFNs, embeddings, initialisers."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def init_dense(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def init_embed(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions; shapes (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) = (x[..., :h], x[..., h:]).

    ``x``: (..., positions, heads, head_dim); cos/sin: (..., positions, h/2)
    broadcast over heads.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.dot(h, w_down, preferred_element_type=jnp.float32).astype(x.dtype)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.dot(x, w_up, preferred_element_type=jnp.float32))
    return jnp.dot(h.astype(x.dtype), w_down,
                   preferred_element_type=jnp.float32).astype(x.dtype)


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy in f32; labels (B, S) int, logits (B, S, V)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
