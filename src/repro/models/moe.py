"""Mixture-of-Experts FFN with sort-based scatter dispatch.

Top-k routing with static per-expert capacity (tokens over capacity are
dropped — GShard semantics).  The dispatch avoids the (N·K, E) one-hot
blow-up: positions-within-expert come from an argsort + offset subtraction,
so peak intermediates are O(N·K) + the (E, C, D) expert buffers, both of
which shard cleanly (tokens over the data axes, experts over the model axis
= expert parallelism).

Supports DeepSeek-style shared experts (always-on dense experts added to the
routed output) and fine-grained experts (d_expert ≪ d_ff).  The router aux
load-balance loss (Switch-style) is returned as a metric.

SSR tie-in: the per-expert grouped GEMM ``einsum('ecd,edf->ecf')`` is the
paper's GEMM kernel with the expert axis as an outer AGU loop; under the ssr
region on TPU it lowers to the streamed ``kernels/gemm.py`` tiles per expert.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.activations import BATCH, MODEL, constrain

from .config import ModelConfig, MoEConfig
from .layers import init_dense


def init_moe(key, cfg: ModelConfig):
    m: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.num_experts
    ks = jax.random.split(key, 7)
    dt = jnp.dtype(cfg.param_dtype)
    std = 1.0 / math.sqrt(d)
    params = {
        "router": init_dense(ks[0], d, e, jnp.float32),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                       * std).astype(dt),
            "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                     * std).astype(dt),
            "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                       / math.sqrt(f)).astype(dt),
        },
    }
    if m.num_shared:
        fs = f * m.num_shared
        params["shared"] = {
            "w_gate": init_dense(ks[4], d, fs, dt),
            "w_up": init_dense(ks[5], d, fs, dt),
            "w_down": init_dense(ks[6], fs, d, dt),
        }
    return params


def capacity(n_tokens: int, m: MoEConfig) -> int:
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _route(xf, router, m: MoEConfig):
    """Shared routing math: (gate_vals, expert_ids, aux_loss)."""
    n = xf.shape[0]
    e, k = m.num_experts, m.top_k
    logits = jnp.dot(xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    occupancy = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (n * k))
    mean_probs = jnp.mean(probs, axis=0)
    return gate_vals, expert_ids, occupancy, mean_probs


def _positions_in_expert(ids, e):
    """Sort-based rank of each dispatch slot within its expert."""
    nk = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    counts = jnp.zeros((e,), jnp.int32).at[ids].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[ids[order]]
    return jnp.zeros((nk,), jnp.int32).at[order].set(ranks_sorted)


def moe_apply(params, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """Dispatcher: expert-parallel shard_map when a mesh is ambient."""
    from repro.parallel.activations import get_activation_mesh  # noqa: PLC0415

    m: MoEConfig = cfg.moe
    mesh = get_activation_mesh()
    if (m.impl in ("auto", "ep") and mesh is not None
            and "model" in mesh.axis_names and mesh.shape["model"] > 1
            and m.num_experts % mesh.shape["model"] == 0):
        # decode-sized token sets: weights-stationary variants (weights
        # never move; tokens/activations are tiny)
        if x.shape[0] * x.shape[1] <= 4096:
            axes, world = ep2d_axes(mesh, m.num_experts)
            if len(axes) > 1 and world > mesh.shape["model"]:
                return _moe_apply_ep2d(params, x, cfg, mesh)
            if "data" in mesh.axis_names and mesh.shape["data"] > 1:
                return _moe_apply_ep_dstat(params, x, cfg, mesh)
        return _moe_apply_ep(params, x, cfg, mesh)
    return _moe_apply_xla(params, x, cfg)


def _moe_apply_xla(params, x: jax.Array, cfg: ModelConfig
                   ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) → (out (B, S, D), aux_loss scalar)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    xf = constrain(x.reshape(n, d), BATCH, None)

    logits = constrain(
        jnp.dot(xf.astype(jnp.float32), params["router"]), BATCH, None)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance loss: E · Σ_e f_e · p_e
    occupancy = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (n * k))
    aux = e * jnp.sum(occupancy * jnp.mean(probs, axis=0))

    # --- dispatch: position-within-expert via sort ------------------------
    nk = n * k
    c = capacity(n, m)
    ids = expert_ids.reshape(nk)
    order = jnp.argsort(ids, stable=True)
    counts = jnp.zeros((e,), jnp.int32).at[ids].add(1)
    starts = jnp.cumsum(counts) - counts                      # (E,)
    ranks_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[ids[order]]
    pos = jnp.zeros((nk,), jnp.int32).at[order].set(ranks_sorted)
    keep = pos < c
    dst = jnp.where(keep, ids * c + pos, e * c)               # drop → OOB

    token_idx = jnp.arange(nk, dtype=jnp.int32) // k
    slot_x = xf[token_idx]                                    # (NK, D)
    buf = jnp.zeros((e * c, d), x.dtype).at[dst].set(
        slot_x, mode="drop")

    # --- per-expert grouped SwiGLU (EP: experts sharded over 'model') -----
    bufe = constrain(buf.reshape(e, c, d), MODEL, None, None)
    ew = params["experts"]
    g = jnp.einsum("ecd,edf->ecf", bufe, ew["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", bufe, ew["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y_e = constrain(jnp.einsum("ecf,efd->ecd", h, ew["w_down"],
                     preferred_element_type=jnp.float32), MODEL, None, None)

    # --- combine -----------------------------------------------------------
    y_slots = y_e.reshape(e * c, d)[jnp.minimum(dst, e * c - 1)]
    y_slots = jnp.where(keep[:, None], y_slots, 0.0)
    y = jnp.sum(
        (y_slots * gate_vals.reshape(nk, 1)).reshape(n, k, d), axis=1)

    if m.num_shared:
        sh = params["shared"]
        gs = jnp.dot(xf, sh["w_gate"], preferred_element_type=jnp.float32)
        us = jnp.dot(xf, sh["w_up"], preferred_element_type=jnp.float32)
        hs = (jax.nn.silu(gs) * us).astype(x.dtype)
        y = y + jnp.dot(hs, sh["w_down"],
                        preferred_element_type=jnp.float32)

    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map): the SSR idea at the cluster level.
#
# XLA's SPMD partitioner cannot shard the scatter/gather dispatch of the
# plain-jit path — it falls back to *replicating* the (N·K, D) slot tensors
# and (E·C, D) buffers per device (observed: 315 GiB/device and 23.8 TB of
# collective traffic on deepseek-v3 train_4k).  The shard_map form pins the
# algorithm instead of hoping propagation finds it:
#
#   * routing is computed redundantly on every model shard (tokens are
#     replicated over 'model'; the router matmul is negligible),
#   * each shard runs ONLY its E/tp local experts on the locally-built
#     capacity buffer — no token exchange at all on dispatch,
#   * the combine is one psum of the (n_local, D) output over 'model' —
#     the only collective in the layer.
#
# This mirrors the paper's data-mover economics: keep operands local, let a
# cheap deterministic "address pattern" (the router) decide what each
# compute unit consumes, and pay one bounded stream of results.
# ---------------------------------------------------------------------------


def _moe_apply_ep(params, x: jax.Array, cfg: ModelConfig, mesh
                  ) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P  # noqa: PLC0415
    from jax.experimental.shard_map import shard_map  # noqa: PLC0415
    from repro.parallel.sharding import dp_axes  # noqa: PLC0415

    m: MoEConfig = cfg.moe
    e, k = m.num_experts, m.top_k
    tp = mesh.shape["model"]
    e_loc = e // tp
    dp = dp_axes(mesh)
    b, s, d = x.shape
    f = m.d_expert
    batch_sharded = dp and all(
        b % int(np.prod([mesh.shape[a] for a in dp[:i + 1]])) == 0
        for i in range(len(dp)))
    bspec = tuple(dp) if batch_sharded else None

    def local(x_l, router, wg, wu, wd):
        bl, sl, _ = x_l.shape
        nl = bl * sl
        xf = x_l.reshape(nl, d)
        gate_vals, expert_ids, occ, mp = _route(xf, router, m)
        if dp:
            occ = jax.lax.pmean(occ, dp)
            mp = jax.lax.pmean(mp, dp)
        aux = e * jnp.sum(occ * mp)

        nk = nl * k
        c = capacity(nl, m)
        ids = expert_ids.reshape(nk)
        pos = _positions_in_expert(ids, e)
        keep = pos < c
        dst = jnp.where(keep, ids * c + pos, e * c)
        token_idx = jnp.arange(nk, dtype=jnp.int32) // k
        slot_x = xf[token_idx]

        # scatter straight into the LOCAL experts' buffer — building the
        # full (E·C, D) buffer and slicing costs tp× the memory (observed
        # +9.4 GiB/device/layer on deepseek prefill_32k)
        my = jax.lax.axis_index("model")
        local_dst = dst - my * (e_loc * c)
        mine_in = keep & (local_dst >= 0) & (local_dst < e_loc * c)
        bufe = jnp.zeros((e_loc * c, d), x.dtype).at[
            jnp.where(mine_in, local_dst, e_loc * c)].set(
            slot_x, mode="drop").reshape(e_loc, c, d)
        g = jnp.einsum("ecd,edf->ecf", bufe, wg,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", bufe, wu,
                       preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(g) * u).astype(x.dtype)
        y_e = jnp.einsum("ecf,efd->ecd", hh, wd,
                         preferred_element_type=jnp.float32)  # (e_loc, C, D)

        # combine: only slots owned by the local experts contribute
        local_dst = dst - my * e_loc * c
        mine = keep & (local_dst >= 0) & (local_dst < e_loc * c)
        safe = jnp.where(mine, local_dst, 0)
        y_slots = y_e.reshape(e_loc * c, d)[safe]
        y_slots = jnp.where(mine[:, None], y_slots, 0.0)
        y_l = jnp.sum((y_slots * gate_vals.reshape(nk, 1)).reshape(nl, k, d),
                      axis=1)
        y_l = jax.lax.psum(y_l, "model")                  # THE collective
        return y_l.reshape(bl, sl, d).astype(x.dtype), aux

    in_specs = (P(bspec, None, None), P(), P("model", None, None),
                P("model", None, None), P("model", None, None))
    out_specs = (P(bspec, None, None), P())
    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    ew = params["experts"]
    y, aux = fn(x, params["router"], ew["w_gate"], ew["w_up"], ew["w_down"])

    if m.num_shared:
        xf = x.reshape(b * s, d)
        sh = params["shared"]
        gs = jnp.dot(xf, sh["w_gate"], preferred_element_type=jnp.float32)
        us = jnp.dot(xf, sh["w_up"], preferred_element_type=jnp.float32)
        hs = (jax.nn.silu(gs) * us).astype(x.dtype)
        y = y + jnp.dot(hs, sh["w_down"],
                        preferred_element_type=jnp.float32
                        ).reshape(b, s, d).astype(x.dtype)
    return y, aux


# ---------------------------------------------------------------------------
# Weights-stationary 2-D expert parallelism — the decode path.
#
# At decode, tokens are tiny (≤ a few thousand × D) while expert weights are
# enormous; the 1-D EP path still all-gathers each layer's data-sharded
# expert weights (~1.4 GiB/layer on deepseek-v3).  Here experts are sharded
# over ('model' × 'data') jointly (one expert per device at E=256 on the
# 256-chip pod), the token batch is all-gathered over 'data' (a few MiB),
# every device runs only the experts it OWNS in place, and one psum over
# both axes returns the combined output — weights never move.  This is the
# paper's economics inverted for the serving regime: stream the (small)
# operand set to the (huge) stationary weights.
# ---------------------------------------------------------------------------


def ep2d_axes(mesh, num_experts: int):
    """Largest ('model', 'data'[, 'pod']) prefix whose size divides E."""
    axes = []
    size = 1
    for a in ("model", "data", "pod"):
        if a in mesh.axis_names and num_experts % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    return tuple(axes), size


def _moe_apply_ep2d(params, x: jax.Array, cfg: ModelConfig, mesh
                    ) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P  # noqa: PLC0415
    from jax.experimental.shard_map import shard_map  # noqa: PLC0415
    from repro.parallel.sharding import dp_axes  # noqa: PLC0415

    m: MoEConfig = cfg.moe
    e, k = m.num_experts, m.top_k
    ep_axes, world = ep2d_axes(mesh, e)
    e_loc = e // world
    b, s, d = x.shape
    dp = dp_axes(mesh)
    gather_axes = tuple(a for a in ep_axes if a != "model")
    dp_rest = tuple(a for a in dp if a not in ep_axes)
    bspec = None
    if dp and b % int(np.prod([mesh.shape[a] for a in dp])) == 0:
        bspec = tuple(dp)

    def local(x_l, router, wg, wu, wd):
        bl, sl, _ = x_l.shape
        xf = x_l.reshape(bl * sl, d)
        if gather_axes:
            xf = jax.lax.all_gather(xf, gather_axes, axis=0, tiled=True)
        nl = xf.shape[0]
        gate_vals, expert_ids, occ, mp = _route(xf, router, m)
        if dp_rest:
            occ = jax.lax.pmean(occ, dp_rest)
            mp = jax.lax.pmean(mp, dp_rest)
        aux = e * jnp.sum(occ * mp)

        nk = nl * k
        c = capacity(nl, m)
        ids = expert_ids.reshape(nk)
        pos = _positions_in_expert(ids, e)
        keep = pos < c
        dst = jnp.where(keep, ids * c + pos, e * c)
        token_idx = jnp.arange(nk, dtype=jnp.int32) // k
        slot_x = xf[token_idx]

        # flat device rank along ep_axes (major-to-minor = axes order)
        my = jnp.int32(0)
        for a in ep_axes:
            my = my * mesh.shape[a] + jax.lax.axis_index(a)
        local_dst = dst - my * (e_loc * c)
        mine_in = keep & (local_dst >= 0) & (local_dst < e_loc * c)
        bufe = jnp.zeros((e_loc * c, d), x.dtype).at[
            jnp.where(mine_in, local_dst, e_loc * c)].set(
            slot_x, mode="drop").reshape(e_loc, c, d)
        g = jnp.einsum("ecd,edf->ecf", bufe, wg,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", bufe, wu,
                       preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(g) * u).astype(x.dtype)
        y_e = jnp.einsum("ecf,efd->ecd", hh, wd,
                         preferred_element_type=jnp.float32)

        safe = jnp.where(mine_in, local_dst, 0)
        y_slots = jnp.where(mine_in[:, None],
                            y_e.reshape(e_loc * c, d)[safe], 0.0)
        y_all = jnp.sum(
            (y_slots * gate_vals.reshape(nk, 1)).reshape(nl, k, d), axis=1)
        y_all = jax.lax.psum(y_all, ep_axes)          # everyone gets all toks
        if gather_axes:
            # slice back this shard's tokens
            gsz = int(np.prod([mesh.shape[a] for a in gather_axes]))
            gidx = jnp.int32(0)
            for a in gather_axes:
                gidx = gidx * mesh.shape[a] + jax.lax.axis_index(a)
            y_l = jax.lax.dynamic_slice_in_dim(
                y_all, gidx * (nl // gsz), nl // gsz, 0)
        else:
            y_l = y_all
        return y_l.reshape(bl, sl, d).astype(x.dtype), aux

    espec = P(tuple(ep_axes) if len(ep_axes) > 1 else ep_axes[0],
              None, None)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(bspec, None, None), P(), espec, espec, espec),
                   out_specs=(P(bspec, None, None), P()),
                   check_rep=False)
    ew = params["experts"]
    y, aux = fn(x, params["router"], ew["w_gate"], ew["w_up"], ew["w_down"])

    if m.num_shared:
        xf = x.reshape(b * s, d)
        sh = params["shared"]
        gs = jnp.dot(xf, sh["w_gate"], preferred_element_type=jnp.float32)
        us = jnp.dot(xf, sh["w_up"], preferred_element_type=jnp.float32)
        hs = (jax.nn.silu(gs) * us).astype(x.dtype)
        y = y + jnp.dot(hs, sh["w_down"],
                        preferred_element_type=jnp.float32
                        ).reshape(b, s, d).astype(x.dtype)
    return y, aux


def _moe_apply_ep_dstat(params, x: jax.Array, cfg: ModelConfig, mesh
                        ) -> Tuple[jax.Array, jax.Array]:
    """Weights-stationary decode MoE for small expert counts (E ∤ world).

    Experts shard over 'model' (EP) and the hidden dims over 'data': each
    device holds (E/tp, D/dd, F) of w_gate/w_up and (E/tp, F/dd, D) of
    w_down.  Tokens are gathered over 'data' (tiny at decode); the two
    contractions are partial over the data-sharded dim and pay one small
    psum each — expert weights never move (vs ~30 GB/token of per-layer
    weight all-gathers on dbrx decode).
    """
    from jax.sharding import PartitionSpec as P  # noqa: PLC0415
    from jax.experimental.shard_map import shard_map  # noqa: PLC0415
    from repro.parallel.sharding import dp_axes  # noqa: PLC0415

    m: MoEConfig = cfg.moe
    e, k = m.num_experts, m.top_k
    tp = mesh.shape["model"]
    dd = mesh.shape["data"]
    e_loc = e // tp
    d_model = cfg.d_model
    f = m.d_expert
    if d_model % dd or f % dd:
        return _moe_apply_ep(params, x, cfg, mesh)
    b, s, _ = x.shape
    dp = dp_axes(mesh)
    bspec = None
    if dp and b % int(np.prod([mesh.shape[a] for a in dp])) == 0:
        bspec = tuple(dp)

    def local(x_l, router, wg, wu, wd):
        bl, sl, _ = x_l.shape
        xf = x_l.reshape(bl * sl, d_model)
        gather_axes = tuple(a for a in dp)
        if gather_axes:
            xf = jax.lax.all_gather(xf, gather_axes, axis=0, tiled=True)
        nl = xf.shape[0]
        gate_vals, expert_ids, occ, mp = _route(xf, router, m)
        aux = e * jnp.sum(occ * mp)

        nk = nl * k
        c = capacity(nl, m)
        ids = expert_ids.reshape(nk)
        pos = _positions_in_expert(ids, e)
        keep = pos < c
        dst = jnp.where(keep, ids * c + pos, e * c)
        token_idx = jnp.arange(nk, dtype=jnp.int32) // k
        slot_x = xf[token_idx]

        my_e = jax.lax.axis_index("model")
        my_d = jax.lax.axis_index("data")
        local_dst = dst - my_e * (e_loc * c)
        mine_in = keep & (local_dst >= 0) & (local_dst < e_loc * c)
        bufe = jnp.zeros((e_loc * c, d_model), x.dtype).at[
            jnp.where(mine_in, local_dst, e_loc * c)].set(
            slot_x, mode="drop").reshape(e_loc, c, d_model)
        # contraction partial over the data-sharded D block → psum('data')
        d_blk = d_model // dd
        buf_d = jax.lax.dynamic_slice_in_dim(bufe, my_d * d_blk, d_blk, 2)
        g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf_d, wg,
                                    preferred_element_type=jnp.float32),
                         "data")
        u = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf_d, wu,
                                    preferred_element_type=jnp.float32),
                         "data")
        hh = (jax.nn.silu(g) * u).astype(x.dtype)
        f_blk = f // dd
        h_f = jax.lax.dynamic_slice_in_dim(hh, my_d * f_blk, f_blk, 2)
        y_e = jax.lax.psum(jnp.einsum("ecf,efd->ecd", h_f, wd,
                                      preferred_element_type=jnp.float32),
                           "data")

        safe = jnp.where(mine_in, local_dst, 0)
        y_slots = jnp.where(mine_in[:, None],
                            y_e.reshape(e_loc * c, d_model)[safe], 0.0)
        y_all = jnp.sum(
            (y_slots * gate_vals.reshape(nk, 1)).reshape(nl, k, d_model),
            axis=1)
        y_all = jax.lax.psum(y_all, "model")
        if gather_axes:
            gsz = int(np.prod([mesh.shape[a] for a in gather_axes]))
            gidx = jnp.int32(0)
            for a in gather_axes:
                gidx = gidx * mesh.shape[a] + jax.lax.axis_index(a)
            y_l = jax.lax.dynamic_slice_in_dim(
                y_all, gidx * (nl // gsz), nl // gsz, 0)
        else:
            y_l = y_all
        return y_l.reshape(bl, sl, d_model).astype(x.dtype), aux

    espec_up = P("model", "data", None)
    espec_dn = P("model", "data", None)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(bspec, None, None), P(), espec_up, espec_up,
                             espec_dn),
                   out_specs=(P(bspec, None, None), P()),
                   check_rep=False)
    ew = params["experts"]
    y, aux = fn(x, params["router"], ew["w_gate"], ew["w_up"], ew["w_down"])

    if m.num_shared:
        xf = x.reshape(b * s, d_model)
        sh = params["shared"]
        gs = jnp.dot(xf, sh["w_gate"], preferred_element_type=jnp.float32)
        us = jnp.dot(xf, sh["w_up"], preferred_element_type=jnp.float32)
        hs = (jax.nn.silu(gs) * us).astype(x.dtype)
        y = y + jnp.dot(hs, sh["w_down"],
                        preferred_element_type=jnp.float32
                        ).reshape(b, s, d_model).astype(x.dtype)
    return y, aux
