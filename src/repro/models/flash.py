"""XLA flash-style chunked attention (the ssrcfg=0 path at scale).

The naive SDPA materialises (B, H, S, S) logits — at train_4k/prefill_32k
scale that alone overflows HBM.  This module is the XLA mirror of the
streamed Pallas kernel (kernels/attention.py): an outer ``lax.map`` over
query tiles and an inner ``lax.scan`` over KV tiles with the online-softmax
accumulator, double-``jax.checkpoint``ed so backward never holds more than
one (bq × bk) tile of logits.  The KV tile walk is literally the SSR read
stream; the (m, l, acc) carry is the accumulator register.

Semantics identical to ``ref.attention_ref`` / ``_sdpa`` (tested) — only
the schedule differs.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.activations import BATCH, MODEL, constrain

_NEG = -1e30


def _pick(block: int, size: int) -> int:
    b = min(block, size)
    while size % b:
        b //= 2
    return max(b, 1)


def flash_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
               q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int], scale: float,
               bq: int = 512, bk: int = 1024) -> jax.Array:
    """q (B,Sq,H,dh); k/v (B,Sk,KV,dh); positions (B,S·) → (B,Sq,H,dh)."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    bq = _pick(bq, sq)
    bk = _pick(bk, sk)
    nq, nk = sq // bq, sk // bk
    masked = causal or (window is not None)

    qr = constrain(q.reshape(b, nq, bq, h, dh).transpose(1, 0, 2, 3, 4),
                   None, BATCH, None, MODEL, None)
    qpr = q_pos.reshape(b, nq, bq).transpose(1, 0, 2)
    kr = constrain(k.reshape(b, nk, bk, kv, dh).transpose(1, 0, 2, 3, 4),
                   None, BATCH, None, MODEL, None)
    vr = constrain(v.reshape(b, nk, bk, kv, dv).transpose(1, 0, 2, 3, 4),
                   None, BATCH, None, MODEL, None)
    kpr = k_pos.reshape(b, nk, bk).transpose(1, 0, 2)

    def kv_step(carry, xs):
        m, l, acc = carry
        qc, qpc, kc, vc, kpc = xs
        qg = qc.reshape(b, bq, kv, g, dh).astype(jnp.float32)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                            kc.astype(jnp.float32)) * scale
        if masked:
            mask = jnp.ones((b, bq, bk), bool)
            qp = qpc[:, :, None]
            kp = kpc[:, None, :]
            if causal:
                mask = mask & (kp <= qp)
            if window is not None:
                mask = mask & (kp > qp - window)
            logits = jnp.where(mask[:, None, None], logits, _NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        m_new = constrain(m_new, BATCH, MODEL, None, None)
        l = constrain(l, BATCH, MODEL, None, None)
        acc = constrain(acc, BATCH, MODEL, None, None, None)
        return (m_new, l, acc), None

    kv_step = jax.checkpoint(kv_step)

    def per_q(xs):
        qc, qpc = xs
        init = (constrain(jnp.full((b, kv, g, bq), _NEG, jnp.float32),
                          BATCH, MODEL, None, None),
                constrain(jnp.zeros((b, kv, g, bq), jnp.float32),
                          BATCH, MODEL, None, None),
                constrain(jnp.zeros((b, kv, g, bq, dv), jnp.float32),
                          BATCH, MODEL, None, None, None))

        def step(carry, kxs):
            kc, vc, kpc = kxs
            return kv_step(carry, (qc, qpc, kc, vc, kpc))

        (m, l, acc), _ = jax.lax.scan(step, init, (kr, vr, kpr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, dv)

    per_q = jax.checkpoint(per_q)
    out = jax.lax.map(per_q, (qr, qpr))          # (nq, B, bq, H, dv)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv).astype(q.dtype)


def chunked_scan(step_fn, init, xs, *, chunk: int, length: int):
    """scan-of-scans with a remat boundary per chunk.

    Backward stores only chunk-boundary carries (+ the chunk's input slice)
    instead of per-step residuals — the standard O(√S)-memory recurrence
    trick, needed by every sequential mixer at 4k–32k tokens.

    ``xs`` leaves have leading dim ``length``; chunk must divide it.
    """
    c = _pick(chunk, length)
    n = length // c

    def rechunk(x):
        return x.reshape(n, c, *x.shape[1:])

    xs_c = jax.tree.map(rechunk, xs)

    def chunk_body(carry, x_chunk):
        return jax.lax.scan(step_fn, carry, x_chunk)

    chunk_body = jax.checkpoint(chunk_body)
    carry, ys = jax.lax.scan(chunk_body, init, xs_c)
    ys = jax.tree.map(lambda y: y.reshape(n * c, *y.shape[2:]), ys)
    return carry, ys
