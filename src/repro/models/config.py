"""Model configuration system.

A :class:`ModelConfig` describes any of the ten assigned architectures plus
the paper's own kernel-suite workloads.  The layer stack is expressed as
*scan groups*: ``(pattern, repeats)`` pairs, where a pattern is a tuple of
(mixer, ffn) block kinds.  Homogeneous repetition lowers to ``lax.scan`` so
even the 126-layer llama3-405b compiles as a single rolled loop.

Block kinds
-----------
mixer: ``attn`` (GQA, optional qk-norm / sliding window), ``mla``
(DeepSeek multi-head latent attention), ``mamba`` (selective SSM),
``mlstm`` / ``slstm`` (xLSTM cells).
ffn:   ``mlp`` (SwiGLU), ``gelu_mlp`` (encoder-style), ``moe``
(top-k routed experts, optional shared expert), ``none`` (xLSTM blocks
carry their own projections).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # dispatch implementation: "auto" picks expert-parallel shard_map when a
    # mesh with a dividing 'model' axis is ambient (XLA's SPMD partitioner
    # replicates the scatter dispatch otherwise — §Perf hillclimb #1);
    # "xla" forces the plain-jit path (the recorded baseline).
    impl: str = "auto"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def cache_dim(self) -> int:
        """Per-token per-layer latent cache width (the MLA selling point)."""
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ScanGroup:
    """``pattern`` applied ``repeats`` times via lax.scan."""

    pattern: Tuple[Tuple[str, str], ...]  # ((mixer, ffn), ...)
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    groups: Tuple[ScanGroup, ...]
    head_dim: Optional[int] = None
    qk_norm: bool = False
    window: Optional[int] = None     # sliding-window attention (SWA)
    rope_theta: float = 10000.0
    causal: bool = True              # False => encoder-only (no decode step)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: Optional[str] = None   # 'audio' | 'vision' | None (stub inputs)
    frontend_len: int = 0            # prefix positions fed by the frontend
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # distribution / training knobs (overridable per run)
    remat: bool = True
    microbatches: int = 1
    optimizer_dtype: str = "float32"
    # dtype of the cross-microbatch gradient accumulator.  f32 is the
    # safe default; the 405B/671B configs use bf16 to fit 16 GiB/chip on
    # the single pod (the accumulator is params-sized: 6.3 GiB f32 at
    # 405B/256 chips).  Adam's per-parameter normalisation makes it
    # robust to the reduced mantissa (loss parity checked in tests).
    grad_accum_dtype: str = "float32"

    def __post_init__(self) -> None:
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be divisible by num_kv_heads")
        for g in self.groups:
            for mixer, ffn in g.pattern:
                if mixer not in ("attn", "mla", "mamba", "mlstm", "slstm"):
                    raise ValueError(f"unknown mixer {mixer}")
                if ffn not in ("mlp", "gelu_mlp", "moe", "none", "dense_mlp"):
                    raise ValueError(f"unknown ffn {ffn}")
                if ffn == "moe" and self.moe is None:
                    raise ValueError("moe block without MoEConfig")

    # -- derived ----------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return sum(g.num_layers for g in self.groups)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token decode context is feasible (DESIGN §4).

        Recurrent state (ssm/xlstm), sliding window (bounded KV), or MLA
        latent cache (O(seq · 576 B) per layer) qualify; dense full-KV
        attention does not.
        """
        if self.family in ("ssm", "hybrid"):
            return True
        if self.window is not None:
            return True
        if self.mla is not None:
            return True
        return False

    @property
    def has_decode(self) -> bool:
        return self.causal

    def param_count(self) -> int:
        """Exact parameter count (used for 6·N·D roofline bookkeeping)."""
        from . import model as _model  # noqa: PLC0415
        import jax  # noqa: PLC0415

        shapes = jax.eval_shape(
            lambda: _model.init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k + shared experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        # subtract inactive routed experts
        from . import model as _model  # noqa: PLC0415
        import jax  # noqa: PLC0415

        shapes = jax.eval_shape(
            lambda: _model.init_params(jax.random.PRNGKey(0), self))
        inactive = 0
        e, k = self.moe.num_experts, self.moe.top_k
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = "/".join(str(p) for p in path)
            if "experts" in keys:
                inactive += math.prod(leaf.shape) * (1 - k / e)
        return int(total - inactive)


def uniform_dense_groups(num_layers: int, ffn: str = "mlp",
                         mixer: str = "attn") -> Tuple[ScanGroup, ...]:
    return (ScanGroup(pattern=((mixer, ffn),), repeats=num_layers),)


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Shrinks width/depth/experts/vocab while preserving every structural
    feature (pattern kinds, GQA ratio, MLA/MoE/SWA presence).
    """
    ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, heads // min(ratio, heads))
    head_dim = 16
    d_model = heads * head_dim * 2
    groups = tuple(
        dataclasses.replace(g, repeats=1) for g in cfg.groups[:2]
    )
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(4, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k), d_expert=32,
            num_shared=min(1, cfg.moe.num_shared))
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                        qk_rope_head_dim=8, v_head_dim=16)
    mamba = cfg.mamba and dataclasses.replace(cfg.mamba, d_state=4)
    defaults = dict(
        name=cfg.name + "-smoke", d_model=d_model, num_heads=heads,
        num_kv_heads=kv, d_ff=(0 if cfg.d_ff == 0 else 4 * head_dim),
        vocab_size=256, groups=groups, head_dim=head_dim, moe=moe, mla=mla,
        mamba=mamba, window=(8 if cfg.window else None),
        frontend_len=(8 if cfg.frontend else 0),
        param_dtype="float32", compute_dtype="float32",
        remat=False, microbatches=1,
    )
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
