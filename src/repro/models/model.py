"""Full model: embeddings → scan groups → head; train/prefill/decode paths."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.activations import BATCH, MODEL, constrain

from . import blocks
from .config import ModelConfig
from .layers import init_dense, init_embed, rms_norm, softmax_xent


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, len(cfg.groups) + 4)
    dt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {}
    if cfg.frontend != "audio":
        params["embed"] = init_embed(ks[0], cfg.vocab_size, cfg.d_model, dt)
    if cfg.frontend is not None:
        # stub frontend: precomputed frame/patch embeddings → linear adapter
        params["frontend_proj"] = init_dense(ks[1], cfg.d_model, cfg.d_model, dt)
    params["groups"] = [
        blocks.init_group(ks[2 + i], g, cfg) for i, g in enumerate(cfg.groups)
    ]
    params["final_norm"] = jnp.ones((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(
            ks[len(cfg.groups) + 2], cfg.d_model, cfg.vocab_size, dt)
    return params


def _embed_inputs(params, cfg: ModelConfig, tokens: Optional[jax.Array],
                  embeds: Optional[jax.Array]) -> jax.Array:
    """Token and/or frontend-stub embeddings → (B, S_total, D)."""
    parts = []
    if embeds is not None:
        parts.append(jnp.dot(embeds, params["frontend_proj"]))
    if tokens is not None:
        parts.append(jnp.take(params["embed"], tokens, axis=0))
    h = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return h.astype(jnp.dtype(cfg.compute_dtype))


def _head(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(h, w, preferred_element_type=jnp.float32)
    return constrain(logits, BATCH, None, MODEL)


def forward(params, cfg: ModelConfig, *, tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None, want_cache: bool = False,
            cache_len: int = 0, positions: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Optional[list], jax.Array]:
    """Full-sequence pass → (logits f32, caches | None, aux_loss)."""
    h = _embed_inputs(params, cfg, tokens, embeds)
    h = constrain(h, BATCH, None, None)
    b, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    caches = [] if want_cache else None
    aux = jnp.float32(0.0)
    for gp, g in zip(params["groups"], cfg.groups):
        h, cache, a = blocks.group_full(
            gp, h, cfg, g, positions=positions, want_cache=want_cache,
            cache_len=cache_len)
        aux = aux + a
        if want_cache:
            caches.append(cache)
    return _head(params, cfg, h), caches, aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return [blocks.init_group_cache(g, cfg, batch, max_len, dtype)
            for g in cfg.groups]


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, caches,
                positions: jax.Array) -> Tuple[jax.Array, list]:
    """One-token step: tokens (B, 1), positions (B,) → (logits, caches)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    new_caches = []
    for gp, g, gc in zip(params["groups"], cfg.groups, caches):
        h, c = blocks.group_decode(gp, h, cfg, g, caches=gc,
                                   positions=positions)
        new_caches.append(c)
    return _head(params, cfg, h), new_caches


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token loss (LM) or frame-classification loss (encoder)."""
    logits, _, aux = forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"))
    labels = batch["labels"]
    if labels.shape[1] != logits.shape[1]:  # vlm: labels only on text tail
        logits = logits[:, -labels.shape[1]:]
    ce = softmax_xent(logits, labels, batch.get("mask"))
    total = ce + (cfg.moe.router_aux_weight * aux if cfg.moe else 0.0)
    return total, {"ce": ce, "aux": aux}
