"""Attention mixers: GQA (qk-norm / sliding-window options) and MLA.

Two execution modes share each mixer:

* ``full``  — training / prefill over a whole sequence (causal or
  bidirectional).  Optionally emits the KV cache for subsequent decoding.
* ``decode`` — one new token against a cache (per-sequence positions), the
  ``serve_step`` path.  Sliding-window caches are ring buffers bounded by the
  window (why h2o-danube's 500k-context decode is feasible); MLA caches the
  compressed latent + rope key only (576 B/token·layer at full size) and uses
  the *absorbed* formulation for decode.

SSR tie-in: the ``full`` path's attention is the streamed flash kernel
(``kernels/attention.py``) when the ssr region is enabled on TPU; the XLA
path below is the semantically identical ``ssrcfg=0`` fallback that the
multi-pod dry-run lowers.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.parallel.activations import BATCH, MODEL, constrain

from .config import MLAConfig, ModelConfig
from .flash import flash_sdpa
from .layers import apply_rope, init_dense, rms_norm, rope_angles

_NEG = -1e30

# above this many kv positions the full-sequence path switches from naive
# SDPA (exact, simple — fine for smoke tests) to the chunked flash schedule
# (same math, O(tile) memory — required at train_4k/prefill_32k scale)
FLASH_THRESHOLD = 1024


class AttnParams(NamedTuple):
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    q_norm: Optional[jax.Array]
    k_norm: Optional[jax.Array]


def init_attn(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": init_dense(ks[0], d, h * hd, dt),
        "wk": init_dense(ks[1], d, kv * hd, dt),
        "wv": init_dense(ks[2], d, kv * hd, dt),
        "wo": init_dense(ks[3], h * hd, d, dt),
        "q_norm": jnp.ones((hd,), dt) if cfg.qk_norm else None,
        "k_norm": jnp.ones((hd,), dt) if cfg.qk_norm else None,
    }


def _mask(sq: int, sk: int, q_pos, k_pos, causal: bool,
          window: Optional[int], valid_len=None):
    """(…, sq, sk) boolean mask from absolute positions."""
    m = jnp.ones(q_pos.shape[:-1] + (sq, sk), bool) if q_pos.ndim > 1 else \
        jnp.ones((sq, sk), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m = m & (kp <= qp)
    if window is not None:
        m = m & (kp > qp - window)
    if valid_len is not None:
        m = m & (kp < valid_len[..., None, None])
    return m


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,dh), k (B,Sk,KV,dh), v (B,Sk,KV,dv); f32 softmax."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, dh)
    # preferred_element_type (NOT .astype) keeps the KV operands in their
    # storage dtype — an .astype(f32) here makes XLA materialise an f32
    # copy of the whole cache, hoisted out of the layer loop (observed:
    # +8.4 GiB/device on llama3 decode_32k).
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None] if mask.ndim == 3 else mask,
                       logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def attn_full(params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, want_cache: bool, cache_len: int = 0):
    """Full-sequence attention.  positions (B, S) absolute indices."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.dot(x, params["wq"]).reshape(b, s, h, hd)
    k = jnp.dot(x, params["wk"]).reshape(b, s, kv, hd)
    v = jnp.dot(x, params["wv"]).reshape(b, s, kv, hd)
    q = constrain(q, BATCH, None, MODEL, None)
    k = constrain(k, BATCH, None, MODEL, None)
    v = constrain(v, BATCH, None, MODEL, None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if s > FLASH_THRESHOLD:
        out = flash_sdpa(q, k, v, q_pos=positions, k_pos=positions,
                         causal=cfg.causal, window=cfg.window,
                         scale=1.0 / math.sqrt(hd))
    else:
        mask = _mask(s, s, positions, positions, cfg.causal, cfg.window)
        out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(hd))
    out = jnp.dot(out.reshape(b, s, h * hd), params["wo"])
    cache = None
    if want_cache:
        cache = init_attn_cache(cfg, b, cache_len, dtype=x.dtype)
        cache = _cache_write_bulk(cache, k, v, positions, cfg)
    return out.astype(x.dtype), cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    size = min(max_len, cfg.window) if cfg.window else max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }


def _cache_write_bulk(cache, k, v, positions, cfg: ModelConfig):
    """Prefill write: place the last ``size`` tokens (ring for SWA)."""
    size = cache["k"].shape[1]
    s = k.shape[1]
    if s >= size:
        ksel, vsel = k[:, -size:], v[:, -size:]
        if cfg.window:  # ring layout: slot = pos % size
            psel = positions[:, -size:] % size
            order = jnp.argsort(psel, axis=1)
            ksel = jnp.take_along_axis(ksel, order[..., None, None], axis=1)
            vsel = jnp.take_along_axis(vsel, order[..., None, None], axis=1)
        return {"k": ksel, "v": vsel}
    k0 = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
    v0 = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    return {"k": k0, "v": v0}


def attn_decode(params, x: jax.Array, cfg: ModelConfig, cache, *,
                positions: jax.Array):
    """One-token step.  x (B, 1, D); positions (B,) next absolute index."""
    b, s, d = x.shape
    assert s == 1
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, hd)
    k = jnp.dot(x, params["wk"]).reshape(b, 1, kv, hd)
    v = jnp.dot(x, params["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions[:, None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    size = cache["k"].shape[1]
    slot = positions % size if cfg.window else positions

    def write(buf, new):
        return jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0))
        )(buf, new, slot)

    k_cache = write(cache["k"], k)
    v_cache = write(cache["v"], v)

    if cfg.window:
        # ring buffer: slot s holds absolute position  s + size*floor(...)
        # valid iff abs_pos > pos - window; reconstruct abs positions.
        idx = jnp.arange(size)[None, :]
        cur = positions[:, None]
        abs_pos = jnp.where(idx <= cur % size, cur - cur % size + idx,
                            cur - cur % size + idx - size)
        valid = (abs_pos >= 0) & (abs_pos > cur - cfg.window) & (abs_pos <= cur)
        k_pos = abs_pos
    else:
        k_pos = jnp.broadcast_to(jnp.arange(size)[None, :], (b, size))
        valid = k_pos <= positions[:, None]
    mask = valid[:, None, :]  # (B, 1, S)
    out = _sdpa(q, k_cache, v_cache, mask, 1.0 / math.sqrt(hd))
    out = jnp.dot(out.reshape(b, 1, h * hd), params["wo"])
    return out.astype(x.dtype), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention.
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wdq": init_dense(ks[0], d, m.q_lora_rank, dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "wuq": init_dense(ks[1], m.q_lora_rank, h * m.qk_head_dim, dt),
        "wdkv": init_dense(ks[2], d, m.kv_lora_rank, dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wkr": init_dense(ks[3], d, m.qk_rope_head_dim, dt),
        "wuk": init_dense(ks[4], m.kv_lora_rank, h * m.qk_nope_head_dim, dt),
        "wuv": init_dense(ks[5], m.kv_lora_rank, h * m.v_head_dim, dt),
        "wo": init_dense(ks[6], h * m.v_head_dim, d, dt),
    }


def _mla_qkr(params, x, cfg, positions):
    """Shared q / latent / rope-key computation."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    cq = rms_norm(jnp.dot(x, params["wdq"]), params["q_norm"], cfg.norm_eps)
    q = jnp.dot(cq, params["wuq"]).reshape(b, s, h, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    ckv = rms_norm(jnp.dot(x, params["wdkv"]), params["kv_norm"], cfg.norm_eps)
    kr = jnp.dot(x, params["wkr"]).reshape(b, s, 1, m.qk_rope_head_dim)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kr = apply_rope(kr, cos, sin)[:, :, 0]  # shared across heads
    return q_nope, q_rope, ckv, kr


def mla_full(params, x, cfg: ModelConfig, *, positions, want_cache: bool,
             cache_len: int = 0):
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope, ckv, kr = _mla_qkr(params, x, cfg, positions)
    k_nope = jnp.dot(ckv, params["wuk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = jnp.dot(ckv, params["wuv"]).reshape(b, s, h, m.v_head_dim)
    scale = 1.0 / math.sqrt(m.qk_head_dim)
    # fold the shared rope key in: q' = [q_nope, q_rope], k' = [k_nope, kr]
    qq = constrain(jnp.concatenate([q_nope, q_rope], axis=-1),
                   BATCH, None, MODEL, None)
    kk = constrain(jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], axis=-1),
        BATCH, None, MODEL, None)
    v = constrain(v, BATCH, None, MODEL, None)
    if s > FLASH_THRESHOLD:
        out = flash_sdpa(qq, kk, v, q_pos=positions, k_pos=positions,
                         causal=cfg.causal, window=cfg.window, scale=scale)
    else:
        mask = _mask(s, s, positions, positions, cfg.causal, cfg.window)
        out = _sdpa(qq, kk, v, mask, scale)
    out = jnp.dot(out.reshape(b, s, h * m.v_head_dim).astype(x.dtype),
                  params["wo"])
    cache = None
    if want_cache:
        cache = init_mla_cache(cfg, b, cache_len, x.dtype)
        lat = jnp.concatenate([ckv, kr], axis=-1)
        cache = {"lat": jax.lax.dynamic_update_slice(
            cache["lat"], lat[:, : cache["lat"].shape[1]], (0, 0, 0))}
    return out.astype(x.dtype), cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {"lat": jnp.zeros((batch, max_len, cfg.mla.cache_dim), dtype)}


def mla_decode(params, x, cfg: ModelConfig, cache, *, positions):
    """Absorbed-formulation decode: scores & values via the latent only."""
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope, ckv, kr = _mla_qkr(params, x, cfg, positions[:, None])
    lat_new = jnp.concatenate([ckv, kr], axis=-1)  # (B,1,r+dr)
    lat = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0))
    )(cache["lat"], lat_new, positions)
    ckv_all = lat[..., : m.kv_lora_rank]
    kr_all = lat[..., m.kv_lora_rank:]
    # absorb W_uk into q: q_lat (B,H,r)
    wuk = params["wuk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk,
                       preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(m.qk_head_dim)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(ckv_all.dtype),
                         ckv_all, preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], kr_all,
                           preferred_element_type=jnp.float32)) * scale
    size = lat.shape[1]
    valid = jnp.arange(size)[None, :] <= positions[:, None]
    logits = jnp.where(valid[:, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p.astype(ckv_all.dtype), ckv_all,
                       preferred_element_type=jnp.float32)
    wuv = params["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", o_lat.astype(wuv.dtype), wuv,
                     preferred_element_type=jnp.float32)
    out = jnp.dot(out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype),
                  params["wo"])
    return out.astype(x.dtype), {"lat": lat}
