"""Recurrent mixers: Mamba selective SSM and xLSTM (mLSTM / sLSTM) cells.

These are the paper's *scan* kernel at model scale: a sequential recurrence
whose operands stream past a small resident state — the SSR accumulator
pattern.  Training uses ``lax.scan`` over time (one rolled HLO loop, cheap to
compile at any depth); decode is a single-step state update, giving the O(1)
per-token cost that makes the 500k-context cells feasible (DESIGN §4).

Simplifications vs the reference CUDA implementations are noted inline and
in DESIGN.md (hardware-adaptation): the selective scan is a straight
``lax.scan`` rather than a chunked parallel scan (a hillclimb candidate),
and the xLSTM blocks omit the small causal-conv pre-layers.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.parallel.activations import BATCH, MODEL, constrain

from .config import MambaConfig, ModelConfig, XLSTMConfig
from .flash import chunked_scan
from .layers import init_dense, rms_norm

# sequence-chunk length for the O(√S)-memory scan schedules below
SCAN_CHUNK = 256


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------


def _dt_rank(cfg: ModelConfig) -> int:
    m = cfg.mamba
    return m.dt_rank or max(1, math.ceil(cfg.d_model / 16))


def init_mamba(key, cfg: ModelConfig):
    m: MambaConfig = cfg.mamba
    d = cfg.d_model
    di = m.expand * d
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    a = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "w_in": init_dense(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, di), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "w_xproj": init_dense(ks[2], di, dtr + 2 * m.d_state, dt),
        "w_dt": init_dense(ks[3], dtr, di, dt),
        "dt_bias": jnp.zeros((di,), dt),
        "a_log": jnp.log(a).astype(dt),
        "d_skip": jnp.ones((di,), dt),
        "w_out": init_dense(ks[4], di, d, dt),
    }


def _mamba_inner(params, xc, cfg):
    """Per-step SSM tensors from the conv output xc (..., di)."""
    m = cfg.mamba
    dtr = _dt_rank(cfg)
    proj = jnp.dot(xc, params["w_xproj"])
    dt_in = proj[..., :dtr]
    b_ssm = proj[..., dtr:dtr + m.d_state]
    c_ssm = proj[..., dtr + m.d_state:]
    delta = jax.nn.softplus(
        jnp.dot(dt_in, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    return delta, a, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def mamba_full(params, x: jax.Array, cfg: ModelConfig, *,
               want_cache: bool = False):
    """x (B, S, D) → (B, S, D); optional terminal recurrent state.

    The selective scan runs chunk-by-chunk with a remat boundary per chunk:
    the (Δ, A, B, C) projections and the (B, chunk, di, d_state) transition
    tensors are (re)computed inside the chunk, so backward holds one chunk's
    worth of scan residuals instead of the full sequence's — the adaptation
    that lets train_4k/prefill_32k fit (DESIGN.md §Hardware-adaptation).
    """
    m: MambaConfig = cfg.mamba
    b, s, d = x.shape
    di = m.expand * d
    xz = constrain(jnp.dot(x, params["w_in"]), BATCH, None, MODEL)
    xi, z = xz[..., :di], xz[..., di:]
    # depthwise causal conv along S
    pad = jnp.pad(xi, ((0, 0), (m.d_conv - 1, 0), (0, 0)))
    xc = sum(pad[:, j:j + s, :] * params["conv_w"][j] for j in range(m.d_conv))
    xc = constrain(jax.nn.silu(xc + params["conv_b"]), BATCH, None, MODEL)

    c = SCAN_CHUNK
    while s % c:
        c //= 2
    n = s // c
    xcc = xc.reshape(b, n, c, di).transpose(1, 0, 2, 3)    # (n, B, c, di)

    def chunk_body(h, xck):
        delta, a, b_ssm, c_ssm = _mamba_inner(params, xck, cfg)
        da = jnp.exp(delta[..., None] * a)                 # (B,c,di,ds)
        dbx = (delta * xck.astype(jnp.float32))[..., None] \
            * b_ssm[:, :, None, :]

        def step(hh, t):
            da_t, dbx_t, c_t = t
            hh = da_t * hh + dbx_t
            return hh, jnp.einsum("bds,bs->bd", hh, c_t)

        h, ys = jax.lax.scan(
            step, h, (da.transpose(1, 0, 2, 3), dbx.transpose(1, 0, 2, 3),
                      c_ssm.transpose(1, 0, 2)))
        return constrain(h, BATCH, MODEL, None), \
            constrain(ys, None, BATCH, MODEL)              # ys (c, B, di)

    chunk_body = jax.checkpoint(chunk_body)
    h0 = constrain(jnp.zeros((b, di, m.d_state), jnp.float32),
                   BATCH, MODEL, None)
    hT, ys = jax.lax.scan(chunk_body, h0, xcc)             # ys (n, c, B, di)
    y = ys.transpose(2, 0, 1, 3).reshape(b, s, di)
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    out = jnp.dot((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                  params["w_out"])
    cache = None
    if want_cache:
        cache = {"conv": xi[:, -(m.d_conv - 1):, :],
                 "ssm": hT.astype(jnp.float32)}
    return out.astype(x.dtype), cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    m = cfg.mamba
    di = m.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32)}


def mamba_decode(params, x: jax.Array, cfg: ModelConfig, cache, *,
                 positions=None):
    m: MambaConfig = cfg.mamba
    b = x.shape[0]
    d = cfg.d_model
    di = m.expand * d
    xz = jnp.dot(x[:, 0], params["w_in"])
    xi, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([cache["conv"], xi[:, None, :]], axis=1)
    xc = jnp.einsum("bkd,kd->bd", window, params["conv_w"])
    xc = jax.nn.silu(xc + params["conv_b"])
    delta, a, b_ssm, c_ssm = _mamba_inner(params, xc, cfg)
    da = jnp.exp(delta[..., None] * a)
    h = da * cache["ssm"] + (delta * xc.astype(jnp.float32))[..., None] \
        * b_ssm[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_ssm)
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    out = jnp.dot((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                  params["w_out"])
    return out[:, None, :].astype(x.dtype), \
        {"conv": window[:, 1:], "ssm": h}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks.
# d_ff = 0 in the xlstm-125m config: the blocks own their projections
# (mLSTM pre-up-projection ×2, sLSTM post gated FFN ×4/3).
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig):
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    dp = int(xc.mlstm_proj_factor * d)
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_up": init_dense(ks[0], d, dp, dt),
        "w_gate": init_dense(ks[1], d, dp, dt),
        "wq": init_dense(ks[2], dp, dp, dt),
        "wk": init_dense(ks[3], dp, dp, dt),
        "wv": init_dense(ks[4], dp, dp, dt),
        "w_ifo": init_dense(ks[5], dp, 3 * h, dt),   # input/forget gates per head
        "skip_norm": jnp.ones((dp,), dt),
        "w_down": init_dense(ks[6], dp, d, dt),
    }


def _mlstm_gates(params, u, h):
    g = jnp.dot(u, params["w_ifo"]).astype(jnp.float32)
    i_pre, f_pre, _ = jnp.split(g, 3, axis=-1)
    return i_pre, f_pre


def mlstm_full(params, x, cfg: ModelConfig, *, want_cache: bool = False):
    """Chunkwise-parallel mLSTM (the xLSTM paper's training form).

    Within a chunk the decayed outer-product memory is evaluated as a masked
    (c × c) attention-like contraction; across chunks the (C, n, m) state
    carries recurrently, in stabilised (÷ exp(m)) units identical to the
    decode path.  This replaces a per-token scan whose backward residuals
    (B·H·dh² per step) were the 2 TiB/device blow-up seen in the first
    dry-run — the chunk form is the TPU-native adaptation (MXU-sized
    contractions, √S memory).
    """
    xcfg = cfg.xlstm
    b, s, d = x.shape
    h = cfg.num_heads
    dp = int(xcfg.mlstm_proj_factor * d)
    dh = dp // h
    u = constrain(jnp.dot(x, params["w_up"]), BATCH, None, MODEL)
    gate = constrain(jnp.dot(x, params["w_gate"]), BATCH, None, MODEL)
    q = constrain(jnp.dot(u, params["wq"]).reshape(b, s, h, dh),
                  BATCH, None, MODEL, None)
    k = constrain(jnp.dot(u, params["wk"]).reshape(b, s, h, dh),
                  BATCH, None, MODEL, None) / math.sqrt(dh)
    v = constrain(jnp.dot(u, params["wv"]).reshape(b, s, h, dh),
                  BATCH, None, MODEL, None)
    i_pre, f_pre = _mlstm_gates(params, u, h)      # (B,S,H)

    c = SCAN_CHUNK
    while s % c:
        c //= 2
    n_chunks = s // c

    def rechunk(a):  # (B,S,...) -> (n,B,c,...)
        return a.astype(jnp.float32).reshape(
            b, n_chunks, c, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1))

    qs, ks, vs = rechunk(q), rechunk(k), rechunk(v)
    is_, fs = rechunk(i_pre), rechunk(f_pre)

    causal = jnp.tril(jnp.ones((c, c), bool))

    def chunk_body(carry, t):
        C, n, m = carry                     # stabilised state (÷ exp(m))
        qc, kc, vc, ic, fc = t              # (B,c,H,dh) / (B,c,H)
        lf = jax.nn.log_sigmoid(fc)
        Lf = jnp.cumsum(lf, axis=1)                        # inclusive (B,c,H)
        a_inter = m[:, None] + Lf                          # (B,c,H)
        # intra-chunk decay: D[t,s] = Lf_t − Lf_s + i_s  (s ≤ t)
        D = Lf[:, :, None] - Lf[:, None, :] + ic[:, None, :]   # (B,c,c,H)
        D = jnp.where(causal[None, :, :, None], D, -jnp.inf)
        m_t = jnp.maximum(a_inter, jnp.max(D, axis=2))     # (B,c,H)
        # inter-chunk path
        scale_in = jnp.exp(a_inter - m_t)                  # (B,c,H)
        num_in = jnp.einsum("bhvk,bthk->bthv", C, qc) * scale_in[..., None]
        den_in = jnp.einsum("bhk,bthk->bth", n, qc) * scale_in
        # intra-chunk path
        w = jnp.exp(D - m_t[:, :, None, :])                # (B,c,c,H)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)
        wqk = w * qk
        num = num_in + jnp.einsum("btsh,bshd->bthd", wqk, vc)
        den = den_in + jnp.sum(wqk, axis=2)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # end-of-chunk state update (stabilised against m_new)
        LfT = Lf[:, -1]                                    # (B,H)
        m_new = jnp.maximum(m + LfT, jnp.max(
            LfT[:, None] - Lf + ic, axis=1))
        wS = jnp.exp(LfT[:, None] - Lf + ic - m_new[:, None])  # (B,c,H)
        C_new = jnp.exp(m + LfT - m_new)[:, :, None, None] * C \
            + jnp.einsum("bsh,bshv,bshk->bhvk", wS, vc, kc)
        n_new = jnp.exp(m + LfT - m_new)[..., None] * n \
            + jnp.einsum("bsh,bshk->bhk", wS, kc)
        C_new = constrain(C_new, BATCH, None, None, None)
        n_new = constrain(n_new, BATCH, None, None)
        m_new = constrain(m_new, BATCH, None)
        return (C_new, n_new, m_new), constrain(y, BATCH, None, None, None)

    chunk_body = jax.checkpoint(chunk_body)
    init = (constrain(jnp.zeros((b, h, dh, dh), jnp.float32),
                      BATCH, None, None, None),
            constrain(jnp.zeros((b, h, dh), jnp.float32), BATCH, None, None),
            constrain(jnp.zeros((b, h), jnp.float32), BATCH, None))
    (C, n, m), ys = jax.lax.scan(chunk_body, init, (qs, ks, vs, is_, fs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, dp)
    y = rms_norm(y.astype(x.dtype), params["skip_norm"], cfg.norm_eps)
    out = jnp.dot((y.astype(jnp.float32)
                   * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype),
                  params["w_down"])
    cache = {"C": C, "n": n, "m": m} if want_cache else None
    return out.astype(x.dtype), cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    h = cfg.num_heads
    dp = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    dh = dp // h
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def mlstm_decode(params, x, cfg: ModelConfig, cache, *, positions=None):
    xc = cfg.xlstm
    b = x.shape[0]
    h = cfg.num_heads
    d = cfg.d_model
    dp = int(xc.mlstm_proj_factor * d)
    dh = dp // h
    u = jnp.dot(x[:, 0], params["w_up"])
    gate = jnp.dot(x[:, 0], params["w_gate"])
    q = jnp.dot(u, params["wq"]).reshape(b, h, dh).astype(jnp.float32)
    k = (jnp.dot(u, params["wk"]).reshape(b, h, dh)
         / math.sqrt(dh)).astype(jnp.float32)
    v = jnp.dot(u, params["wv"]).reshape(b, h, dh).astype(jnp.float32)
    i_pre, f_pre = _mlstm_gates(params, u, h)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(logf + cache["m"] - m_new)
    C = f_sc[..., None, None] * cache["C"] + i_sc[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f_sc[..., None] * cache["n"] + i_sc[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, dp)
    y = rms_norm(y.astype(x.dtype), params["skip_norm"], cfg.norm_eps)
    out = jnp.dot((y.astype(jnp.float32)
                   * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype),
                  params["w_down"])
    return out[:, None].astype(x.dtype), {"C": C, "n": n, "m": m_new}


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    xc = cfg.xlstm
    df = int(xc.slstm_proj_factor * d)
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_zifo": init_dense(ks[0], d, 4 * d, dt),
        "r_zifo": init_dense(ks[1], d, 4 * d, dt, scale=0.5),
        "b_zifo": jnp.zeros((4 * d,), dt),
        "ffn_up": init_dense(ks[2], d, 2 * df, dt),
        "ffn_down": init_dense(ks[3], df, d, dt),
    }


def _slstm_step(params, x_t, carry):
    """x_t (B, D) f32; carry (c, n, h, m) each (B, D) f32."""
    c, n, h, m = carry
    d = x_t.shape[-1]
    pre = (jnp.dot(x_t, params["w_zifo"].astype(jnp.float32))
           + jnp.dot(h, params["r_zifo"].astype(jnp.float32))
           + params["b_zifo"].astype(jnp.float32))
    z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    logf = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(logf + m, i_p)
    i_sc = jnp.exp(i_p - m_new)
    f_sc = jnp.exp(logf + m - m_new)
    c_new = constrain(f_sc * c + i_sc * z, BATCH, MODEL)
    n_new = constrain(f_sc * n + i_sc, BATCH, MODEL)
    h_new = constrain(o * c_new / jnp.maximum(n_new, 1.0), BATCH, MODEL)
    m_new = constrain(m_new, BATCH, MODEL)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_full(params, x, cfg: ModelConfig, *, want_cache: bool = False):
    """sLSTM has a true hidden-to-hidden recurrence (no parallel form, per
    the xLSTM paper) — trained with the chunked-remat scan (√S memory)."""
    b, s, d = x.shape
    init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(4))
    carry, ys = chunked_scan(
        lambda cr, xt: _slstm_step(params, xt, cr),
        init, x.astype(jnp.float32).transpose(1, 0, 2),
        chunk=SCAN_CHUNK, length=s)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    # post gated FFN (×4/3)
    df2 = params["ffn_up"].shape[1]
    up = jnp.dot(y, params["ffn_up"]).astype(jnp.float32)
    g, u = up[..., : df2 // 2], up[..., df2 // 2:]
    out = jnp.dot((jax.nn.silu(g) * u).astype(x.dtype), params["ffn_down"])
    cache = None
    if want_cache:
        cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return out.astype(x.dtype), cache


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return {k: jnp.zeros((batch, d), jnp.float32) for k in "cnhm"}


def slstm_decode(params, x, cfg: ModelConfig, cache, *, positions=None):
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    carry, y = _slstm_step(params, x[:, 0].astype(jnp.float32), carry)
    y = y[:, None].astype(x.dtype)
    df2 = params["ffn_up"].shape[1]
    up = jnp.dot(y, params["ffn_up"]).astype(jnp.float32)
    g, u = up[..., : df2 // 2], up[..., df2 // 2:]
    out = jnp.dot((jax.nn.silu(g) * u).astype(x.dtype), params["ffn_down"])
    return out.astype(x.dtype), \
        {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
