"""Block assembly: (mixer, ffn) pairs, pre-norm residuals, scan groups.

A :class:`~repro.models.config.ScanGroup` lowers to one ``lax.scan`` whose
body applies the whole pattern once; parameters and caches are stacked on a
leading ``repeats`` axis.  This keeps HLO size flat in depth (llama3's 126
layers compile as one rolled loop) and is remat-friendly (``jax.checkpoint``
wraps the scan body).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.activations import BATCH, MODEL, constrain

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig, ScanGroup
from .layers import gelu_mlp, init_dense, rms_norm, swiglu

_MIXER_INIT = {
    "attn": attn_mod.init_attn,
    "mla": attn_mod.init_mla,
    "mamba": ssm_mod.init_mamba,
    "mlstm": ssm_mod.init_mlstm,
    "slstm": ssm_mod.init_slstm,
}


def init_ffn(key, kind: str, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    if kind == "none":
        return {}
    if kind == "moe":
        return moe_mod.init_moe(key, cfg)
    f = cfg.d_ff
    ks = jax.random.split(key, 3)
    if kind == "gelu_mlp":
        return {"w_up": init_dense(ks[0], d, f, dt),
                "w_down": init_dense(ks[1], f, d, dt)}
    return {"w_gate": init_dense(ks[0], d, f, dt),
            "w_up": init_dense(ks[1], d, f, dt),
            "w_down": init_dense(ks[2], f, d, dt)}


def init_block(key, mixer: str, ffn: str, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {
        "norm1": jnp.ones((cfg.d_model,), dt),
        "mixer": _MIXER_INIT[mixer](k1, cfg),
        "ffn": init_ffn(k2, ffn, cfg),
    }
    if ffn != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
    return p


def _apply_ffn(params, kind: str, h, cfg: ModelConfig):
    if kind == "moe":
        return moe_mod.moe_apply(params, h, cfg)
    if kind == "gelu_mlp":
        return gelu_mlp(h, params["w_up"], params["w_down"]), 0.0
    return swiglu(h, params["w_gate"], params["w_up"], params["w_down"]), 0.0


def block_full(params, h, cfg: ModelConfig, mixer: str, ffn: str, *,
               positions, want_cache: bool, cache_len: int):
    """One block, full-sequence mode. Returns (h, cache, aux)."""
    x = rms_norm(h, params["norm1"], cfg.norm_eps)
    if mixer == "attn":
        y, cache = attn_mod.attn_full(
            params["mixer"], x, cfg, positions=positions,
            want_cache=want_cache, cache_len=cache_len)
    elif mixer == "mla":
        y, cache = attn_mod.mla_full(
            params["mixer"], x, cfg, positions=positions,
            want_cache=want_cache, cache_len=cache_len)
    elif mixer == "mamba":
        y, cache = ssm_mod.mamba_full(params["mixer"], x, cfg,
                                      want_cache=want_cache)
    elif mixer == "mlstm":
        y, cache = ssm_mod.mlstm_full(params["mixer"], x, cfg,
                                      want_cache=want_cache)
    else:  # slstm
        y, cache = ssm_mod.slstm_full(params["mixer"], x, cfg,
                                      want_cache=want_cache)
    h = h + y
    aux = jnp.float32(0.0)
    if ffn != "none":
        z = rms_norm(h, params["norm2"], cfg.norm_eps)
        out, aux_f = _apply_ffn(params["ffn"], ffn, z, cfg)
        h = h + out
        aux = aux + aux_f
    return h, cache, aux


_MIXER_DECODE = {
    "attn": attn_mod.attn_decode,
    "mla": attn_mod.mla_decode,
    "mamba": ssm_mod.mamba_decode,
    "mlstm": ssm_mod.mlstm_decode,
    "slstm": ssm_mod.slstm_decode,
}


def block_decode(params, h, cfg: ModelConfig, mixer: str, ffn: str, *,
                 cache, positions):
    x = rms_norm(h, params["norm1"], cfg.norm_eps)
    y, cache = _MIXER_DECODE[mixer](params["mixer"], x, cfg, cache,
                                    positions=positions)
    h = h + y
    if ffn != "none":
        z = rms_norm(h, params["norm2"], cfg.norm_eps)
        out, _ = _apply_ffn(params["ffn"], ffn, z, cfg)
        h = h + out
    return h, cache


def init_cache_for(mixer: str, cfg: ModelConfig, batch: int, max_len: int,
                   dtype):
    if mixer == "attn":
        return attn_mod.init_attn_cache(cfg, batch, max_len, dtype)
    if mixer == "mla":
        return attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if mixer == "mlstm":
        return ssm_mod.init_mlstm_cache(cfg, batch, dtype)
    return ssm_mod.init_slstm_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# Scan groups
# ---------------------------------------------------------------------------


def init_group(key, group: ScanGroup, cfg: ModelConfig):
    """Stacked params: one entry per pattern element, leading axis repeats."""
    out = []
    for j, (mixer, ffn) in enumerate(group.pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), group.repeats)
        out.append(jax.vmap(
            lambda k, m=mixer, f=ffn: init_block(k, m, f, cfg))(keys))
    return out


def init_group_cache(group: ScanGroup, cfg: ModelConfig, batch: int,
                     max_len: int, dtype):
    out = []
    for mixer, _ in group.pattern:
        one = init_cache_for(mixer, cfg, batch, max_len, dtype)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (group.repeats,) + x.shape).copy(), one))
    return out


def _layer_chunk(repeats: int) -> int:
    """√R-ish divisor for nested layer-group remat."""
    g = max(1, int(repeats ** 0.5))
    while repeats % g:
        g -= 1
    return g


def group_full(group_params, h, cfg: ModelConfig, group: ScanGroup, *,
               positions, want_cache: bool, cache_len: int):
    """Apply a scan group in full-sequence mode → (h, caches, aux_sum).

    Backward through a plain scan saves the carry (the full activation) at
    *every* layer — ~50 GiB/device for llama3's 126 layers at train_4k.
    With remat on, deep groups therefore scan in two levels: an outer
    checkpointed scan over ~√R layer-groups (saving only group-boundary
    activations) and an inner scan within the group (per-layer saves
    bounded by the group size) — the classic √depth memory/recompute trade
    applied to the layer axis (§Perf iteration log).
    """

    def body(carry, layer_params):
        hh, aux = carry
        caches = []
        for j, (mixer, ffn) in enumerate(group.pattern):
            hh, cache, a = block_full(
                layer_params[j], hh, cfg, mixer, ffn, positions=positions,
                want_cache=want_cache, cache_len=cache_len)
            hh = constrain(hh, BATCH, None, None)
            caches.append(cache)
        return (hh, aux + a), (caches if want_cache else None)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    r = group.repeats
    g = _layer_chunk(r) if (cfg.remat and not want_cache and r >= 8) else 1
    if g <= 1:
        (h, aux), caches = jax.lax.scan(
            body, (h, jnp.float32(0.0)), group_params)
        return h, caches, aux

    # index the ORIGINAL stack with a per-chunk dynamic slice: a
    # tree-mapped reshape to (R/g, g, ...) materialises regrouped copies of
    # every stacked weight (observed: ~5 full parameter-tree copies,
    # +15 GiB/device on llama3)
    def outer(carry, i):
        chunk_params = jax.tree.map(
            lambda p: jax.lax.dynamic_slice_in_dim(p, i * g, g, 0),
            group_params)
        out, _ = jax.lax.scan(body, carry, chunk_params)
        return out, None

    outer = jax.checkpoint(
        outer, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(outer, (h, jnp.float32(0.0)),
                               jnp.arange(r // g))
    return h, None, aux


def group_decode(group_params, h, cfg: ModelConfig, group: ScanGroup, *,
                 caches, positions):
    """Decode through a scan group with *carry-resident* caches.

    Caches ride the scan carry and are updated in place per layer via
    dynamic_update_index — unlike the xs→ys formulation, XLA can alias the
    carried buffers across iterations (and, with donation, alias them to
    the step inputs), so decode holds ~one cache copy instead of four
    (observed −20 GiB/device on llama3 decode_32k).
    """

    def body(carry, xs):
        hh, cbufs = carry
        layer_params, j = xs
        new_bufs = list(cbufs)
        for e, (mixer, ffn) in enumerate(group.pattern):
            cache_j = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, j, 0,
                                                       keepdims=False),
                cbufs[e])
            hh, c2 = block_decode(layer_params[e], hh, cfg, mixer, ffn,
                                  cache=cache_j, positions=positions)
            new_bufs[e] = jax.tree.map(
                lambda buf, nc: jax.lax.dynamic_update_index_in_dim(
                    buf, nc.astype(buf.dtype), j, 0),
                new_bufs[e], c2)
        return (hh, new_bufs), None

    (h, new_caches), _ = jax.lax.scan(
        body, (h, list(caches)),
        (group_params, jnp.arange(group.repeats)))
    return h, new_caches
