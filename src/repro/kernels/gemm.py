"""Tiled GEMM — paper §4.2 (32×32·32×32) and the production matmul.

The canonical SSR composition, and since the multi-level lowering landed,
*fully compiler-scheduled*: the kernel module declares only the
:func:`repro.core.compiler.gemm_nest` loop nest (three AGU loops m, n, k;
two read streams; one write ref revisited across k) plus the block-level
fmadd body, and ``ssrify``/``lower_nest``/``ssr_call`` derive the grid, the
index maps, and the accumulator.  What used to be hand-written geometry now
falls out of the nest:

* the A panel's ``index_map`` ignores the n grid axis — the same block is
  served to every n-tile, the repeat register at block granularity (A's
  level-1 coefficient is 0);
* B's storage order (k, n) permutes the loop order — its blocks walk
  column-tiles while the innermost loop contracts k;
* C's level-2 coefficient is 0, so the output block is revisited across
  the whole k walk: the lowering gives it an f32 VMEM scratch accumulator,
  zeroed on the first k step and drained on the last;
* ``dimension_semantics = (parallel, parallel, arbitrary)`` lets the
  Pallas pipeline double-buffer the k-stream — the data mover running
  ahead of the MXU.

This file is also the production matmul for the LM stack (``ssr_matmul``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compiler

from .frontend import MonolithicKernel, NestKernel, promote
from .registry import KernelEntry, register_kernel


def _prepare(a, b, bm=None, bn=None, bk=None, out_dtype=None):
    m, kdim = a.shape
    k2, n = b.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    # Degenerate n/k (a column vector, an outer product) zero-pad to 2 so
    # every ref keeps its canonical rank-2 storage order — the body's
    # (tm, tk)·(tk, tn) orientation — instead of collapsing to a vector
    # walk.  Zero columns contribute nothing to the contraction; the
    # finish step trims the output back.
    if n < 2:
        b = jnp.pad(b, ((0, 0), (0, 2 - n)))
    if kdim < 2:
        a = jnp.pad(a, ((0, 0), (0, 2 - kdim)))
        b = jnp.pad(b, ((0, 2 - kdim), (0, 0)))
    # bm/bn/bk are accepted for call-site compatibility but tiles are now
    # chosen by the lowering policy (min-clamped to the padded dims, so a
    # tiny matrix is never padded up to a full production tile).
    return ({"A": a, "B": b}, (m, max(n, 2), max(kdim, 2)),
            (m, n, out_dtype.name))


def _nest(static):
    m, n, k = static
    return compiler.gemm_nest(m, n, k)


def _body(static):
    def body(a_blk, b_blk):
        # one output-block partial per grid step: C[i,j] += A[i,k]·B[k,j]
        return jax.lax.dot_general(
            promote(a_blk), promote(b_blk), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    return body


def _finish(out, final):
    # trim the degenerate-dim padding (prepare grows n to 2) and cast
    m, n, dtype = final
    return out[:m, :n].astype(dtype)


_ssr = NestKernel("gemm", prepare=_prepare, nest=_nest, body=_body,
                  finish=_finish)


def ssr_matmul(a: jax.Array, b: jax.Array, *,
               bm: int | None = None, bn: int | None = None,
               bk: int | None = None,
               out_dtype=None, interpret=None,
               schedule=None) -> jax.Array:
    """C = A·B through the full compiler path (nest → plan → Pallas).

    ``bm``/``bn``/``bk`` are retained for call-site compatibility with the
    old hand-tiled engine; tiling now comes from the lowering schedule
    (tile targets + grid-axis order, autotuned per shape when a cached
    winner exists, pinned by an explicit ``schedule=``) and is clamped to
    the (padded) problem, never the other way around.
    """
    return _ssr(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                interpret=interpret, schedule=schedule)


def _prepare_base(a, b, out_dtype=None):
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    pk = (-a.shape[1]) % 128
    if pk:
        a = jnp.pad(a, ((0, 0), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, 0)))
    return (a, b), out_dtype.name, None


def _baseline_body(static):
    def body(a_ref, b_ref, o_ref):
        # Monolithic single-step kernel: operands resident, explicit k-walk
        # with dynamic-slice loads — compute stalls behind each "load", no
        # run-ahead.
        m, kdim = a_ref.shape
        n = b_ref.shape[1]
        bk = min(kdim, 128)

        def step(i, acc):
            a = a_ref[:, pl.dslice(i * bk, bk)]
            b = b_ref[pl.dslice(i * bk, bk), :]
            return acc + jax.lax.dot_general(
                a, b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        acc = jax.lax.fori_loop(0, kdim // bk, step,
                                jnp.zeros((m, n), jnp.float32))
        o_ref[...] = acc.astype(o_ref.dtype)

    return body


_base = MonolithicKernel(
    "gemm", prepare=_prepare_base, body=_baseline_body,
    out_shape=lambda out_dtype, a, b: jax.ShapeDtypeStruct(
        (a.shape[0], b.shape[1]), out_dtype))


def baseline_matmul(a: jax.Array, b: jax.Array, *, out_dtype=None,
                    interpret=None) -> jax.Array:
    return _base(a, b, out_dtype=out_dtype, interpret=interpret)


def cluster_matmul(a: jax.Array, b: jax.Array, *, cores: int,
                   out_dtype=None, interpret=None) -> jax.Array:
    """GEMM on a C-core cluster (paper §5.3): the 2-D row×col split.

    The iteration space splits on *both* parallel levels: cores factor into
    a (rows × cols) grid (closest to square), A shards row-wise, B
    col-wise, and each core runs the unchanged compiled GEMM on its
    (m/Cr, n/Cc) output tile — the contraction (k) stays core-local, so no
    collective is emitted at all.  ``cores=1`` bypasses the mesh entirely.
    """
    from repro.parallel.cluster import cluster_kernel2d, factor_cores

    if cores == 1:
        return ssr_matmul(a, b, out_dtype=out_dtype, interpret=interpret)
    cr, cc = factor_cores(cores)
    m, n = a.shape[0], b.shape[1]
    pm, pn = (-m) % cr, (-n) % cc
    if pm:
        a = jnp.pad(a, ((0, pm), (0, 0)))
    if pn:
        b = jnp.pad(b, ((0, 0), (0, pn)))
    out = cluster_kernel2d(
        lambda ac, bc: ssr_matmul(ac, bc, out_dtype=out_dtype,
                                  interpret=interpret),
        (a, b), cores=cores,
        in_dims=((0, None), (None, 1)), out_dims=(0, 1))
    return out[:m, :n]


@register_kernel("gemm")
def _entry() -> KernelEntry:
    from . import ref

    def _ref(a, b, out_dtype=None, **tile_kw):
        # the ``ssrcfg``-off path keeps the storage dtype unless overridden;
        # tile-tuning kwargs (bm/bn/bk) only steer the streamed engine and
        # are ignored here, so one call site works under both ssrcfg states
        return ref.matmul_ref(a, b).astype(out_dtype or a.dtype)

    def example(rng, odd: bool = False):
        m, n, k = (100, 130, 70) if odd else (32, 32, 32)
        return ((jnp.asarray(rng.standard_normal((m, k)), jnp.float32),
                 jnp.asarray(rng.standard_normal((k, n)), jnp.float32)),
                {"out_dtype": jnp.float32})

    return KernelEntry(name="gemm", ssr=ssr_matmul, baseline=baseline_matmul,
                       ref=_ref, cluster=cluster_matmul, example=example,
                       tol={"rtol": 2e-4, "atol": 2e-4},
                       problem="32×32 · 32×32")
