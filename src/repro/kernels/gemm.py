"""Tiled GEMM — paper §4.2 (32×32·32×32) and the production matmul.

The canonical SSR composition: three AGU loops (m, n, k) drive two read
streams and one revisited output.  The A panel's ``index_map`` ignores the n
grid axis — the same block is served to every n-tile, which is precisely the
repeat register at block granularity (fetched once, emitted N/bn times).
Accumulation runs in an f32 VMEM scratch; the write stream drains on the
last k step.  With ``dimension_semantics = (parallel, parallel, arbitrary)``
the Pallas pipeline double-buffers the k-stream — the data mover running
ahead of the MXU.

This file is also the production matmul for the LM stack (``ssr_matmul``),
with MXU-aligned default tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction, auto_block

from .frontend import Launch, MonolithicKernel, StreamKernel
from .registry import KernelEntry, register_kernel


def _prepare(a, b, bm=256, bn=256, bk=512, out_dtype=None):
    m, kdim = a.shape
    k2, n = b.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    bm = auto_block(m, bm, 8) if m % bm else bm
    bn = auto_block(n, bn, 128) if n % bn else bn
    bk = auto_block(kdim, bk, 128) if kdim % bk else bk
    pm, pn, pk = (-m) % bm, (-n) % bn, (-kdim) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    return (a, b), (bm, bn, bk, out_dtype.name), (m, n)


def _ssr_body(static):
    def body(a_ref, b_ref, o_ref, acc_ref):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(k == pl.num_programs(2) - 1)
        def _write():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return body


def _launch(static, a, b):
    bm, bn, bk, out_dtype = static
    m, kdim = a.shape
    n = b.shape[1]
    return Launch(
        grid=(m // bm, n // bn, kdim // bk),
        in_streams=(
            # A ignores j: block reuse across the n axis (repeat semantics)
            BlockStream((bm, bk), lambda i, j, k: (i, k), name="A"),
            BlockStream((bk, bn), lambda i, j, k: (k, j), name="B"),
        ),
        out_streams=(BlockStream((bm, bn), lambda i, j, k: (i, j),
                                 Direction.WRITE, name="C"),),
        out_shapes=(jax.ShapeDtypeStruct((m, n), out_dtype),),
        scratch_shapes=(pltpu.VMEM((bm, bn), jnp.float32),),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


_ssr = StreamKernel("gemm", prepare=_prepare, launch=_launch, body=_ssr_body,
                    finish=lambda out, mn: out[:mn[0], :mn[1]])


def ssr_matmul(a: jax.Array, b: jax.Array, *,
               bm: int = 256, bn: int = 256, bk: int = 512,
               out_dtype=None, interpret=None) -> jax.Array:
    """C = A·B with streamed operand delivery.  Pads to tile multiples."""
    return _ssr(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                interpret=interpret)


def _prepare_base(a, b, out_dtype=None):
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    pk = (-a.shape[1]) % 128
    if pk:
        a = jnp.pad(a, ((0, 0), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, 0)))
    return (a, b), out_dtype.name, None


def _baseline_body(static):
    def body(a_ref, b_ref, o_ref):
        # Monolithic single-step kernel: operands resident, explicit k-walk
        # with dynamic-slice loads — compute stalls behind each "load", no
        # run-ahead.
        m, kdim = a_ref.shape
        n = b_ref.shape[1]
        bk = min(kdim, 128)

        def step(i, acc):
            a = a_ref[:, pl.dslice(i * bk, bk)]
            b = b_ref[pl.dslice(i * bk, bk), :]
            return acc + jax.lax.dot_general(
                a, b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        acc = jax.lax.fori_loop(0, kdim // bk, step,
                                jnp.zeros((m, n), jnp.float32))
        o_ref[...] = acc.astype(o_ref.dtype)

    return body


_base = MonolithicKernel(
    "gemm", prepare=_prepare_base, body=_baseline_body,
    out_shape=lambda out_dtype, a, b: jax.ShapeDtypeStruct(
        (a.shape[0], b.shape[1]), out_dtype))


def baseline_matmul(a: jax.Array, b: jax.Array, *, out_dtype=None,
                    interpret=None) -> jax.Array:
    return _base(a, b, out_dtype=out_dtype, interpret=interpret)


@register_kernel("gemm")
def _entry() -> KernelEntry:
    from . import ref

    def _ref(a, b, out_dtype=None, **tile_kw):
        # the ``ssrcfg``-off path keeps the storage dtype unless overridden;
        # tile-tuning kwargs (bm/bn/bk) only steer the streamed engine and
        # are ignored here, so one call site works under both ssrcfg states
        return ref.matmul_ref(a, b).astype(out_dtype or a.dtype)

    def example(rng, odd: bool = False):
        m, n, k = (100, 130, 70) if odd else (32, 32, 32)
        return ((jnp.asarray(rng.standard_normal((m, k)), jnp.float32),
                 jnp.asarray(rng.standard_normal((k, n)), jnp.float32)),
                {"out_dtype": jnp.float32})

    return KernelEntry(name="gemm", ssr=ssr_matmul, baseline=baseline_matmul,
                       ref=_ref, example=example,
                       tol={"rtol": 2e-4, "atol": 2e-4},
                       problem="32×32 · 32×32")
