"""Tiled GEMM — paper §4.2 (32×32·32×32) and the production matmul.

The canonical SSR composition: three AGU loops (m, n, k) drive two read
streams and one revisited output.  The A panel's ``index_map`` ignores the n
grid axis — the same block is served to every n-tile, which is precisely the
repeat register at block granularity (fetched once, emitted N/bn times).
Accumulation runs in an f32 VMEM scratch; the write stream drains on the
last k step.  With ``dimension_semantics = (parallel, parallel, arbitrary)``
the Pallas pipeline double-buffers the k-stream — the data mover running
ahead of the MXU.

This file is also the production matmul for the LM stack (``ssr_matmul``),
with MXU-aligned default tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction, auto_block, ssr_pallas


def _body(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def _dispatch(a, b, bm, bn, bk, out_dtype, interpret: bool = True):
    m, kdim = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn, kdim // bk)
    fn = ssr_pallas(
        _body,
        grid=grid,
        in_streams=[
            # A ignores j: block reuse across the n axis (repeat semantics)
            BlockStream((bm, bk), lambda i, j, k: (i, k), name="A"),
            BlockStream((bk, bn), lambda i, j, k: (k, j), name="B"),
        ],
        out_streams=[BlockStream((bm, bn), lambda i, j, k: (i, j),
                                 Direction.WRITE, name="C")],
        out_shapes=[jax.ShapeDtypeStruct((m, n), out_dtype)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )
    return fn(a, b)


def ssr_matmul(a: jax.Array, b: jax.Array, *,
               bm: int = 256, bn: int = 256, bk: int = 512,
               out_dtype=None, interpret: bool = True) -> jax.Array:
    """C = A·B with streamed operand delivery.  Pads to tile multiples."""
    m, kdim = a.shape
    k2, n = b.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    bm = auto_block(m, bm, 8) if m % bm else bm
    bn = auto_block(n, bn, 128) if n % bn else bn
    bk = auto_block(kdim, bk, 128) if kdim % bk else bk
    pm, pn, pk = (-m) % bm, (-n) % bn, (-kdim) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    out = _dispatch(a, b, bm, bn, bk, jnp.dtype(out_dtype).name, interpret)
    return out[:m, :n]


def _baseline_body(a_ref, b_ref, o_ref):
    # Monolithic single-step kernel: operands resident, explicit k-walk with
    # dynamic-slice loads — compute stalls behind each "load", no run-ahead.
    m, kdim = a_ref.shape
    n = b_ref.shape[1]
    bk = min(kdim, 128)

    def step(i, acc):
        a = a_ref[:, pl.dslice(i * bk, bk)]
        b = b_ref[pl.dslice(i * bk, bk), :]
        return acc + jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, kdim // bk, step,
                            jnp.zeros((m, n), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def baseline_matmul(a: jax.Array, b: jax.Array, *, out_dtype=None,
                    interpret: bool = True) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    pk = (-a.shape[1]) % 128
    if pk:
        a = jnp.pad(a, ((0, 0), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, 0)))
    return pl.pallas_call(
        _baseline_body,
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), out_dtype),
        interpret=interpret,
    )(a, b)
