"""Shared kernel frontend: pad → reshape → stream dispatch → trim, once.

This is the loop prologue/epilogue of the paper's Fig. 4 (steps ① and ④ —
stream setup before the region, result write-back after), factored out of
the §4.2 kernel suite.  Every kernel used to re-implement the same four
steps around its compute body: zero-pad operands to whole VMEM blocks, reshape to the
2-D (rows, lanes) layout the streams address, build + jit the ``ssr_pallas``
call, and trim the padding off the result.  :class:`StreamKernel` owns that
pipeline; a kernel module now declares only

* ``prepare`` — operand canonicalisation (pure jnp pad/reshape, usually one
  of the helpers below),
* ``launch``  — the stream geometry (grid, BlockStreams, out shapes,
  scratch) as a :class:`Launch`,
* ``body``    — the compute region builder (``body(static) -> callable``),
* ``finish``  — result trimming.

The whole pipeline — prepare, engine, finish — composes into ONE cached
jitted callable keyed on the *raw* call inputs (shapes/dtypes + static
values + schedule), so the pad/trim traffic fuses into the same XLA
program as the kernel and a repeated call is a dict probe plus one jitted
invocation (``DISPATCH_STATS`` counts builds/traces/calls; the trace-count
tests pin the zero-overhead contract).  ``interpret=None`` autodetects:
Mosaic on a real TPU, interpreter elsewhere.

Dtype policy: bodies compute in :data:`COMPUTE_DTYPE` (f32 — the MXU/VPU
accumulation width) regardless of storage dtype; :func:`promote` is the one
place that states it.

:class:`MonolithicKernel` is the same contract for the *baseline* variants:
a single-step ``pallas_call`` whose body walks blocks with explicit loads —
the paper's serialised load→compute issue — with the identical caching and
pad/trim treatment, so "baseline" and "ssr" differ only in how operands are
delivered.

**One path to silicon.**  :class:`NestKernel` is the preferred declarative
shell: the kernel states a :class:`~repro.core.LoopNest` (the §3.2
compiler's input) plus a block body, and the whole schedule — grid, index
maps, repeat streams, contraction accumulators — comes out of
``ssrify``/``lower_plan``/``lower_nest`` via :func:`repro.core.ssr_call`,
under a block :class:`~repro.core.Schedule` resolved from the autotuner's
persistent cache (``schedule=None``) or pinned explicitly per call.
A module may still hand a raw :class:`Launch` to :class:`StreamKernel` /
:class:`ChainedKernel`, but only with a ``lowering_waiver``: one sentence
stating why the pattern is outside the block-granular AGU model (halo
overlap, carried state, power-of-two shuffle networks, …).  The waiver is
mandatory — an undeclared escape hatch is a compiler-coverage bug.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream  # noqa: F401  (re-export for kernels)
from repro.core import autotune, resilience
from repro.core.lowering import (DEFAULT_SCHEDULE, Schedule, _body_key,
                                 ssr_call)
from repro.core.ssr import _on_tpu, ssr_pallas

ROWS = 8
LANES = 128
BLOCK_ELEMS = ROWS * LANES
COMPUTE_DTYPE = jnp.float32

#: Frontend dispatch instrumentation, mirroring
#: ``lowering.DISPATCH_STATS``: ``builds`` counts jitted prepare→finish
#: pipelines constructed, ``traces`` moves only while one is being traced,
#: ``calls`` per ``__call__``.  The trace-count tests assert a repeated
#: call is a pure cache hit.  ``fallbacks``/``degraded`` mirror the
#: lowering counters: lookups abandoned for the default schedule vs tuned
#: pipelines quarantined and rebuilt on the default — zero when healthy.
DISPATCH_STATS: Dict[str, int] = {"builds": 0, "traces": 0, "calls": 0,
                                  "fallbacks": 0, "degraded": 0}


#: Built-pipeline cap per kernel instance: epoch bumps retire old entries,
#: so the bound only needs to stop pathological shape churn.
_PIPELINE_CACHE_MAX = 512


def reset_dispatch_stats() -> None:
    for k in DISPATCH_STATS:
        DISPATCH_STATS[k] = 0


def _is_arraylike(x: Any) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def _static_key(v: Any) -> Any:
    """Hashable identity for a non-array call ingredient (param or arg)."""
    if callable(v):
        return _body_key(v)
    try:
        hash(v)
        return v
    except TypeError:
        return repr(v)


def _call_key(args: tuple, params: Dict[str, Any]) -> Any:
    """Cache key over raw call inputs: array shapes/dtypes + static values.

    Keying on *raw* inputs (not prepared arrays) is what lets the whole
    prepare→engine→finish pipeline live behind one dict probe.
    """
    arg_key = tuple(
        (tuple(a.shape), str(a.dtype)) if _is_arraylike(a) else
        ("static", _static_key(a))
        for a in args)
    param_key = tuple(sorted((k, _static_key(v)) for k, v in params.items()))
    return arg_key, param_key


def promote(x: jax.Array) -> jax.Array:
    """Cast a block to the compute dtype (f32 accumulation everywhere)."""
    return x.astype(COMPUTE_DTYPE)


# -- operand canonicalisation helpers ---------------------------------------


def pad_vector(x: jax.Array, *, block: int = BLOCK_ELEMS,
               lanes: int = LANES) -> jax.Array:
    """Zero-pad a 1-D array to whole blocks; reshape to (rows, lanes)."""
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, lanes)


def trim_vector(out: jax.Array, n: int) -> jax.Array:
    """Undo :func:`pad_vector`: flatten and drop the padding tail."""
    return out.reshape(-1)[:n]


def pad_leading(a: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the leading (row) dim of a matrix to a multiple of ``mult``."""
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    return a


def require_power_of_two(n: int, what: str) -> None:
    # n & (n - 1) alone silently accepts n == 0 (0 & -1 == 0): an empty
    # operand would sail into log2/stage loops and fail far from the cause.
    if n <= 0 or n & (n - 1):
        raise ValueError(f"{what} needs a power-of-two length, got {n}")


# -- declarative kernel shells ----------------------------------------------


@dataclasses.dataclass(frozen=True)
class Launch:
    """One kernel's stream geometry, as declared by its module."""

    grid: Tuple[int, ...]
    in_streams: Tuple[BlockStream, ...]
    out_streams: Tuple[BlockStream, ...]
    out_shapes: Tuple[jax.ShapeDtypeStruct, ...]
    scratch_shapes: Tuple[Any, ...] = ()
    dimension_semantics: Optional[Tuple[str, ...]] = None


class _KernelBase:
    """Shared call pipeline: prepare → build → run → finish, ONE jit.

    The whole pipeline — operand canonicalisation (pad/reshape), the
    engine call, and result trimming — composes into a single cached
    jitted callable keyed on the *raw* call inputs, so the pad/trim
    traffic fuses into the same XLA program as the kernel instead of
    dispatching eagerly per call.  The first call for a signature runs
    ``prepare`` once eagerly (to learn the static meta the builder needs)
    and then traces the fused pipeline; every later call is a dict probe
    plus one jitted invocation.
    """

    def __init__(self, name: str, *, prepare: Callable,
                 finish: Optional[Callable] = None):
        self.name = name
        self._prepare = prepare
        self._finish = finish
        self._cache: Dict[Any, Callable] = {}
        # A ``schedule=`` param is routed to prepare only when it asks for
        # one (geometry consumers like the stencil); otherwise it stays a
        # builder-level knob (buffer_depth) and prepare never sees it.
        try:
            sig = inspect.signature(prepare)
            self._prepare_takes_schedule = (
                "schedule" in sig.parameters
                or any(p.kind is inspect.Parameter.VAR_KEYWORD
                       for p in sig.parameters.values()))
        except (TypeError, ValueError):  # pragma: no cover - C callables
            self._prepare_takes_schedule = True

    def _build(self, static, arrays, interpret: bool,
               schedule: Optional[Schedule]) -> Callable:
        raise NotImplementedError

    def __call__(self, *args, interpret: Optional[bool] = None, **params):
        if interpret is None:
            interpret = not _on_tpu()
        DISPATCH_STATS["calls"] += 1
        schedule = params.get("schedule")
        prep_params = params if self._prepare_takes_schedule else \
            {k: v for k, v in params.items() if k != "schedule"}
        key = (_call_key(args, params), bool(interpret))
        fn = self._cache.get(key)
        if fn is None:
            arrays, static, _final = self._prepare(*args, **prep_params)
            built = self._build(static, tuple(arrays), bool(interpret),
                                schedule)
            arr_idx = tuple(i for i, a in enumerate(args)
                            if _is_arraylike(a))
            # Capture only the static (non-array) positions: closing over
            # the first call's arrays would pin their device buffers for
            # the cache entry's lifetime.
            statics = tuple(None if _is_arraylike(a) else a for a in args)

            def pipeline(*arrs, _st=statics, _idx=arr_idx, _built=built):
                DISPATCH_STATS["traces"] += 1
                full = list(_st)
                for i, a in zip(_idx, arrs):
                    full[i] = a
                prepared, _s, final = self._prepare(*full, **prep_params)
                out = _built(*prepared)
                return self._finish(out, final) if self._finish else out

            fn = jax.jit(pipeline)
            DISPATCH_STATS["builds"] += 1
            if len(self._cache) >= _PIPELINE_CACHE_MAX:
                self._cache.clear()
            self._cache[key] = fn
        return fn(*[a for a in args if _is_arraylike(a)])


def _require_waiver(name: str, waiver: Optional[str]) -> str:
    """Hand-scheduled geometry must state why the compiler cannot emit it."""
    if not waiver or not waiver.strip():
        raise ValueError(
            f"kernel {name!r} constructs a Launch without a lowering_waiver; "
            "declare why the pattern is outside the block-granular AGU "
            "model, or migrate to NestKernel")
    return waiver


class NestKernel:
    """A kernel whose schedule IS its :class:`~repro.core.LoopNest`.

    The declarative replacement for ``StreamKernel``'s ``launch=`` escape
    hatch: the module supplies

    * ``prepare(*args, **params) -> (operands, static, final)`` — operand
      canonicalisation; ``operands`` maps the nest's :class:`MemRef` names
      to arrays (no padding/reshaping — the lowering owns the layout);
    * ``nest(static) -> LoopNest`` — the §3.2 compiler input; a nest with
      an output WRITE ref takes the level-mapped contraction path, a
      read-only nest uses ``mode`` (``"reduce"``/``"map"``);
    * ``body(static) -> fn(*blocks)`` — the pure compute region;
    * ``finish(out, final)`` — result post-processing (dtype cast, …).

    Everything between prepare and finish — grid, index maps, repeat
    streams, accumulators, padding, kernel caching — is
    :func:`repro.core.ssr_call`, i.e. the same pipeline the compiler tests
    verify, so the kernel is covered by the Eq. (1)–(3) cost model
    (``plan_stats``) and the cluster layer for free.
    """

    def __init__(self, name: str, *, prepare: Callable, nest: Callable,
                 body: Callable, mode: str = "reduce",
                 finish: Optional[Callable] = None,
                 out_dtype: Optional[Callable] = None):
        self.name = name
        self._prepare = prepare
        self._nest = nest
        self._body = body
        self._mode = mode
        self._finish = finish
        # out_dtype(static) -> dtype; None keeps ssr_call's f32 accumulation
        # default.  Dtype-preserving kernels (integer relu) need this so the
        # streamed engine stays bit-exact with the baseline.
        self._out_dtype = out_dtype
        self._cache: Dict[Any, Callable] = {}

    def loop_nest(self, static):
        """The nest this kernel executes — exposed for cost-model oracles."""
        return self._nest(static)

    def schedule_for(self, *args, **params) -> Schedule:
        """The schedule this call would run: tuned (cache hit) or default."""
        operands, static, _final = self._prepare(*args, **params)
        out_dtype = "float32" if self._out_dtype is None else \
            str(jnp.dtype(self._out_dtype(static)))
        return autotune.lookup(self._nest(static), dict(operands),
                               mode=self._mode, out_dtype=out_dtype)

    def __call__(self, *args, interpret: Optional[bool] = None,
                 schedule: Optional[Schedule] = None, **params):
        """Run the kernel as ONE jitted prepare→ssr_call→finish pipeline.

        ``schedule=None`` consults the autotuner's persistent schedule
        cache (:func:`repro.core.autotune.lookup`) — so registry/``ops``
        callers pick up tuned schedules transparently.  The pipeline cache
        keys on the autotune epoch: committing a new winner rebuilds the
        pipeline on the next call instead of serving the stale schedule.

        **Degradation**: because the pipeline hands ``ssr_call`` an
        *explicit* resolved schedule, the lowering layer cannot degrade it
        — this level owns the ladder.  A typed dispatch failure (injected
        fault, cache I/O, :class:`LoweringError`, compile error) under a
        *tuned* schedule quarantines the cache entry and rebuilds on the
        default schedule; an explicit ``schedule=`` always propagates the
        error (the caller pinned it, masking would hide their bug).
        """
        DISPATCH_STATS["calls"] += 1
        try:
            return self._dispatch(args, params, interpret, schedule)
        except resilience.fallback_error_types() as e:
            if schedule is not None:
                raise
            key = self._quarantine_tuned(args, params)
            if key is None:
                raise
            DISPATCH_STATS["degraded"] += 1
            resilience.record_fallback(
                seam=resilience.classify(e), site=f"nest_kernel:{self.name}",
                error=e, from_schedule="tuned", to_schedule="default",
                key=key)
            return self._dispatch(args, params, interpret, DEFAULT_SCHEDULE)

    def _quarantine_tuned(self, args, params) -> Optional[str]:
        """Sideline the committed tuned entry for this call, if any.

        Returns the quarantined cache key, or ``None`` when the call was
        already running the default schedule (nothing tuned to degrade
        from — the failure is genuine and must propagate).
        """
        try:
            operands, static, _final = self._prepare(*args, **params)
            nest = self._nest(static)
            out_dtype = "float32" if self._out_dtype is None else \
                str(jnp.dtype(self._out_dtype(static)))
            tuned = autotune.lookup(nest, dict(operands), mode=self._mode,
                                    out_dtype=out_dtype)
            if tuned == DEFAULT_SCHEDULE:
                return None
            return autotune.quarantine(nest, dict(operands), mode=self._mode,
                                       out_dtype=out_dtype)
        except Exception:  # re-probe failed: keep the original error
            return None

    def _dispatch(self, args, params, interpret: Optional[bool],
                  schedule: Optional[Schedule]):
        key = (_call_key(args, params), schedule, interpret,
               autotune.epoch() if schedule is None else -1)
        fn = self._cache.get(key)
        if fn is None:
            operands, static, _final = self._prepare(*args, **params)
            nest = self._nest(static)
            kw = {} if self._out_dtype is None else \
                {"out_dtype": self._out_dtype(static)}
            sched = schedule
            if sched is None:
                out_dtype = str(jnp.dtype(kw.get("out_dtype", jnp.float32)))
                try:
                    sched = autotune.lookup(nest, dict(operands),
                                            mode=self._mode,
                                            out_dtype=out_dtype)
                except resilience.fallback_error_types() as e:
                    DISPATCH_STATS["fallbacks"] += 1
                    resilience.record_fallback(
                        seam=resilience.classify(e),
                        site=f"nest_kernel:{self.name}", error=e,
                        from_schedule="tuned-lookup", to_schedule="default")
                    sched = DEFAULT_SCHEDULE
            arr_idx = tuple(i for i, a in enumerate(args)
                            if _is_arraylike(a))
            # static positions only — see the _KernelBase note: closing
            # over first-call arrays would pin their buffers
            statics = tuple(None if _is_arraylike(a) else a for a in args)

            def pipeline(*arrs, _st=statics, _idx=arr_idx, _sched=sched):
                DISPATCH_STATS["traces"] += 1
                full = list(_st)
                for i, a in zip(_idx, arrs):
                    full[i] = a
                ops, s, final = self._prepare(*full, **params)
                okw = {} if self._out_dtype is None else \
                    {"out_dtype": self._out_dtype(s)}
                out = ssr_call(self._nest(s), self._body(s), dict(ops),
                               mode=self._mode, schedule=_sched,
                               interpret=interpret, **okw)
                return self._finish(out, final) if self._finish else out

            resilience.inject("compile")
            fn = jax.jit(pipeline)
            DISPATCH_STATS["builds"] += 1
            if len(self._cache) >= _PIPELINE_CACHE_MAX:
                self._cache.clear()
            self._cache[key] = fn
        return fn(*[a for a in args if _is_arraylike(a)])


class StreamKernel(_KernelBase):
    """A streamed (SSR) kernel: geometry from ``launch``, body per block.

    Requires a ``lowering_waiver`` naming why the §3.2 pipeline cannot
    emit this schedule (see module docstring) — prefer :class:`NestKernel`.
    """

    def __init__(self, name: str, *, prepare: Callable, launch: Callable,
                 body: Callable, finish: Optional[Callable] = None,
                 lowering_waiver: Optional[str] = None):
        super().__init__(name, prepare=prepare, finish=finish)
        self.lowering_waiver = _require_waiver(name, lowering_waiver)
        self._launch = launch
        self._body = body

    def _build(self, static, arrays, interpret: bool,
               schedule: Optional[Schedule]) -> Callable:
        lc: Launch = self._launch(static, *arrays)
        return ssr_pallas(
            self._body(static),
            grid=lc.grid,
            in_streams=list(lc.in_streams),
            out_streams=list(lc.out_streams),
            out_shapes=list(lc.out_shapes),
            scratch_shapes=list(lc.scratch_shapes),
            interpret=interpret,
            dimension_semantics=lc.dimension_semantics,
            buffer_depth=(schedule or DEFAULT_SCHEDULE).buffer_depth,
        )


class MonolithicKernel(_KernelBase):
    """A baseline kernel: one grid step, explicit in-body block walk."""

    def __init__(self, name: str, *, prepare: Callable, body: Callable,
                 out_shape: Callable, finish: Optional[Callable] = None):
        super().__init__(name, prepare=prepare, finish=finish)
        self._body = body
        self._out_shape = out_shape

    def _build(self, static, arrays, interpret: bool,
               schedule: Optional[Schedule]) -> Callable:
        # the serialised baseline has no streams to pipeline: schedule
        # (buffer_depth included) is deliberately ignored
        call = pl.pallas_call(
            self._body(static),
            out_shape=self._out_shape(static, *arrays),
            interpret=interpret,
        )
        return jax.jit(call)


class ChainedKernel(_KernelBase):
    """A fused producer→consumer kernel (stream chaining).

    Reuses a producer's stream geometry (its ``launch``) unchanged; per grid
    step the producer body's output block is written to a VMEM scratch —
    never to HBM — and the ``consumer`` body (a unary block→block post-op)
    reads it back the same step.  Fusing eliminates the intermediate HBM
    buffer the unfused two-kernel composition materialises: one store and
    one load per element, the dominant cost of short chained kernels.

    ``producer(static) -> fn(*in_blocks) -> block`` and
    ``consumer(static) -> fn(block) -> block`` follow the same builder
    contract as :class:`StreamKernel` bodies.  The scratch block's shape and
    dtype come from the launch's (single) output stream, so the consumer
    must be shape-preserving — chains that change the iteration space need
    the nest-level :func:`repro.core.ssr_chain_call` instead.
    """

    def __init__(self, name: str, *, prepare: Callable, launch: Callable,
                 producer: Callable, consumer: Callable,
                 finish: Optional[Callable] = None,
                 lowering_waiver: Optional[str] = None):
        super().__init__(name, prepare=prepare, finish=finish)
        self.lowering_waiver = _require_waiver(name, lowering_waiver)
        self._launch = launch
        self._producer = producer
        self._consumer = consumer

    def _build(self, static, arrays, interpret: bool,
               schedule: Optional[Schedule]) -> Callable:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

        lc: Launch = self._launch(static, *arrays)
        if len(lc.out_streams) != 1:
            raise ValueError(
                f"{self.name}: ChainedKernel needs exactly one output "
                "stream to size the intermediate scratch")
        prod_body = self._producer(static)
        cons_body = self._consumer(static)
        n_in = len(lc.in_streams)
        inter_shape = lc.out_streams[0].block_shape
        inter_dtype = lc.out_shapes[0].dtype

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            s_ref = refs[n_in + 1]
            # the intermediate lands in VMEM scratch, never HBM
            s_ref[...] = jnp.asarray(
                prod_body(*[r[...] for r in in_refs]),
                inter_dtype).reshape(inter_shape)
            o_ref[...] = jnp.asarray(cons_body(s_ref[...]),
                                     inter_dtype).reshape(inter_shape)

        return ssr_pallas(
            kernel,
            grid=lc.grid,
            in_streams=list(lc.in_streams),
            out_streams=list(lc.out_streams),
            out_shapes=list(lc.out_shapes),
            scratch_shapes=[pltpu.VMEM(inter_shape, inter_dtype),
                            *lc.scratch_shapes],
            interpret=interpret,
            dimension_semantics=lc.dimension_semantics,
            buffer_depth=(schedule or DEFAULT_SCHEDULE).buffer_depth,
        )
