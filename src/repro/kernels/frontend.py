"""Shared kernel frontend: pad → reshape → stream dispatch → trim, once.

This is the loop prologue/epilogue of the paper's Fig. 4 (steps ① and ④ —
stream setup before the region, result write-back after), factored out of
the §4.2 kernel suite.  Every kernel used to re-implement the same four
steps around its compute body: zero-pad operands to whole VMEM blocks, reshape to the
2-D (rows, lanes) layout the streams address, build + jit the ``ssr_pallas``
call, and trim the padding off the result.  :class:`StreamKernel` owns that
pipeline; a kernel module now declares only

* ``prepare`` — operand canonicalisation (pure jnp pad/reshape, usually one
  of the helpers below),
* ``launch``  — the stream geometry (grid, BlockStreams, out shapes,
  scratch) as a :class:`Launch`,
* ``body``    — the compute region builder (``body(static) -> callable``),
* ``finish``  — result trimming.

Built kernels are cached on (static meta, operand shapes/dtypes, interpret),
so repeated calls reuse the jitted ``pallas_call`` exactly like the old
per-module ``functools.partial(jax.jit, static_argnames=…)`` dispatchers —
but in one place.  ``interpret=None`` autodetects: Mosaic on a real TPU,
interpreter elsewhere.

Dtype policy: bodies compute in :data:`COMPUTE_DTYPE` (f32 — the MXU/VPU
accumulation width) regardless of storage dtype; :func:`promote` is the one
place that states it.

:class:`MonolithicKernel` is the same contract for the *baseline* variants:
a single-step ``pallas_call`` whose body walks blocks with explicit loads —
the paper's serialised load→compute issue — with the identical caching and
pad/trim treatment, so "baseline" and "ssr" differ only in how operands are
delivered.

**One path to silicon.**  :class:`NestKernel` is the preferred declarative
shell: the kernel states a :class:`~repro.core.LoopNest` (the §3.2
compiler's input) plus a block body, and the whole schedule — grid, index
maps, repeat streams, contraction accumulators — comes out of
``ssrify``/``lower_plan``/``lower_nest`` via :func:`repro.core.ssr_call`.
A module may still hand a raw :class:`Launch` to :class:`StreamKernel` /
:class:`ChainedKernel`, but only with a ``lowering_waiver``: one sentence
stating why the pattern is outside the block-granular AGU model (halo
overlap, carried state, power-of-two shuffle networks, …).  The waiver is
mandatory — an undeclared escape hatch is a compiler-coverage bug.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream  # noqa: F401  (re-export for kernels)
from repro.core.lowering import ssr_call
from repro.core.ssr import _on_tpu, ssr_pallas

ROWS = 8
LANES = 128
BLOCK_ELEMS = ROWS * LANES
COMPUTE_DTYPE = jnp.float32


def promote(x: jax.Array) -> jax.Array:
    """Cast a block to the compute dtype (f32 accumulation everywhere)."""
    return x.astype(COMPUTE_DTYPE)


# -- operand canonicalisation helpers ---------------------------------------


def pad_vector(x: jax.Array, *, block: int = BLOCK_ELEMS,
               lanes: int = LANES) -> jax.Array:
    """Zero-pad a 1-D array to whole blocks; reshape to (rows, lanes)."""
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, lanes)


def trim_vector(out: jax.Array, n: int) -> jax.Array:
    """Undo :func:`pad_vector`: flatten and drop the padding tail."""
    return out.reshape(-1)[:n]


def pad_leading(a: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the leading (row) dim of a matrix to a multiple of ``mult``."""
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    return a


def require_power_of_two(n: int, what: str) -> None:
    if n & (n - 1):
        raise ValueError(f"{what} needs a power-of-two length, got {n}")


# -- declarative kernel shells ----------------------------------------------


@dataclasses.dataclass(frozen=True)
class Launch:
    """One kernel's stream geometry, as declared by its module."""

    grid: Tuple[int, ...]
    in_streams: Tuple[BlockStream, ...]
    out_streams: Tuple[BlockStream, ...]
    out_shapes: Tuple[jax.ShapeDtypeStruct, ...]
    scratch_shapes: Tuple[Any, ...] = ()
    dimension_semantics: Optional[Tuple[str, ...]] = None


class _KernelBase:
    """Shared call pipeline: prepare → cached build → run → finish."""

    def __init__(self, name: str, *, prepare: Callable,
                 finish: Optional[Callable] = None):
        self.name = name
        self._prepare = prepare
        self._finish = finish
        self._cache: Dict[Any, Callable] = {}

    def _build(self, static, arrays, interpret: bool) -> Callable:
        raise NotImplementedError

    def __call__(self, *args, interpret: Optional[bool] = None, **params):
        arrays, static, final = self._prepare(*args, **params)
        arrays = tuple(arrays)
        if interpret is None:
            interpret = not _on_tpu()
        key = (static,
               tuple((a.shape, str(a.dtype)) for a in arrays),
               bool(interpret))
        fn = self._cache.get(key)
        if fn is None:
            fn = self._build(static, arrays, bool(interpret))
            self._cache[key] = fn
        out = fn(*arrays)
        return self._finish(out, final) if self._finish else out


def _require_waiver(name: str, waiver: Optional[str]) -> str:
    """Hand-scheduled geometry must state why the compiler cannot emit it."""
    if not waiver or not waiver.strip():
        raise ValueError(
            f"kernel {name!r} constructs a Launch without a lowering_waiver; "
            "declare why the pattern is outside the block-granular AGU "
            "model, or migrate to NestKernel")
    return waiver


class NestKernel:
    """A kernel whose schedule IS its :class:`~repro.core.LoopNest`.

    The declarative replacement for ``StreamKernel``'s ``launch=`` escape
    hatch: the module supplies

    * ``prepare(*args, **params) -> (operands, static, final)`` — operand
      canonicalisation; ``operands`` maps the nest's :class:`MemRef` names
      to arrays (no padding/reshaping — the lowering owns the layout);
    * ``nest(static) -> LoopNest`` — the §3.2 compiler input; a nest with
      an output WRITE ref takes the level-mapped contraction path, a
      read-only nest uses ``mode`` (``"reduce"``/``"map"``);
    * ``body(static) -> fn(*blocks)`` — the pure compute region;
    * ``finish(out, final)`` — result post-processing (dtype cast, …).

    Everything between prepare and finish — grid, index maps, repeat
    streams, accumulators, padding, kernel caching — is
    :func:`repro.core.ssr_call`, i.e. the same pipeline the compiler tests
    verify, so the kernel is covered by the Eq. (1)–(3) cost model
    (``plan_stats``) and the cluster layer for free.
    """

    def __init__(self, name: str, *, prepare: Callable, nest: Callable,
                 body: Callable, mode: str = "reduce",
                 finish: Optional[Callable] = None,
                 out_dtype: Optional[Callable] = None):
        self.name = name
        self._prepare = prepare
        self._nest = nest
        self._body = body
        self._mode = mode
        self._finish = finish
        # out_dtype(static) -> dtype; None keeps ssr_call's f32 accumulation
        # default.  Dtype-preserving kernels (integer relu) need this so the
        # streamed engine stays bit-exact with the baseline.
        self._out_dtype = out_dtype

    def loop_nest(self, static):
        """The nest this kernel executes — exposed for cost-model oracles."""
        return self._nest(static)

    def __call__(self, *args, interpret: Optional[bool] = None, **params):
        operands, static, final = self._prepare(*args, **params)
        kw = {} if self._out_dtype is None else \
            {"out_dtype": self._out_dtype(static)}
        out = ssr_call(self._nest(static), self._body(static), dict(operands),
                       mode=self._mode, interpret=interpret, **kw)
        return self._finish(out, final) if self._finish else out


class StreamKernel(_KernelBase):
    """A streamed (SSR) kernel: geometry from ``launch``, body per block.

    Requires a ``lowering_waiver`` naming why the §3.2 pipeline cannot
    emit this schedule (see module docstring) — prefer :class:`NestKernel`.
    """

    def __init__(self, name: str, *, prepare: Callable, launch: Callable,
                 body: Callable, finish: Optional[Callable] = None,
                 lowering_waiver: Optional[str] = None):
        super().__init__(name, prepare=prepare, finish=finish)
        self.lowering_waiver = _require_waiver(name, lowering_waiver)
        self._launch = launch
        self._body = body

    def _build(self, static, arrays, interpret: bool) -> Callable:
        lc: Launch = self._launch(static, *arrays)
        return ssr_pallas(
            self._body(static),
            grid=lc.grid,
            in_streams=list(lc.in_streams),
            out_streams=list(lc.out_streams),
            out_shapes=list(lc.out_shapes),
            scratch_shapes=list(lc.scratch_shapes),
            interpret=interpret,
            dimension_semantics=lc.dimension_semantics,
        )


class MonolithicKernel(_KernelBase):
    """A baseline kernel: one grid step, explicit in-body block walk."""

    def __init__(self, name: str, *, prepare: Callable, body: Callable,
                 out_shape: Callable, finish: Optional[Callable] = None):
        super().__init__(name, prepare=prepare, finish=finish)
        self._body = body
        self._out_shape = out_shape

    def _build(self, static, arrays, interpret: bool) -> Callable:
        call = pl.pallas_call(
            self._body(static),
            out_shape=self._out_shape(static, *arrays),
            interpret=interpret,
        )
        return jax.jit(call)


class ChainedKernel(_KernelBase):
    """A fused producer→consumer kernel (stream chaining).

    Reuses a producer's stream geometry (its ``launch``) unchanged; per grid
    step the producer body's output block is written to a VMEM scratch —
    never to HBM — and the ``consumer`` body (a unary block→block post-op)
    reads it back the same step.  Fusing eliminates the intermediate HBM
    buffer the unfused two-kernel composition materialises: one store and
    one load per element, the dominant cost of short chained kernels.

    ``producer(static) -> fn(*in_blocks) -> block`` and
    ``consumer(static) -> fn(block) -> block`` follow the same builder
    contract as :class:`StreamKernel` bodies.  The scratch block's shape and
    dtype come from the launch's (single) output stream, so the consumer
    must be shape-preserving — chains that change the iteration space need
    the nest-level :func:`repro.core.ssr_chain_call` instead.
    """

    def __init__(self, name: str, *, prepare: Callable, launch: Callable,
                 producer: Callable, consumer: Callable,
                 finish: Optional[Callable] = None,
                 lowering_waiver: Optional[str] = None):
        super().__init__(name, prepare=prepare, finish=finish)
        self.lowering_waiver = _require_waiver(name, lowering_waiver)
        self._launch = launch
        self._producer = producer
        self._consumer = consumer

    def _build(self, static, arrays, interpret: bool) -> Callable:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

        lc: Launch = self._launch(static, *arrays)
        if len(lc.out_streams) != 1:
            raise ValueError(
                f"{self.name}: ChainedKernel needs exactly one output "
                "stream to size the intermediate scratch")
        prod_body = self._producer(static)
        cons_body = self._consumer(static)
        n_in = len(lc.in_streams)
        inter_shape = lc.out_streams[0].block_shape
        inter_dtype = lc.out_shapes[0].dtype

        def kernel(*refs):
            in_refs, o_ref = refs[:n_in], refs[n_in]
            s_ref = refs[n_in + 1]
            # the intermediate lands in VMEM scratch, never HBM
            s_ref[...] = jnp.asarray(
                prod_body(*[r[...] for r in in_refs]),
                inter_dtype).reshape(inter_shape)
            o_ref[...] = jnp.asarray(cons_body(s_ref[...]),
                                     inter_dtype).reshape(inter_shape)

        return ssr_pallas(
            kernel,
            grid=lc.grid,
            in_streams=list(lc.in_streams),
            out_streams=list(lc.out_streams),
            out_shapes=list(lc.out_shapes),
            scratch_shapes=[pltpu.VMEM(inter_shape, inter_dtype),
                            *lc.scratch_shapes],
            interpret=interpret,
            dimension_semantics=lc.dimension_semantics,
        )
