"""Fused-DAG kernels — multi-consumer chaining over the registry.

The linear chains in :mod:`repro.kernels.chained` fuse one producer into
one consumer.  Real pre/post-processing blocks are *DAGs*: layernorm's
centred input feeds both the variance pass and the final normalise, and
an MLP's activation feeds both the second matmul and the residual add.
:func:`repro.core.ssr_dag_call` fuses the whole graph into ONE Pallas
kernel — every intermediate lives in a refcounted VMEM scratch slot and
is freed after its last consumer — so a diamond costs two scratch blocks
and zero HBM round-trips.

Three kernels ride the path, each a three-stage diamond over rows of
``DEFAULT_POLICY.lanes`` (= 128) elements:

* ``layernorm``     — x → {centre, square} → normalise           (map)
* ``softmax_xent``  — z → {shift, exp} → masked row loss         (reduce)
* ``mlp_block``     — x → relu(xW₁+b₁) → {xW₂+b₂, residual add}  (map)

Per-row reductions (mean, max, logsumexp) work because a streamed
``(n,)`` vector with ``n`` a multiple of 128 lays out as rows of exactly
one data row per block row — the bodies therefore assume the default
128-lane block policy, which the DAG autotuner never changes (it searches
*graph cuts*, not block geometry).

Each registry entry exposes ``ssr`` = the fused DAG, ``baseline`` = the
honest unfused composition (every intermediate through HBM, schedules
pinned to the default so fusion is the only variable), and ``ref`` = the
jnp oracle.  :func:`dag_cases` additionally hands the bench the raw
``(nests, bodies, operands)`` spec so ``autotune_dag`` can search cuts
and the HLO census can audit every intermediate at once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import (Direction, LoopNest, MemRef, compiler, ssr_call,
                        ssr_dag_call)
from repro.core.lowering import DEFAULT_POLICY, DEFAULT_SCHEDULE

from .frontend import BLOCK_ELEMS
from .registry import KernelEntry, register_kernel

LANES = DEFAULT_POLICY.lanes  # data-row width every body assumes
EPS = 1e-5


def _padded_blocks(n: int) -> Tuple[int, int]:
    """Padded 2-D (rows, lanes) layout of an n-element streamed vector."""
    steps = -(-n // BLOCK_ELEMS)
    return (steps * DEFAULT_POLICY.rows, DEFAULT_POLICY.lanes)


def _rows_of(x: jax.Array) -> int:
    if x.ndim != 2 or x.shape[1] != LANES:
        raise ValueError(
            f"dag kernels stream rows of exactly {LANES} elements; got "
            f"shape {x.shape}")
    return x.shape[0]


def _dag_nests(n: int,
               stages: Tuple[Tuple[Tuple[str, ...], Tuple[str, ...], int],
                             ...]) -> Tuple[LoopNest, ...]:
    """Flat (n,)-bounds nests from ((reads, writes, compute), ...)."""
    out = []
    for reads, writes, cost in stages:
        refs = tuple([MemRef(r, Direction.READ, (1,)) for r in reads]
                     + [MemRef(w, Direction.WRITE, (1,)) for w in writes])
        out.append(LoopNest(bounds=(n,), refs=refs,
                            compute_per_level=(cost,)))
    return tuple(out)


def _map_nest(n: int, names: Tuple[str, ...], compute: int) -> LoopNest:
    return compiler.elementwise_nest(n, names, compute)


# --------------------------------------------------------------------------
# layernorm: x → {C = x − μ, V = C²} → C·rsqrt(mean(V) + ε)
# --------------------------------------------------------------------------


def _ln_centre(xb):
    return xb - jnp.mean(xb, axis=1, keepdims=True)


def _ln_square(cb):
    return cb * cb


def _ln_normalise(cb, vb):
    return cb * jax.lax.rsqrt(jnp.mean(vb, axis=1, keepdims=True) + EPS)


_LN_STAGES = ((("X",), ("C",), 2), (("C",), ("V",), 1),
              (("C", "V"), (), 3))
_LN_BODIES = (_ln_centre, _ln_square, _ln_normalise)


def layernorm_spec(x: jax.Array):
    """(nests, bodies, operands, mode, uniforms) — raw ssr_dag_call args."""
    n = _rows_of(x) * LANES
    return (_dag_nests(n, _LN_STAGES), _LN_BODIES,
            {"X": x.astype(jnp.float32).reshape(-1)}, "map", {})


def fused_layernorm(x: jax.Array, *, interpret=None, schedule=None):
    """Per-row layernorm as ONE kernel: C is consumed twice, all in VMEM.

    ``schedule=None`` resolves through the DAG autotune cache (the best
    committed graph cut); pass ``DEFAULT_SCHEDULE`` to pin all-fused.
    """
    m = _rows_of(x)
    nests, bodies, operands, mode, _ = layernorm_spec(x)
    out = ssr_dag_call(nests, bodies, operands, mode=mode,
                       schedule=schedule, interpret=interpret)
    return out.reshape(m, LANES)


def unfused_layernorm(x: jax.Array, *, interpret=None):
    """Three streamed kernels: C and V both round-trip through HBM."""
    m = _rows_of(x)
    n = m * LANES
    xf = x.astype(jnp.float32).reshape(-1)
    c = ssr_call(_map_nest(n, ("X",), 2), _ln_centre, {"X": xf},
                 mode="map", schedule=DEFAULT_SCHEDULE, interpret=interpret)
    v = ssr_call(_map_nest(n, ("C",), 1), _ln_square, {"C": c},
                 mode="map", schedule=DEFAULT_SCHEDULE, interpret=interpret)
    out = ssr_call(_map_nest(n, ("C", "V"), 3), _ln_normalise,
                   {"C": c, "V": v}, mode="map",
                   schedule=DEFAULT_SCHEDULE, interpret=interpret)
    return out.reshape(m, LANES)


# --------------------------------------------------------------------------
# softmax cross-entropy: z → {C = z − max, E = exp C} → masked row loss
# --------------------------------------------------------------------------


def _sx_shift(zb):
    return zb - jnp.max(zb, axis=1, keepdims=True)


def _sx_exp(cb):
    return jnp.exp(cb)


def _sx_loss(cb, eb, pb):
    # mask = Σp per row: 1 on real rows (targets sum to one), 0 on padding
    # rows — exactly the padding-neutrality the reduce epilogue requires.
    # Real rows have Σexp(C) ≥ exp(0) = 1 (the max logit shifts to 0), so
    # the clamp only rescues padding rows — where E re-padded to zeros
    # after an HBM round-trip would otherwise give mask·log(0) = NaN.
    mask = jnp.sum(pb, axis=1, keepdims=True)
    lse = jnp.log(jnp.maximum(jnp.sum(eb, axis=1, keepdims=True), 1e-30))
    dot = jnp.sum(pb * cb, axis=1, keepdims=True)
    return jnp.broadcast_to(mask * (lse - dot) / cb.shape[1], cb.shape)


_SX_STAGES = ((("Z",), ("C",), 2), (("C",), ("E",), 1),
              (("C", "E", "P"), (), 4))
_SX_BODIES = (_sx_shift, _sx_exp, _sx_loss)


def softmax_xent_spec(z: jax.Array, p: jax.Array):
    n = _rows_of(z) * LANES
    if p.shape != z.shape:
        raise ValueError(f"targets shape {p.shape} != logits {z.shape}")
    return (_dag_nests(n, _SX_STAGES), _SX_BODIES,
            {"Z": z.astype(jnp.float32).reshape(-1),
             "P": p.astype(jnp.float32).reshape(-1)}, "reduce", {})


def fused_softmax_xent(z: jax.Array, p: jax.Array, *, interpret=None,
                       schedule=None):
    """Σ_rows [logΣexp(z) − Σ p·z] as one fused DAG (reduce epilogue).

    The shifted logits C feed both the exp pass and the p·C dot — the
    classic two-consumer pattern a linear chain cannot express.
    """
    nests, bodies, operands, mode, _ = softmax_xent_spec(z, p)
    return ssr_dag_call(nests, bodies, operands, mode=mode,
                        schedule=schedule, interpret=interpret)


def unfused_softmax_xent(z: jax.Array, p: jax.Array, *, interpret=None):
    n = _rows_of(z) * LANES
    zf = z.astype(jnp.float32).reshape(-1)
    pf = p.astype(jnp.float32).reshape(-1)
    c = ssr_call(_map_nest(n, ("Z",), 2), _sx_shift, {"Z": zf},
                 mode="map", schedule=DEFAULT_SCHEDULE, interpret=interpret)
    e = ssr_call(_map_nest(n, ("C",), 1), _sx_exp, {"C": c},
                 mode="map", schedule=DEFAULT_SCHEDULE, interpret=interpret)
    return ssr_call(_map_nest(n, ("C", "E", "P"), 4), _sx_loss,
                    {"C": c, "E": e, "P": pf}, mode="reduce",
                    schedule=DEFAULT_SCHEDULE, interpret=interpret)


# --------------------------------------------------------------------------
# 2-layer MLP block: x → H = relu(xW₁+b₁) → {Y = HW₂+b₂, Y + H}
# --------------------------------------------------------------------------

# The weights ride as *uniform operands* — whole arrays every grid step
# needs in full, delivered to the kernel as one loop-invariant block each
# and appended to EVERY stage body's arguments (Pallas forbids kernels
# closing over array constants).  Uniform order is dict order: W1, B1,
# W2, B2.


def _mlp_hidden(xb, w1, b1, w2, b2):
    return jax.nn.relu(
        jnp.dot(xb, w1, preferred_element_type=jnp.float32) + b1)


def _mlp_out(hb, w1, b1, w2, b2):
    return jnp.dot(hb, w2, preferred_element_type=jnp.float32) + b2


def _mlp_residual(hb, yb, *uniforms):
    return yb + hb


_MLP_STAGES = ((("X",), ("H",), 2 * LANES),
               (("H",), ("Y",), 2 * LANES),
               (("H", "Y"), (), 1))
_MLP_BODIES = (_mlp_hidden, _mlp_out, _mlp_residual)


def mlp_block_spec(x, w1, b1, w2, b2):
    n = _rows_of(x) * LANES
    for w in (w1, w2):
        if w.shape != (LANES, LANES):
            raise ValueError(
                f"mlp_block weights must be ({LANES}, {LANES}); "
                f"got {w.shape}")
    uniforms = {"W1": jnp.asarray(w1, jnp.float32),
                "B1": jnp.asarray(b1, jnp.float32),
                "W2": jnp.asarray(w2, jnp.float32),
                "B2": jnp.asarray(b2, jnp.float32)}
    return (_dag_nests(n, _MLP_STAGES), _MLP_BODIES,
            {"X": x.astype(jnp.float32).reshape(-1)}, "map", uniforms)


def fused_mlp_block(x, w1, b1, w2, b2, *, interpret=None, schedule=None):
    """relu(xW₁+b₁) → second matmul + residual add, H consumed twice."""
    m = _rows_of(x)
    nests, bodies, operands, mode, uniforms = mlp_block_spec(
        x, w1, b1, w2, b2)
    out = ssr_dag_call(nests, bodies, operands, mode=mode,
                       schedule=schedule, interpret=interpret,
                       uniforms=uniforms)
    return out.reshape(m, LANES)


def unfused_mlp_block(x, w1, b1, w2, b2, *, interpret=None):
    m = _rows_of(x)
    n = m * LANES
    _, _, operands, _, uniforms = mlp_block_spec(x, w1, b1, w2, b2)
    h = ssr_call(_map_nest(n, ("X",), 2 * LANES), _mlp_hidden, operands,
                 mode="map", schedule=DEFAULT_SCHEDULE, interpret=interpret,
                 uniforms=uniforms)
    y = ssr_call(_map_nest(n, ("H",), 2 * LANES), _mlp_out, {"H": h},
                 mode="map", schedule=DEFAULT_SCHEDULE, interpret=interpret,
                 uniforms=uniforms)
    out = ssr_call(_map_nest(n, ("H", "Y"), 1), _mlp_residual,
                   {"H": h, "Y": y}, mode="map",
                   schedule=DEFAULT_SCHEDULE, interpret=interpret,
                   uniforms=uniforms)
    return out.reshape(m, LANES)


# --------------------------------------------------------------------------
# DAG-case table: bench, HLO-elimination audit, and cut search iterate it.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DagCase:
    """One fused-DAG variant plus everything needed to audit and tune it.

    ``inters(*args)`` returns the (dtype, dims) of EVERY padded buffer the
    unfused composition materialises (one per distinct intermediate — the
    multi-consumer one appears once; its extra load is free to audit).
    ``spec(*args)`` returns the raw ``(nests, bodies, operands, mode,
    uniforms)`` quintuple so the bench can run
    :func:`repro.core.autotune.autotune_dag` on exactly the graph the
    fused kernel executes.
    """

    name: str
    fused: Callable
    unfused: Callable
    ref: Callable
    example: Callable
    inters: Callable[..., Tuple[Tuple[str, Tuple[int, ...]], ...]]
    spec: Callable
    tol: Dict[str, float]


def _two_vector_inters(x, *rest, **kw):
    dims = _padded_blocks(x.shape[0] * LANES)
    return (("f32", dims), ("f32", dims))


def _mk_examples():
    def ex_layernorm(rng, odd: bool = False):
        m = 37 if odd else 32
        return ((jnp.asarray(rng.standard_normal((m, LANES)),
                             jnp.float32),), {})

    def ex_softmax(rng, odd: bool = False):
        m = 37 if odd else 32
        z = jnp.asarray(rng.standard_normal((m, LANES)), jnp.float32)
        p = jax.nn.one_hot(
            jnp.asarray(rng.integers(0, LANES, m)), LANES,
            dtype=jnp.float32)
        return ((z, p), {})

    def ex_mlp(rng, odd: bool = False):
        m = 37 if odd else 32
        x = jnp.asarray(rng.standard_normal((m, LANES)), jnp.float32)
        w1 = jnp.asarray(rng.standard_normal((LANES, LANES)) * 0.1,
                         jnp.float32)
        b1 = jnp.asarray(rng.standard_normal(LANES) * 0.1, jnp.float32)
        w2 = jnp.asarray(rng.standard_normal((LANES, LANES)) * 0.1,
                         jnp.float32)
        b2 = jnp.asarray(rng.standard_normal(LANES) * 0.1, jnp.float32)
        return ((x, w1, b1, w2, b2), {})

    return ex_layernorm, ex_softmax, ex_mlp


def dag_cases() -> Tuple[DagCase, ...]:
    from . import ref

    ex_ln, ex_sx, ex_mlp = _mk_examples()
    loose = {"rtol": 1e-3, "atol": 1e-3}
    reduce_tol = {"rtol": 1e-2, "atol": 1e-2}
    return (
        DagCase("layernorm", fused_layernorm, unfused_layernorm,
                ref.layernorm_ref, ex_ln, _two_vector_inters,
                layernorm_spec, loose),
        DagCase("softmax_xent", fused_softmax_xent, unfused_softmax_xent,
                ref.softmax_xent_ref, ex_sx, _two_vector_inters,
                softmax_xent_spec, reduce_tol),
        DagCase("mlp_block", fused_mlp_block, unfused_mlp_block,
                ref.mlp_block_ref, ex_mlp, _two_vector_inters,
                mlp_block_spec, loose),
    )


def _register(case: DagCase) -> None:
    @register_kernel(case.name)
    def _entry() -> KernelEntry:
        return KernelEntry(name=case.name, ssr=case.fused,
                           baseline=case.unfused, ref=case.ref,
                           example=case.example, tol=dict(case.tol),
                           problem=f"fused DAG: {case.name}")


for _case in dag_cases():
    _register(_case)
