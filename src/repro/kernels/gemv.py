"""Dense matrix-vector product (GEMV) — paper §4.2 (64×64 · 64).

SSR structure: the matrix is a 2-D read stream walked row-panel-wise; the
vector is a *repeat* stream — one fetch, re-emitted for every row panel
(the paper's repeat register: "useful if a value loaded from memory is used
as an operand multiple times", §3.1).  Output is a write stream of row
panels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, ssr_pallas

_ROWS = 8


def _body(a_ref, x_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(
        a, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch(a, x2d, interpret: bool = True):
    m, n = a.shape
    grid = (m // _ROWS,)
    fn = ssr_pallas(
        _body,
        grid=grid,
        in_streams=[
            BlockStream((_ROWS, n), lambda i: (i, 0), name="A"),
            BlockStream((1, n), lambda i: (0, 0), name="x"),   # repeat stream
        ],
        out_streams=[BlockStream((_ROWS, 1), lambda i: (i, 0),
                                 Direction.WRITE, name="y")],
        out_shapes=[jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("parallel",),
    )
    return fn(a, x2d)


def ssr_gemv(a: jax.Array, x: jax.Array, *, interpret: bool = True) -> jax.Array:
    m, n = a.shape
    pad_m = (-m) % _ROWS
    if pad_m:
        a = jnp.pad(a, ((0, pad_m), (0, 0)))
    out = _dispatch(a, x.reshape(1, n), interpret)
    return out.reshape(-1)[:m]


def _baseline_body(a_ref, x_ref, o_ref):
    m = a_ref.shape[0]
    nblk = m // _ROWS

    def step(i, _):
        a = a_ref[pl.dslice(i * _ROWS, _ROWS), :].astype(jnp.float32)
        x = x_ref[...].astype(jnp.float32)
        o_ref[pl.dslice(i * _ROWS, _ROWS), :] = jax.lax.dot_general(
            a, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        return 0

    jax.lax.fori_loop(0, nblk, step, 0)


def baseline_gemv(a: jax.Array, x: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    m, n = a.shape
    pad_m = (-m) % _ROWS
    if pad_m:
        a = jnp.pad(a, ((0, pad_m), (0, 0)))
    out = pl.pallas_call(
        _baseline_body,
        out_shape=jax.ShapeDtypeStruct((m + pad_m, 1), jnp.float32),
        interpret=interpret,
    )(a, x.reshape(1, n))
    return out.reshape(-1)[:m]
