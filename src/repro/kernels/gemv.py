"""Dense matrix-vector product (GEMV) — paper §4.2 (64×64 · 64).

SSR structure, now fully nest-lowered: the matrix walks both loops dense
(row-major); the vector is a *repeat* stream — one fetch, re-emitted for
every row tile (the paper's repeat register: "useful if a value loaded
from memory is used as an operand multiple times", §3.1); y is revisited
across the column walk, so ``lower_nest`` carries it in a VMEM
accumulator (init on the first n step, drain on the last).  The kernel
module declares only :func:`repro.core.compiler.gemv_nest` plus the
row-panel dot body — grid, index maps, repeat stream and accumulator all
fall out of the shared lowering, and the autotuner searches the full
block geometry (the old waivered launch only exposed ``buffer_depth``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compiler
from repro.core.lowering import Schedule

from .frontend import (ROWS, MonolithicKernel, NestKernel, pad_leading,
                       promote)
from .registry import KernelEntry, register_kernel


def matvec_block(a, x):
    """Pure (rows, n)·(1, n)ᵀ row-panel product — shared with the baseline."""
    return jax.lax.dot_general(
        promote(a), promote(x), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _prepare(a, x):
    m, n = a.shape
    return {"A": a, "x": x}, (m, n), None


def _nest(static):
    m, n = static
    return compiler.gemv_nest(m, n)


def _body(static):
    def body(a_blk, x_blk):
        # one (t_m, 1) partial per grid step: y[i] += A[i, k-tile]·x[k-tile]
        return matvec_block(a_blk, x_blk)

    return body


_ssr = NestKernel("gemv", prepare=_prepare, nest=_nest, body=_body)


def _prepare_base(a, x):
    m, n = a.shape
    return (pad_leading(a, ROWS), x.reshape(1, n)), None, m


def _baseline_body(static):
    def body(a_ref, x_ref, o_ref):
        nblk = a_ref.shape[0] // ROWS

        def step(i, _):
            a = a_ref[pl.dslice(i * ROWS, ROWS), :]
            o_ref[pl.dslice(i * ROWS, ROWS), :] = matvec_block(a, x_ref[...])
            return 0

        jax.lax.fori_loop(0, nblk, step, 0)

    return body


_base = MonolithicKernel(
    "gemv", prepare=_prepare_base, body=_baseline_body,
    out_shape=lambda static, a, x2d: jax.ShapeDtypeStruct((a.shape[0], 1),
                                                          jnp.float32),
    finish=lambda out, m: out.reshape(-1)[:m])


def ssr_gemv(a: jax.Array, x: jax.Array, *, interpret=None,
             schedule: Schedule | None = None) -> jax.Array:
    """Streamed GEMV through the full compiler path (nest → plan → Pallas).

    ``schedule=None`` consults the autotuner's cache (keyed on
    :func:`~repro.core.compiler.gemv_nest`) for a tuned block geometry /
    ``buffer_depth``; an explicit schedule pins it.
    """
    return _ssr(a, x, interpret=interpret, schedule=schedule)


def baseline_gemv(a: jax.Array, x: jax.Array, *, interpret=None) -> jax.Array:
    return _base(a, x, interpret=interpret)


def cluster_gemv(a: jax.Array, x: jax.Array, *, cores: int,
                 interpret=None) -> jax.Array:
    """GEMV on a C-core cluster (paper §5.3): row-block split.

    GEMV is a reduction *per row*, so the nest-level map/reduce modes do
    not apply; instead the output rows split across cores
    (``cluster_kernel``): each core runs the unchanged streamed GEMV on
    its row panel with the x repeat-stream replicated (every core holds
    its own copy — the TCDM broadcast), and the row tiles concatenate
    with no collective at all.
    """
    from repro.parallel.cluster import cluster_kernel

    m = a.shape[0]
    a = pad_leading(a, cores * ROWS)
    out = cluster_kernel(
        lambda ac, xc: ssr_gemv(ac, xc, interpret=interpret),
        (a, x), cores=cores, in_dims=(0, None), out_dim=0)
    return out.reshape(-1)[:m]


@register_kernel("gemv")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        m, n = (60, 64) if odd else (64, 64)
        return ((jnp.asarray(rng.standard_normal((m, n)), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    return KernelEntry(name="gemv", ssr=ssr_gemv, baseline=baseline_gemv,
                       ref=ref.gemv_ref, cluster=cluster_gemv,
                       example=example,
                       tol={"rtol": 1e-3, "atol": 1e-3},
                       problem="64×64 · 64")
