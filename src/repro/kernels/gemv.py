"""Dense matrix-vector product (GEMV) — paper §4.2 (64×64 · 64).

SSR structure: the matrix is a 2-D read stream walked row-panel-wise; the
vector is a *repeat* stream — one fetch, re-emitted for every row panel
(the paper's repeat register: "useful if a value loaded from memory is used
as an operand multiple times", §3.1).  Output is a write stream of row
panels.

The launch geometry is waivered (whole-row panels), so the autotuner's
only effective knob here is ``Schedule.buffer_depth`` — the data mover's
FIFO depth.  ``ssr_gemv(schedule=None)`` resolves it transparently from
the schedule cache keyed on :func:`repro.core.compiler.gemv_nest`, the
same pattern the stencil uses for its block width.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, autotune, compiler
from repro.core.lowering import Schedule

from .frontend import (ROWS, Launch, MonolithicKernel, StreamKernel,
                       pad_leading, promote)
from .registry import KernelEntry, register_kernel


def matvec_block(a, x):
    """Pure (ROWS, n)·(1, n)ᵀ row-panel product — shared with fused variants."""
    return jax.lax.dot_general(
        promote(a), promote(x), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _prepare(a, x):
    m, n = a.shape
    return (pad_leading(a, ROWS), x.reshape(1, n)), None, m


def _ssr_body(static):
    def body(a_ref, x_ref, o_ref):
        o_ref[...] = matvec_block(a_ref[...], x_ref[...])

    return body


def _launch(static, a, x2d):
    m, n = a.shape
    return Launch(
        grid=(m // ROWS,),
        in_streams=(
            BlockStream((ROWS, n), lambda i: (i, 0), name="A"),
            BlockStream((1, n), lambda i: (0, 0), name="x"),  # repeat stream
        ),
        out_streams=(BlockStream((ROWS, 1), lambda i: (i, 0),
                                 Direction.WRITE, name="y"),),
        out_shapes=(jax.ShapeDtypeStruct((m, 1), jnp.float32),),
        dimension_semantics=("parallel",),
    )


_ssr = StreamKernel(
    "gemv", prepare=_prepare, launch=_launch, body=_ssr_body,
    finish=lambda out, m: out.reshape(-1)[:m],
    lowering_waiver=(
        "whole-row (ROWS, n) panels with an un-tiled contraction dim — the "
        "MXU wants the full row resident per step, and this launch is the "
        "geometry substrate ChainedKernel fusions (gemv_relu) reuse"))


def _baseline_body(static):
    def body(a_ref, x_ref, o_ref):
        nblk = a_ref.shape[0] // ROWS

        def step(i, _):
            a = a_ref[pl.dslice(i * ROWS, ROWS), :]
            o_ref[pl.dslice(i * ROWS, ROWS), :] = matvec_block(a, x_ref[...])
            return 0

        jax.lax.fori_loop(0, nblk, step, 0)

    return body


_base = MonolithicKernel(
    "gemv", prepare=_prepare, body=_baseline_body,
    out_shape=lambda static, a, x2d: jax.ShapeDtypeStruct((a.shape[0], 1),
                                                          jnp.float32),
    finish=lambda out, m: out.reshape(-1)[:m])


def ssr_gemv(a: jax.Array, x: jax.Array, *, interpret=None,
             schedule: Schedule | None = None) -> jax.Array:
    """Streamed GEMV.  ``schedule=None`` consults the autotuner's cache
    (keyed on :func:`~repro.core.compiler.gemv_nest`) for a tuned
    ``buffer_depth``; an explicit schedule pins it."""
    if schedule is None:
        m, n = a.shape
        hit = autotune.lookup(compiler.gemv_nest(m, n), {"A": a, "x": x},
                              mode="map")
        schedule = None if hit == autotune.DEFAULT_SCHEDULE else hit
    return _ssr(a, x, interpret=interpret, schedule=schedule)


def baseline_gemv(a: jax.Array, x: jax.Array, *, interpret=None) -> jax.Array:
    return _base(a, x, interpret=interpret)


def cluster_gemv(a: jax.Array, x: jax.Array, *, cores: int,
                 interpret=None) -> jax.Array:
    """GEMV on a C-core cluster (paper §5.3): row-block split.

    GEMV is a reduction *per row*, so the nest-level map/reduce modes do
    not apply; instead the output rows split across cores
    (``cluster_kernel``): each core runs the unchanged streamed GEMV on
    its row panel with the x repeat-stream replicated (every core holds
    its own copy — the TCDM broadcast), and the row tiles concatenate
    with no collective at all.
    """
    from repro.parallel.cluster import cluster_kernel

    m = a.shape[0]
    a = pad_leading(a, cores * ROWS)
    out = cluster_kernel(
        lambda ac, xc: ssr_gemv(ac, xc, interpret=interpret),
        (a, x), cores=cores, in_dims=(0, None), out_dim=0)
    return out.reshape(-1)[:m]


@register_kernel("gemv")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        m, n = (60, 64) if odd else (64, 64)
        return ((jnp.asarray(rng.standard_normal((m, n)), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    return KernelEntry(name="gemv", ssr=ssr_gemv, baseline=baseline_gemv,
                       ref=ref.gemv_ref, cluster=cluster_gemv,
                       example=example,
                       tol={"rtol": 1e-3, "atol": 1e-3},
                       problem="64×64 · 64")
