"""CSR sparse kernels (SpMV / SpMM) on the indirection-stream path.

The SSR paper streams *affine* walks; its follow-ups — Indirection-SSR
(arXiv 2011.08070) and Sparse SSR (arXiv 2305.05559) — extend the AGU with
an index stream feeding the address stage, which is exactly what CSR
sparse-dense products need: the column-index stream drives gathers from the
dense operand.  This module is that extension end to end through the
*existing* pipeline:

* the host side validates CSR (loud ``ValueError`` per malformed invariant)
  and packs it to ELL — ``(m, k)`` value/column-index planes, ``k`` the max
  row population — because Pallas block schedules need static shapes; the
  pad entries are ``(0.0, 0)`` so they gather ``x[0]`` times zero;
* :func:`repro.core.compiler.spmv_nest` / :func:`~repro.core.compiler.
  spmm_nest` declare the loop nests with an **indirect** :class:`~repro.
  core.nest_analysis.MemRef` (``index_of="cidx"``), and ``ssrify`` /
  ``lower_nest`` / ``ssr_call`` do the rest — the gather table rides whole
  in VMEM, the body sees gathered blocks, the contraction accumulates;
* the baselines are monolithic single-step kernels with the *explicit*
  index handling (in-body ``jnp.take`` per element) the indirection papers
  charge against scalar cores; the refs densify and ``jnp.dot``.

Because ELL's row capacity ``k`` is a *data* fact (max nnz per row), the
public entry points take concrete CSR arrays, derive ``k`` on the host, and
only then enter the shape-static ``NestKernel`` — sparse formats are not
jit-transparent, by construction.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import compiler

from .frontend import MonolithicKernel, NestKernel, promote
from .registry import KernelEntry, register_kernel

# Padded row pitch of the SpMM gather table: the indirect ref's
# ``index_scale`` must be a static layout fact, so the dense operand is
# padded to a lane-aligned pitch independent of the (searched) schedule.
_TABLE_PITCH = 128


# --------------------------------------------------------------------------
# Host-side CSR validation + ELL packing
# --------------------------------------------------------------------------


def validate_csr(data, indices, indptr, num_cols: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Check the CSR invariants; return host arrays + row count.

    Every violation raises a ``ValueError`` whose message is pinned by
    ``tests/test_sparse.py`` — these are API surface, not prose.
    """
    data = np.asarray(data)
    indices = np.asarray(indices)
    indptr = np.asarray(indptr)
    if indptr.ndim != 1 or indptr.size < 2:
        raise ValueError(
            "CSR indptr must be 1-D with at least two entries (m+1)")
    if data.ndim != 1 or indices.ndim != 1 or data.shape != indices.shape:
        raise ValueError("CSR data and indices must be 1-D of equal length")
    if np.any(np.diff(indptr) < 0):
        raise ValueError("CSR indptr must be non-decreasing")
    if indptr[0] != 0 or indptr[-1] != data.size:
        raise ValueError("CSR indptr must start at 0 and end at nnz")
    if indices.size and (indices.min() < 0 or indices.max() >= num_cols):
        raise ValueError(
            f"CSR column index out of range [0, {num_cols})")
    if indices.size > 1:
        jumps = np.diff(indices)
        same_row = np.ones(indices.size - 1, dtype=bool)
        starts = indptr[1:-1]
        starts = starts[(starts > 0) & (starts < indices.size)]
        same_row[starts - 1] = False
        if np.any(jumps[same_row] <= 0):
            raise ValueError(
                "CSR column indices must be strictly increasing within "
                "each row")
    return data, indices, indptr, indptr.size - 1


def csr_to_ell(data, indices, indptr, num_cols: int
               ) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Validate + pack CSR to ELL ``(vals, cidx, m, k)`` host arrays.

    ``k`` is the max row population (≥ 1 so the nest never degenerates);
    pad slots hold ``(0.0, 0)`` — a zero-weighted gather of element 0.
    """
    data, indices, indptr, m = validate_csr(data, indices, indptr, num_cols)
    counts = np.diff(indptr)
    k = int(max(1, counts.max(initial=0)))
    vals = np.zeros((m, k), np.float32)
    cidx = np.zeros((m, k), np.int32)
    if data.size:
        rows = np.repeat(np.arange(m), counts)
        pos = np.arange(data.size) - np.repeat(indptr[:-1], counts)
        vals[rows, pos] = data
        cidx[rows, pos] = indices
    return vals, cidx, m, k


def csr_to_dense(data, indices, indptr, num_cols: int) -> np.ndarray:
    """Densify (validating) — the differential-testing oracle's input."""
    data, indices, indptr, m = validate_csr(data, indices, indptr, num_cols)
    dense = np.zeros((m, num_cols), np.float32)
    if data.size:
        counts = np.diff(indptr)
        rows = np.repeat(np.arange(m), counts)
        dense[rows, indices] = data
    return dense


# --------------------------------------------------------------------------
# SpMV: y[m] = A_csr[m, n] · x[n]
# --------------------------------------------------------------------------


def _prepare_spmv(vals, cidx, x, m=None, k=None):
    return ({"vals": vals, "cidx": cidx, "x": x}, (m, k), None)


def _nest_spmv(static):
    m, k = static
    return compiler.spmv_nest(m, k)


def _body_spmv(static):
    def body(vals_blk, cidx_blk, x_gathered):
        # cidx's block rides to the kernel anyway (it feeds the gather's
        # addresses); the body consumes only vals × gathered-x.
        del cidx_blk
        return jnp.sum(promote(vals_blk) * promote(x_gathered), axis=1)

    return body


_ssr_spmv = NestKernel("spmv", prepare=_prepare_spmv, nest=_nest_spmv,
                       body=_body_spmv)


def ssr_spmv(data, indices, indptr, x, *, interpret=None,
             schedule=None) -> jax.Array:
    """y = A·x for CSR ``A`` through the compiled indirection-stream path."""
    x = jnp.asarray(x, jnp.float32)
    vals, cidx, m, k = csr_to_ell(data, indices, indptr, int(x.shape[0]))
    return _ssr_spmv(jnp.asarray(vals), jnp.asarray(cidx), x, m=m, k=k,
                     interpret=interpret, schedule=schedule)


# The sparse-row generalisation of gemv: identical entry point, named for
# call sites that think in dense-kernel terms (cidx = iota recovers gemv).
ssr_sparse_gemv = ssr_spmv


def _prepare_spmv_base(vals, cidx, x):
    return ((vals, cidx, x.reshape(1, -1)), int(vals.shape[0]), None)


def _base_body_spmv(static):
    def body(v_ref, c_ref, x_ref, o_ref):
        # Explicit index handling, the scalar-core baseline the papers
        # count: load the index block, compute each address, gather one
        # element at a time (batched here as one take), then multiply.
        x = x_ref[...].reshape(-1)
        c = c_ref[...]
        g = jnp.take(x, c.reshape(-1), mode="clip").reshape(c.shape)
        o_ref[...] = jnp.sum(v_ref[...] * g, axis=1, keepdims=True)

    return body


_base_spmv = MonolithicKernel(
    "spmv", prepare=_prepare_spmv_base, body=_base_body_spmv,
    out_shape=lambda m, v, c, x: jax.ShapeDtypeStruct((m, 1), jnp.float32),
    finish=lambda out, _final: out[:, 0])


def baseline_spmv(data, indices, indptr, x, *, interpret=None) -> jax.Array:
    x = jnp.asarray(x, jnp.float32)
    vals, cidx, _m, _k = csr_to_ell(data, indices, indptr, int(x.shape[0]))
    return _base_spmv(jnp.asarray(vals), jnp.asarray(cidx), x,
                      interpret=interpret)


def ref_spmv(data, indices, indptr, x) -> jax.Array:
    """Densified ``jnp.dot`` oracle (also the ``ssrcfg``-off path)."""
    x = jnp.asarray(x, jnp.float32)
    dense = csr_to_dense(data, indices, indptr, int(x.shape[0]))
    return jnp.dot(jnp.asarray(dense), x)


# --------------------------------------------------------------------------
# SpMM: Y[m, c] = A_csr[m, n] · X[n, c]
# --------------------------------------------------------------------------


def _prepare_spmm(vals, cidx, x, m=None, k=None, pitch=None):
    c = int(x.shape[1])
    xp = jnp.pad(x, ((0, 0), (0, pitch - c)))
    return ({"vals": vals, "cidx": cidx, "X": xp}, (m, c, k, pitch), None)


def _nest_spmm(static):
    m, c, k, pitch = static
    return compiler.spmm_nest(m, c, k, pitch)


def _body_spmm(static):
    def body(vals_blk, cidx_blk, x_gathered):
        del cidx_blk
        # gathered block is (tile_c, tile_m, tile_k): the affine column
        # level prepends one dimension to the index block's (m, k) walk.
        return jnp.einsum("mk,cmk->mc", promote(vals_blk),
                          promote(x_gathered))

    return body


_ssr_spmm = NestKernel("spmm", prepare=_prepare_spmm, nest=_nest_spmm,
                       body=_body_spmm)


def ssr_spmm(data, indices, indptr, x, *, interpret=None,
             schedule=None) -> jax.Array:
    """Y = A·X for CSR ``A``, dense ``X[n, c]`` — the compiled gather path."""
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"spmm needs a dense (n, c) operand, got {x.shape}")
    vals, cidx, m, k = csr_to_ell(data, indices, indptr, int(x.shape[0]))
    pitch = -(-int(x.shape[1]) // _TABLE_PITCH) * _TABLE_PITCH
    return _ssr_spmm(jnp.asarray(vals), jnp.asarray(cidx), x,
                     m=m, k=k, pitch=pitch,
                     interpret=interpret, schedule=schedule)


def _prepare_spmm_base(vals, cidx, x):
    return ((vals, cidx, x),
            (int(vals.shape[0]), int(x.shape[1])), None)


def _base_body_spmm(static):
    def body(v_ref, c_ref, x_ref, o_ref):
        c = c_ref[...]
        g = jnp.take(x_ref[...], c.reshape(-1), axis=0, mode="clip")
        g = g.reshape(c.shape + (x_ref.shape[1],))
        o_ref[...] = jnp.einsum("mk,mkc->mc", v_ref[...], g)

    return body


_base_spmm = MonolithicKernel(
    "spmm", prepare=_prepare_spmm_base, body=_base_body_spmm,
    out_shape=lambda st, v, c, x: jax.ShapeDtypeStruct(st, jnp.float32))


def baseline_spmm(data, indices, indptr, x, *, interpret=None) -> jax.Array:
    x = jnp.asarray(x, jnp.float32)
    vals, cidx, _m, _k = csr_to_ell(data, indices, indptr, int(x.shape[0]))
    return _base_spmm(jnp.asarray(vals), jnp.asarray(cidx), x,
                      interpret=interpret)


def ref_spmm(data, indices, indptr, x) -> jax.Array:
    x = jnp.asarray(x, jnp.float32)
    dense = csr_to_dense(data, indices, indptr, int(x.shape[0]))
    return jnp.dot(jnp.asarray(dense), x)


# --------------------------------------------------------------------------
# Registry entries
# --------------------------------------------------------------------------


def random_csr(rng, m: int, n: int, density: float
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """A random CSR ``(data, indices, indptr)`` triple at ``density``."""
    mask = rng.random((m, n)) < density
    dense = np.where(mask, rng.standard_normal((m, n)), 0.0)
    indptr = np.zeros(m + 1, np.int64)
    cols, vals = [], []
    for i in range(m):
        nz = np.nonzero(dense[i])[0]
        cols.append(nz)
        vals.append(dense[i, nz])
        indptr[i + 1] = indptr[i] + nz.size
    indices = np.concatenate(cols) if cols else np.zeros(0, np.int64)
    data = np.concatenate(vals) if vals else np.zeros(0, np.float64)
    return (data.astype(np.float32), indices.astype(np.int64), indptr)


@register_kernel("spmv")
def _entry_spmv() -> KernelEntry:
    def example(rng, odd: bool = False):
        m, n, density = (37, 53, 0.1) if odd else (32, 32, 0.25)
        data, indices, indptr = random_csr(rng, m, n, density)
        x = rng.standard_normal(n).astype(np.float32)
        return ((data, indices, indptr, x), {})

    return KernelEntry(name="spmv", ssr=ssr_spmv, baseline=baseline_spmv,
                       ref=ref_spmv, example=example,
                       tol={"rtol": 1e-5, "atol": 1e-5},
                       problem="CSR 32×32 @ 25% density")


@register_kernel("spmm")
def _entry_spmm() -> KernelEntry:
    def example(rng, odd: bool = False):
        m, n, c, density = (29, 41, 17, 0.1) if odd else (32, 32, 16, 0.25)
        data, indices, indptr = random_csr(rng, m, n, density)
        x = rng.standard_normal((n, c)).astype(np.float32)
        return ((data, indices, indptr, x), {})

    return KernelEntry(name="spmm", ssr=ssr_spmm, baseline=baseline_spmm,
                       ref=ref_spmm, example=example,
                       tol={"rtol": 1e-5, "atol": 1e-5},
                       problem="CSR 32×32 · 32×16 @ 25% density")
