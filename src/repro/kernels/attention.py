"""Streaming flash attention — the SSR technique applied to the LM hot spot.

Attention *is* the paper's reduction (§4.1/Fig. 4) writ large, and since
the online-rescaled accumulator landed in ``lower_nest`` it is fully
*nest-lowered*: the module declares only
:func:`repro.core.compiler.attention_nest` — K/V read streams over the kv
contraction level, Q a repeat stream, and an output WRITE ref with
``acc_kind="online_softmax"`` — plus the score body.  The lowering owns
the flash recurrence (DESIGN.md §13):

* the m/l/acc online-softmax state lives in VMEM scratch across the kv
  walk, *rescaled* by ``exp(m − m')`` every step — the generalised
  accumulator register;
* the kv grid axis is ``arbitrary`` (sequential), the rest ``parallel``;
  the pipeline prefetches the next K/V tile during this tile's two
  matmuls — the data-mover run-ahead that gives the paper its 3×;
* causal/sliding-window masks are *static* index arithmetic in the body
  (iota against the grid offsets) — data-oblivious, as SSR requires.

Supports MHA/GQA (q heads grouped over kv heads via an outer vmap), causal
and sliding-window (h2o-danube) masking.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import compiler
from repro.core.lowering import Schedule

from .frontend import NestKernel, promote
from .registry import KernelEntry, register_kernel

_NEG_INF = -1e30


def _prepare(q, k, v, causal=False, window=None, scale=None,
             bq=128, bk=128):
    # bq/bk are retained for call-site compatibility with the old
    # hand-tiled launch; tiling now comes from the lowering schedule.
    sq, d = q.shape
    sk = k.shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    static = (sq, sk, d, bool(causal), window, float(scale), str(q.dtype))
    return {"Q": q, "K": k, "V": v}, static, None


def _nest(static):
    sq, sk, d = static[:3]
    return compiler.attention_nest(sq, sk, d)


def _body(static):
    sq, sk, d, causal, window, scale, _dt = static
    offs_rc = sk - sq  # query/key end alignment (decode-friendly)

    def body(k_blk, v_blk, q_blk, offs):
        # Raw scores for one (q-tile, kv-tile) step; the lowering's
        # online-softmax kernel owns the m/l/acc rescaling recurrence.
        # ``offs`` are the per-level global offsets (q, d, kv).
        s = jax.lax.dot_general(
            promote(q_blk), promote(k_blk), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        rows = offs[0] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
            + offs_rc
        cols = offs[2] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < sk                     # padded kv columns
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        return jnp.where(mask, s, _NEG_INF), v_blk

    return body


_ssr = NestKernel("attention", prepare=_prepare, nest=_nest, body=_body,
                  out_dtype=lambda static: static[6])


def ssr_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = False, window: int | None = None,
                        scale: float | None = None, bq: int = 128,
                        bk: int = 128, interpret=None,
                        schedule: Schedule | None = None) -> jax.Array:
    """Single-head streaming attention; q (Sq,D), k/v (Sk,D).

    Multi-head / batch: ``jax.vmap`` this (tested); GQA: vmap over kv heads
    with q reshaped (kv_heads, group, Sq, D).  ``schedule=None`` resolves
    a tuned schedule from the autotuner's cache; ``bq``/``bk`` are
    accepted for call-site compatibility (tiles come from the schedule).
    """
    return _ssr(q, k, v, causal=causal, window=window, scale=scale,
                bq=bq, bk=bk, interpret=interpret, schedule=schedule)


@register_kernel("attention")
def _entry() -> KernelEntry:
    from . import ref

    def _ref(q, k, v, **kw):
        return ref.attention_ref(q, k, v, **kw).astype(q.dtype)

    def example(rng, odd: bool = False):
        sq, sk = (128, 256) if odd else (256, 256)
        d = 64
        return ((jnp.asarray(rng.standard_normal((sq, d)), jnp.float32),
                 jnp.asarray(rng.standard_normal((sk, d)), jnp.float32),
                 jnp.asarray(rng.standard_normal((sk, d)), jnp.float32)),
                {"causal": True})

    return KernelEntry(name="attention", ssr=ssr_flash_attention, ref=_ref,
                       example=example, tol={"rtol": 2e-4, "atol": 2e-4},
                       problem="flash attention, S=256 D=64")
