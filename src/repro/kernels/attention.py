"""Streaming flash attention — the SSR technique applied to the LM hot spot.

Attention *is* the paper's reduction (§4.1/Fig. 4) writ large: for each
query tile the K/V operands stream past the compute unit once, with an
online-softmax accumulator playing the role of the ``%x`` register.  The
mapping (paper §2–3 concepts → this kernel):

* K and V are **read streams** over the kv axis (AGU loop 2), revisited per
  query tile (AGU loop 1) — block reuse = repeat register.
* The m/l/acc online-softmax state lives in VMEM scratch across the kv walk,
  exactly like the dot-product accumulator.
* The kv grid axis is ``arbitrary`` (sequential), the q axis ``parallel``;
  the pipeline prefetches K/V tile j+1 during tile j's two matmuls — the
  data mover run-ahead that gives the paper its 3× on reductions.
* Causal/sliding-window masks are *static* index arithmetic (iota against
  the grid position) — data-oblivious, as required for SSR-ability.

Supports MHA/GQA (q heads grouped over kv heads via an outer vmap), causal
and sliding-window (h2o-danube) masking.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction

from .frontend import Launch, StreamKernel, promote
from .registry import KernelEntry, register_kernel

_NEG_INF = -1e30


def _prepare(q, k, v, causal=False, window=None, scale=None,
             bq=128, bk=128):
    sq, d = q.shape
    sk = k.shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    bq = min(bq, sq)
    bk = min(bk, sk)
    while sq % bq:
        bq //= 2
    while sk % bk:
        bk //= 2
    static = (max(bq, 1), max(bk, 1), sq, sk, bool(causal), window,
              float(scale))
    return (q, k, v), static, None


def _body(static):
    bq, bk, sq, sk, causal, window, scale = static
    offs = sk - sq  # query/key end alignment (decode-friendly)

    def body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        qi = pl.program_id(0)
        kj = pl.program_id(1)

        @pl.when(kj == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = promote(q_ref[...])
        k = promote(k_ref[...])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
            + offs
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, promote(v_ref[...]), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

        @pl.when(kj == pl.num_programs(1) - 1)
        def _write():
            l = jnp.maximum(l_ref[...], 1e-30)   # fully-masked row guard
            o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)

    return body


def _launch(static, q, k, v):
    bq, bk, sq, sk, _causal, _window, _scale = static
    d = q.shape[1]
    return Launch(
        grid=(sq // bq, sk // bk),
        in_streams=(
            BlockStream((bq, d), lambda i, j: (i, 0), name="Q"),
            BlockStream((bk, d), lambda i, j: (j, 0), name="K"),  # reuse/i
            BlockStream((bk, d), lambda i, j: (j, 0), name="V"),
        ),
        out_streams=(BlockStream((bq, d), lambda i, j: (i, 0),
                                 Direction.WRITE, name="O"),),
        out_shapes=(jax.ShapeDtypeStruct((sq, d), q.dtype),),
        scratch_shapes=(
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ),
        dimension_semantics=("parallel", "arbitrary"),
    )


_ssr = StreamKernel(
    "attention", prepare=_prepare, launch=_launch, body=_body,
    lowering_waiver=(
        "online-softmax carried state: the m/l/acc scratch is *rescaled* "
        "(multiplied by alpha) every kv step, not just accumulated — "
        "beyond the init/add/drain contraction pattern lower_nest emits"))


def ssr_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = False, window: int | None = None,
                        scale: float | None = None, bq: int = 128,
                        bk: int = 128, interpret=None) -> jax.Array:
    """Single-head streaming attention; q (Sq,D), k/v (Sk,D).

    Multi-head / batch: ``jax.vmap`` this (tested); GQA: vmap over kv heads
    with q reshaped (kv_heads, group, Sq, D).
    """
    return _ssr(q, k, v, causal=causal, window=window, scale=scale,
                bq=bq, bk=bk, interpret=interpret)


@register_kernel("attention")
def _entry() -> KernelEntry:
    from . import ref

    def _ref(q, k, v, **kw):
        return ref.attention_ref(q, k, v, **kw).astype(q.dtype)

    def example(rng, odd: bool = False):
        sq, sk = (128, 256) if odd else (256, 256)
        d = 64
        return ((jnp.asarray(rng.standard_normal((sq, d)), jnp.float32),
                 jnp.asarray(rng.standard_normal((sk, d)), jnp.float32),
                 jnp.asarray(rng.standard_normal((sk, d)), jnp.float32)),
                {"causal": True})

    return KernelEntry(name="attention", ssr=ssr_flash_attention, ref=_ref,
                       example=example, tol={"rtol": 2e-4, "atol": 2e-4},
                       problem="flash attention, S=256 D=64")
