"""Radix-2 Stockham FFT — paper §4.2 (2048 points).

The FFT is the paper's showcase for *strided* streams: every stage reads the
working vector at a different power-of-two stride.  The Stockham (auto-sort)
formulation makes each stage's access pattern a pure affine reshape — all
``log2(n)`` stages unroll statically in the body, so the hot region contains
only butterflies (complex fmadds), with the per-stage twiddle tables riding a
constant stream.  Complex data travels as separate re/im planes (TPU has no
native complex tiles — hardware adaptation note in DESIGN.md).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, ssr_pallas


def twiddle_tables(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-stage twiddles, padded to (stages, n//2).

    Stage s operates on sub-transforms of length nc = n >> s and needs
    m = nc/2 factors  w_p = exp(-2πi p / nc).
    """
    stages = int(math.log2(n))
    wr = np.zeros((stages, n // 2), np.float32)
    wi = np.zeros((stages, n // 2), np.float32)
    for s in range(stages):
        nc = n >> s
        m = nc // 2
        p = np.arange(m)
        wr[s, :m] = np.cos(-2 * np.pi * p / nc)
        wi[s, :m] = np.sin(-2 * np.pi * p / nc)
    return wr, wi


def _body(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    n = xr_ref.shape[1]
    stages = int(math.log2(n))
    xr = xr_ref[...].reshape(n).astype(jnp.float32)
    xi = xi_ref[...].reshape(n).astype(jnp.float32)
    s_stride = 1
    nc = n
    for s in range(stages):                    # static unroll
        m = nc // 2
        Xr = xr.reshape(nc, s_stride)
        Xi = xi.reshape(nc, s_stride)
        ar, ai = Xr[:m], Xi[:m]
        br, bi = Xr[m:], Xi[m:]
        wr = wr_ref[s, :m].reshape(m, 1)
        wi = wi_ref[s, :m].reshape(m, 1)
        er, ei = ar + br, ai + bi              # even outputs
        dr, di = ar - br, ai - bi
        orr = dr * wr - di * wi                # odd outputs: (a−b)·w
        oii = dr * wi + di * wr
        xr = jnp.stack([er, orr], axis=1).reshape(nc * s_stride)
        xi = jnp.stack([ei, oii], axis=1).reshape(nc * s_stride)
        nc //= 2
        s_stride *= 2
    or_ref[...] = xr.reshape(1, n)
    oi_ref[...] = xi.reshape(1, n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch(xr, xi, wr, wi, interpret: bool = True):
    n = xr.shape[1]
    fn = ssr_pallas(
        _body,
        grid=(1,),
        in_streams=[
            BlockStream((1, n), lambda i: (0, 0), name="xr"),
            BlockStream((1, n), lambda i: (0, 0), name="xi"),
            BlockStream(wr.shape, lambda i: (0, 0), name="wr"),
            BlockStream(wi.shape, lambda i: (0, 0), name="wi"),
        ],
        out_streams=[
            BlockStream((1, n), lambda i: (0, 0), Direction.WRITE, name="yr"),
            BlockStream((1, n), lambda i: (0, 0), Direction.WRITE, name="yi"),
        ],
        out_shapes=[jax.ShapeDtypeStruct((1, n), jnp.float32),
                    jax.ShapeDtypeStruct((1, n), jnp.float32)],
        interpret=interpret,
    )
    return fn(xr, xi, wr, wi)


def ssr_fft(re: jax.Array, im: jax.Array, *,
            interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Forward DFT of a power-of-two length vector, split re/im."""
    n = re.shape[0]
    if n & (n - 1):
        raise ValueError("radix-2 FFT needs power-of-two length")
    wr, wi = twiddle_tables(n)
    yr, yi = _dispatch(re.reshape(1, n), im.reshape(1, n),
                       jnp.asarray(wr), jnp.asarray(wi), interpret)
    return yr.reshape(-1), yi.reshape(-1)
