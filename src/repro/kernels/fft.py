"""Radix-2 Stockham FFT — paper §4.2 (2048 points).

The FFT is the paper's showcase for *strided* streams: every stage reads the
working vector at a different power-of-two stride.  The Stockham (auto-sort)
formulation makes each stage's access pattern a pure affine reshape — all
``log2(n)`` stages unroll statically in the body, so the hot region contains
only butterflies (complex fmadds), with the per-stage twiddle tables riding a
constant stream.  Complex data travels as separate re/im planes (TPU has no
native complex tiles — hardware adaptation note in DESIGN.md).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BlockStream, Direction

from .frontend import Launch, StreamKernel, promote, require_power_of_two
from .registry import KernelEntry, register_kernel


def twiddle_tables(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-stage twiddles, padded to (stages, n//2).

    Stage s operates on sub-transforms of length nc = n >> s and needs
    m = nc/2 factors  w_p = exp(-2πi p / nc).
    """
    stages = int(math.log2(n))
    wr = np.zeros((stages, n // 2), np.float32)
    wi = np.zeros((stages, n // 2), np.float32)
    for s in range(stages):
        nc = n >> s
        m = nc // 2
        p = np.arange(m)
        wr[s, :m] = np.cos(-2 * np.pi * p / nc)
        wi[s, :m] = np.sin(-2 * np.pi * p / nc)
    return wr, wi


def _prepare(re, im):
    n = re.shape[0]
    require_power_of_two(n, "radix-2 FFT")
    wr, wi = twiddle_tables(n)
    return (re.reshape(1, n), im.reshape(1, n),
            jnp.asarray(wr), jnp.asarray(wi)), None, None


def _body(static):
    def body(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
        n = xr_ref.shape[1]
        stages = int(math.log2(n))
        xr = promote(xr_ref[...]).reshape(n)
        xi = promote(xi_ref[...]).reshape(n)
        s_stride = 1
        nc = n
        for s in range(stages):                # static unroll
            m = nc // 2
            Xr = xr.reshape(nc, s_stride)
            Xi = xi.reshape(nc, s_stride)
            ar, ai = Xr[:m], Xi[:m]
            br, bi = Xr[m:], Xi[m:]
            wr = wr_ref[s, :m].reshape(m, 1)
            wi = wi_ref[s, :m].reshape(m, 1)
            er, ei = ar + br, ai + bi          # even outputs
            dr, di = ar - br, ai - bi
            orr = dr * wr - di * wi            # odd outputs: (a−b)·w
            oii = dr * wi + di * wr
            xr = jnp.stack([er, orr], axis=1).reshape(nc * s_stride)
            xi = jnp.stack([ei, oii], axis=1).reshape(nc * s_stride)
            nc //= 2
            s_stride *= 2
        or_ref[...] = xr.reshape(1, n)
        oi_ref[...] = xi.reshape(1, n)

    return body


def _launch(static, xr, xi, wr, wi):
    n = xr.shape[1]
    return Launch(
        grid=(1,),
        in_streams=(
            BlockStream((1, n), lambda i: (0, 0), name="xr"),
            BlockStream((1, n), lambda i: (0, 0), name="xi"),
            BlockStream(wr.shape, lambda i: (0, 0), name="wr"),
            BlockStream(wi.shape, lambda i: (0, 0), name="wi"),
        ),
        out_streams=(
            BlockStream((1, n), lambda i: (0, 0), Direction.WRITE, name="yr"),
            BlockStream((1, n), lambda i: (0, 0), Direction.WRITE, name="yi"),
        ),
        out_shapes=(jax.ShapeDtypeStruct((1, n), jnp.float32),
                    jax.ShapeDtypeStruct((1, n), jnp.float32)),
    )


_ssr = StreamKernel(
    "fft", prepare=_prepare, launch=_launch, body=_body,
    finish=lambda out, _: (out[0].reshape(-1), out[1].reshape(-1)),
    lowering_waiver=(
        "per-stage power-of-two strided butterflies: every stage re-walks "
        "the working vector at a different stride — word-granular AGU "
        "territory, no whole-block dense layout across stages"))


def ssr_fft(re: jax.Array, im: jax.Array, *,
            interpret=None) -> tuple[jax.Array, jax.Array]:
    """Forward DFT of a power-of-two length vector, split re/im."""
    return _ssr(re, im, interpret=interpret)


@register_kernel("fft")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 256 if odd else 2048   # no odd sizes: radix-2 requires 2^k
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    return KernelEntry(name="fft", ssr=ssr_fft, ref=ref.fft_ref,
                       example=example, tol={"rtol": 1e-3, "atol": 5e-2},
                       problem="radix-2, n=2048")
