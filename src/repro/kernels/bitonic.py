"""Bitonic sort network — paper §4.2 (1024 values).

A sort network is data-oblivious: the compare-exchange pattern is fixed, so
every stage's partner access is an affine (power-of-two strided) stream —
the reason the paper can SSR-ify a *sort*.  All log²(n)/2-ish stages unroll
statically; each stage's partner pairing is a reshape to (n/2j, 2, j) and the
direction mask is computed from a static iota (no data-dependent addressing
anywhere, so the body is min/max ops only).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import BlockStream, Direction

from .frontend import Launch, StreamKernel, require_power_of_two
from .registry import KernelEntry, register_kernel


def _prepare(x):
    require_power_of_two(x.shape[0], "bitonic network")
    return (x.reshape(1, -1),), None, None


def _body(static):
    def body(x_ref, o_ref):
        n = x_ref.shape[1]
        x = x_ref[...].reshape(n)
        stages = int(math.log2(n))
        for ks in range(1, stages + 1):        # k = 2**ks
            k = 1 << ks
            for js in range(ks - 1, -1, -1):   # j = 2**js
                j = 1 << js
                X = x.reshape(n // (2 * j), 2, j)
                a = X[:, 0, :]
                b = X[:, 1, :]
                # ascending iff (i & k) == 0; i = q·2j + h·j + r and k ≥ 2j,
                # so the k-bit of i is carried entirely by q.
                q = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1), 0)
                asc = ((q * 2 * j) & k) == 0
                lo = jnp.minimum(a, b)
                hi = jnp.maximum(a, b)
                first = jnp.where(asc, lo, hi)
                second = jnp.where(asc, hi, lo)
                x = jnp.stack([first, second], axis=1).reshape(n)
        o_ref[...] = x.reshape(1, n)

    return body


def _launch(static, x2d):
    n = x2d.shape[1]
    return Launch(
        grid=(1,),
        in_streams=(BlockStream((1, n), lambda i: (0, 0), name="x"),),
        out_streams=(BlockStream((1, n), lambda i: (0, 0),
                                 Direction.WRITE, name="y"),),
        out_shapes=(jax.ShapeDtypeStruct((1, n), x2d.dtype),),
    )


_ssr = StreamKernel(
    "bitonic", prepare=_prepare, launch=_launch, body=_body,
    finish=lambda out, _: out.reshape(-1),
    lowering_waiver=(
        "compare-exchange network: each stage pairs elements at a "
        "different power-of-two distance — data-oblivious and affine per "
        "stage, but not one dense block walk over a single LoopNest"))


def ssr_sort(x: jax.Array, *, interpret=None) -> jax.Array:
    """Ascending sort of a power-of-two length vector."""
    return _ssr(x, interpret=interpret)


@register_kernel("bitonic")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 64 if odd else 1024    # no odd sizes: the network requires 2^k
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),), {})

    return KernelEntry(name="bitonic", ssr=ssr_sort, ref=ref.sort_ref,
                       example=example, tol={"rtol": 0.0, "atol": 0.0},
                       problem="sort network, n=1024")
