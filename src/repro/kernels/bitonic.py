"""Bitonic sort network — paper §4.2 (1024 values).

A sort network is data-oblivious: the compare-exchange pattern is fixed, so
every stage's partner access is an affine (power-of-two strided) stream —
the reason the paper can SSR-ify a *sort*.  All log²(n)/2-ish stages unroll
statically; each stage's partner pairing is a reshape to (n/2j, 2, j) and the
direction mask is computed from a static iota (no data-dependent addressing
anywhere, so the body is min/max ops only).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, ssr_pallas


def _body(x_ref, o_ref):
    n = x_ref.shape[1]
    x = x_ref[...].reshape(n)
    stages = int(math.log2(n))
    for ks in range(1, stages + 1):            # k = 2**ks
        k = 1 << ks
        for js in range(ks - 1, -1, -1):       # j = 2**js
            j = 1 << js
            X = x.reshape(n // (2 * j), 2, j)
            a = X[:, 0, :]
            b = X[:, 1, :]
            # ascending iff (i & k) == 0; i = q·2j + h·j + r and k ≥ 2j, so
            # the k-bit of i is carried entirely by q.
            q = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1), 0)
            asc = ((q * 2 * j) & k) == 0
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            first = jnp.where(asc, lo, hi)
            second = jnp.where(asc, hi, lo)
            x = jnp.stack([first, second], axis=1).reshape(n)
    o_ref[...] = x.reshape(1, n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch(x2d, interpret: bool = True):
    n = x2d.shape[1]
    fn = ssr_pallas(
        _body,
        grid=(1,),
        in_streams=[BlockStream((1, n), lambda i: (0, 0), name="x")],
        out_streams=[BlockStream((1, n), lambda i: (0, 0),
                                 Direction.WRITE, name="y")],
        out_shapes=[jax.ShapeDtypeStruct((1, n), x2d.dtype)],
        interpret=interpret,
    )
    return fn(x2d)


def ssr_sort(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Ascending sort of a power-of-two length vector."""
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError("bitonic network needs power-of-two length")
    return _dispatch(x.reshape(1, n), interpret).reshape(-1)
