"""Public kernel API with ``ssrcfg`` dispatch.

Every op picks the streamed Pallas kernel inside an ``ssr_region`` and the
plain-XLA path outside it — the software form of the paper's opt-in CSR
(§2.2.2): flipping the bit never changes semantics, only the execution
engine.  The XLA path is also what the multi-pod dry-run lowers (Pallas
interpret mode is CPU-only scaffolding; on a real TPU fleet the flag enables
the Mosaic kernels).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.region import ssr_enabled
from . import ref
from .attention import ssr_flash_attention
from .bitonic import ssr_sort
from .fft import ssr_fft
from .gemm import ssr_matmul
from .gemv import ssr_gemv
from .reduction import ssr_dot
from .relu import ssr_relu
from .scan import ssr_scan
from .stencil import ssr_stencil1d, ssr_stencil2d


def _use_ssr(override: Optional[bool]) -> bool:
    return ssr_enabled() if override is None else override


def dot(x, y, *, ssr: Optional[bool] = None):
    return ssr_dot(x, y) if _use_ssr(ssr) else ref.dot_ref(x, y)


def prefix_sum(x, *, ssr: Optional[bool] = None):
    return ssr_scan(x) if _use_ssr(ssr) else ref.scan_ref(x)


def relu(x, *, ssr: Optional[bool] = None):
    return ssr_relu(x) if _use_ssr(ssr) else ref.relu_ref(x)


def stencil1d(x, w, *, ssr: Optional[bool] = None):
    return ssr_stencil1d(x, w) if _use_ssr(ssr) else ref.stencil1d_ref(x, w)


def stencil2d(x, wx, wy, *, ssr: Optional[bool] = None):
    if _use_ssr(ssr):
        return ssr_stencil2d(x, wx, wy)
    return ref.stencil2d_ref(x, wx, wy)


def gemv(a, x, *, ssr: Optional[bool] = None):
    return ssr_gemv(a, x) if _use_ssr(ssr) else ref.gemv_ref(a, x)


def matmul(a, b, *, ssr: Optional[bool] = None, **kw):
    if _use_ssr(ssr):
        return ssr_matmul(a, b, **kw)
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def fft(re, im, *, ssr: Optional[bool] = None):
    return ssr_fft(re, im) if _use_ssr(ssr) else ref.fft_ref(re, im)


def sort(x, *, ssr: Optional[bool] = None):
    return ssr_sort(x) if _use_ssr(ssr) else ref.sort_ref(x)


def flash_attention(q, k, v, *, causal: bool = False,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    ssr: Optional[bool] = None):
    """Single-head attention; heads/batch via vmap (see models.attention)."""
    if _use_ssr(ssr):
        return ssr_flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale).astype(q.dtype)
