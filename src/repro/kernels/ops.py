"""Public kernel API with ``ssrcfg`` dispatch.

Every op picks the streamed Pallas kernel inside an ``ssr_region`` and the
plain-XLA path outside it — the software form of the paper's opt-in CSR
(§2.2.2): flipping the bit never changes semantics, only the execution
engine.  The XLA path is also what the multi-pod dry-run lowers (Pallas
interpret mode is CPU-only scaffolding; on a real TPU fleet the flag enables
the Mosaic kernels).

Each function is a thin typed façade over :func:`registry.dispatch`; the
registry owns the variant table, so adding a kernel means registering it
once, not editing an import list here.

Schedule tuning is transparent at this layer: the streamed variants the
registry routes to (``NestKernel``-backed kernels, and the schedule-aware
stencil) resolve their block schedule from the autotuner's persistent
cache (:mod:`repro.core.autotune`) on every build — run the tuner once
(``benchmarks/kernel_bench.py --autotune-only`` or
:func:`repro.core.autotune.autotune`) and these ops pick the committed
winners up with no call-site changes.
"""

from __future__ import annotations

from typing import Optional

from . import registry


def dot(x, y, *, ssr: Optional[bool] = None):
    return registry.dispatch("reduction", x, y, ssr=ssr)


def prefix_sum(x, *, ssr: Optional[bool] = None):
    return registry.dispatch("scan", x, ssr=ssr)


def relu(x, *, ssr: Optional[bool] = None):
    return registry.dispatch("relu", x, ssr=ssr)


def stencil1d(x, w, *, ssr: Optional[bool] = None):
    return registry.dispatch("stencil1d", x, w, ssr=ssr)


def stencil2d(x, wx, wy, *, ssr: Optional[bool] = None):
    return registry.dispatch("stencil2d", x, wx, wy, ssr=ssr)


def gemv(a, x, *, ssr: Optional[bool] = None):
    return registry.dispatch("gemv", a, x, ssr=ssr)


def matmul(a, b, *, ssr: Optional[bool] = None, **kw):
    return registry.dispatch("gemm", a, b, ssr=ssr, **kw)


def spmv(data, indices, indptr, x, *, ssr: Optional[bool] = None):
    """CSR sparse-matrix × dense-vector via the indirection-stream path."""
    return registry.dispatch("spmv", data, indices, indptr, x, ssr=ssr)


def spmm(data, indices, indptr, x, *, ssr: Optional[bool] = None):
    """CSR sparse-matrix × dense-matrix via the indirection-stream path."""
    return registry.dispatch("spmm", data, indices, indptr, x, ssr=ssr)


def sparse_gemv(data, indices, indptr, x, *, ssr: Optional[bool] = None):
    """The sparse-row generalisation of :func:`gemv` (alias of spmv)."""
    return registry.dispatch("spmv", data, indices, indptr, x, ssr=ssr)


def fft(re, im, *, ssr: Optional[bool] = None):
    return registry.dispatch("fft", re, im, ssr=ssr)


def sort(x, *, ssr: Optional[bool] = None):
    return registry.dispatch("bitonic", x, ssr=ssr)


# -- fused (stream-chained) ops ---------------------------------------------
# One kernel each: the producer's result reaches the consumer through a VMEM
# scratch block, never HBM.  ``ssr=False`` falls back to the jnp oracle.


def gemv_relu(a, x, *, ssr: Optional[bool] = None):
    return registry.dispatch("gemv_relu", a, x, ssr=ssr)


def stencil1d_relu(x, w, *, ssr: Optional[bool] = None):
    return registry.dispatch("stencil1d_relu", x, w, ssr=ssr)


def sum_sq_diff(x, y, *, ssr: Optional[bool] = None):
    return registry.dispatch("sum_sq_diff", x, y, ssr=ssr)


def axpy_dot(x, y, w, *, alpha: float = 1.0, ssr: Optional[bool] = None):
    return registry.dispatch("axpy_dot", x, y, w, alpha=alpha, ssr=ssr)


def flash_attention(q, k, v, *, causal: bool = False,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    ssr: Optional[bool] = None):
    """Single-head attention; heads/batch via vmap (see models.attention)."""
    return registry.dispatch("attention", q, k, v, causal=causal,
                             window=window, scale=scale, ssr=ssr)
