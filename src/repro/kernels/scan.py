"""Scan (all prefix sums) over a long vector — paper §4.2, 4096 values.

SSR structure: one read stream and one write stream walk the vector in
lockstep; the carry lives in a VMEM scratch register across grid steps (the
sequential dependence the paper handles with the accumulator register).  The
grid dimension is ``arbitrary`` (sequential) — blocks must retire in order,
but the *fetch* of block i+1 still overlaps the compute of block i.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction, ssr_pallas

_ROWS = 8
_LANES = 128
BLOCK_ELEMS = _ROWS * _LANES


def _ssr_body(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    flat = x_ref[...].astype(jnp.float32).reshape(-1)
    csum = jnp.cumsum(flat)
    o_ref[...] = (csum + carry_ref[0, 0]).reshape(_ROWS, _LANES)
    carry_ref[...] = (carry_ref[0, 0] + csum[-1]).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch(x2d: jax.Array, interpret: bool = True) -> jax.Array:
    grid = (x2d.shape[0] // _ROWS,)
    fn = ssr_pallas(
        _ssr_body,
        grid=grid,
        in_streams=[BlockStream((_ROWS, _LANES), lambda i: (i, 0), name="x")],
        out_streams=[BlockStream((_ROWS, _LANES), lambda i: (i, 0),
                                 Direction.WRITE, name="y")],
        out_shapes=[jax.ShapeDtypeStruct(x2d.shape, jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("arbitrary",),
    )
    return fn(x2d)


def ssr_scan(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Inclusive prefix sum; input padded to whole blocks, result trimmed."""
    n = x.shape[0]
    pad = (-n) % BLOCK_ELEMS
    if pad:
        x = jnp.pad(x, (0, pad))
    rows = (n + pad) // _LANES
    out = _dispatch(x.reshape(rows, _LANES), interpret)
    return out.reshape(-1)[:n]


def _baseline_body(x_ref, o_ref):
    # Monolithic: single grid step, in-body block walk with explicit loads.
    rows = x_ref.shape[0]
    nblk = rows // _ROWS

    def step(i, carry):
        x = x_ref[pl.dslice(i * _ROWS, _ROWS), :].astype(jnp.float32)
        csum = jnp.cumsum(x.reshape(-1))
        o_ref[pl.dslice(i * _ROWS, _ROWS), :] = (
            (csum + carry).reshape(_ROWS, _LANES))
        return carry + csum[-1]

    jax.lax.fori_loop(0, nblk, step, jnp.float32(0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch_base(x2d, interpret: bool = True):
    return pl.pallas_call(
        _baseline_body,
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=interpret,
    )(x2d)


def baseline_scan(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % BLOCK_ELEMS
    if pad:
        x = jnp.pad(x, (0, pad))
    rows = (n + pad) // _LANES
    return _dispatch_base(x.reshape(rows, _LANES), interpret).reshape(-1)[:n]
