"""Scan (all prefix sums) over a long vector — paper §4.2, 4096 values.

SSR structure: one read stream and one write stream walk the vector in
lockstep; the carry lives in a VMEM scratch register across grid steps (the
sequential dependence the paper handles with the accumulator register).  The
grid dimension is ``arbitrary`` (sequential) — blocks must retire in order,
but the *fetch* of block i+1 still overlaps the compute of block i.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction

from .frontend import (LANES, ROWS, Launch, MonolithicKernel, StreamKernel,
                       pad_vector, promote, trim_vector)
from .registry import KernelEntry, register_kernel


def _prepare(x):
    return (pad_vector(x),), None, x.shape[0]


def _ssr_body(static):
    def body(x_ref, o_ref, carry_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            carry_ref[...] = jnp.zeros_like(carry_ref)

        csum = jnp.cumsum(promote(x_ref[...]).reshape(-1))
        o_ref[...] = (csum + carry_ref[0, 0]).reshape(ROWS, LANES)
        carry_ref[...] = (carry_ref[0, 0] + csum[-1]).reshape(1, 1)

    return body


def _launch(static, x2d):
    return Launch(
        grid=(x2d.shape[0] // ROWS,),
        in_streams=(BlockStream((ROWS, LANES), lambda i: (i, 0), name="x"),),
        out_streams=(BlockStream((ROWS, LANES), lambda i: (i, 0),
                                 Direction.WRITE, name="y"),),
        out_shapes=(jax.ShapeDtypeStruct(x2d.shape, jnp.float32),),
        scratch_shapes=(pltpu.VMEM((1, 1), jnp.float32),),
        dimension_semantics=("arbitrary",),
    )


_ssr = StreamKernel(
    "scan", prepare=_prepare, launch=_launch, body=_ssr_body,
    finish=trim_vector,
    lowering_waiver=(
        "loop-carried dependence: block i+1's prefix needs block i's total "
        "— a sequenced VMEM carry register, not an affine stream walk a "
        "LoopNest can express"))


def _baseline_body(static):
    def body(x_ref, o_ref):
        # Monolithic: single grid step, in-body block walk, explicit loads.
        nblk = x_ref.shape[0] // ROWS

        def step(i, carry):
            x = promote(x_ref[pl.dslice(i * ROWS, ROWS), :])
            csum = jnp.cumsum(x.reshape(-1))
            o_ref[pl.dslice(i * ROWS, ROWS), :] = (
                (csum + carry).reshape(ROWS, LANES))
            return carry + csum[-1]

        jax.lax.fori_loop(0, nblk, step, jnp.float32(0))

    return body


_base = MonolithicKernel(
    "scan", prepare=_prepare, body=_baseline_body,
    out_shape=lambda static, x2d: jax.ShapeDtypeStruct(x2d.shape,
                                                       jnp.float32),
    finish=trim_vector)


def ssr_scan(x: jax.Array, *, interpret=None) -> jax.Array:
    """Inclusive prefix sum; input padded to whole blocks, result trimmed."""
    return _ssr(x, interpret=interpret)


def baseline_scan(x: jax.Array, *, interpret=None) -> jax.Array:
    return _base(x, interpret=interpret)


@register_kernel("scan")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 3000 if odd else 4096
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),), {})

    return KernelEntry(name="scan", ssr=ssr_scan, baseline=baseline_scan,
                       ref=ref.scan_ref, example=example,
                       tol={"rtol": 1e-3, "atol": 1e-3},
                       problem="prefix sums, n=4096")
