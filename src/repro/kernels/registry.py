"""Kernel registry: one uniform handle per kernel in the suite.

Benchmarks, equivalence tests, and the ``ssrcfg`` dispatch layer used to
hand-maintain parallel import lists of the kernel modules; adding a kernel
meant editing four files.  Now a module self-registers at import time:

    @register_kernel("reduction")
    def _entry():
        return KernelEntry(name="reduction", ssr=ssr_dot,
                           baseline=baseline_dot, ref=ref.dot_ref,
                           example=_example)

and every consumer iterates :func:`entries`:

* ``benchmarks/kernel_bench.py`` times each entry's ``ref`` path and smoke-
  runs its ``ssr`` path from the same ``example`` factory;
* ``tests/test_registry.py`` asserts ``ssr == baseline == ref`` per entry on
  non-multiple-of-block sizes;
* ``repro.kernels.ops`` routes its public functions through
  :func:`dispatch`, which consults ``region.ssr_enabled()`` — the software
  ``ssrcfg`` CSR — to pick the streamed or plain-XLA variant.

Entries are lazy (factories resolved on first access) so registration adds
zero import cost and no cycles.
"""

from __future__ import annotations

import dataclasses
import importlib
import os
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import resilience
from repro.core.region import ssr_enabled

# Every module under repro.kernels that registers at least one kernel.  This
# is the single place the suite is enumerated; consumers iterate the
# registry, never this tuple.
_KERNEL_MODULES = ("reduction", "scan", "relu", "stencil", "gemv", "gemm",
                   "fft", "bitonic", "attention", "chained", "dag", "sparse")


@dataclasses.dataclass(frozen=True, eq=False)
class KernelEntry:
    """One kernel's public variants.

    ``ssr``      — streamed Pallas kernel (operands delivered by BlockStreams)
    ``baseline`` — monolithic Pallas kernel with explicit in-body loads
                   (``None`` where the paper has no meaningful baseline)
    ``ref``      — pure-jnp oracle, also the ``ssrcfg``-off execution path
    ``cluster``  — multi-core variant (paper §5.3–5.5): same positional
                   args as ``ssr`` plus a ``cores=C`` kwarg, sharded over a
                   ``cores`` device mesh (``None`` where the iteration
                   space has no clean outer split).  Deliberately *not* in
                   :meth:`variants`: it needs a multi-device mesh, which
                   the single-device equivalence suite does not have —
                   ``benchmarks/cluster_bench.py`` and
                   ``tests/test_cluster.py`` enumerate it instead.
    ``example``  — ``example(rng, odd=False) -> (args, kwargs)`` sample-input
                   factory; ``odd=True`` yields non-multiple-of-block sizes
    ``tol``      — allclose tolerances for ssr/baseline-vs-ref comparisons
    ``problem``  — human-readable §4.2 problem description
    """

    name: str
    ssr: Callable
    ref: Callable
    baseline: Optional[Callable] = None
    cluster: Optional[Callable] = None
    example: Optional[Callable] = None
    tol: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"rtol": 1e-3, "atol": 1e-3})
    problem: str = ""

    def variants(self) -> Dict[str, Callable]:
        out = {"ssr": self.ssr, "ref": self.ref}
        if self.baseline is not None:
            out["baseline"] = self.baseline
        return out

    def cluster_variants(self) -> Dict[str, Callable]:
        """The multi-core variants, keyed like :meth:`variants`."""
        return {"cluster": self.cluster} if self.cluster is not None else {}


_FACTORIES: Dict[str, Callable[[], KernelEntry]] = {}
_RESOLVED: Dict[str, KernelEntry] = {}


def register_kernel(name: str):
    """Class/function decorator registering a lazy :class:`KernelEntry`."""

    def deco(factory: Callable[[], KernelEntry]):
        if name in _FACTORIES:
            raise ValueError(f"kernel {name!r} registered twice")
        _FACTORIES[name] = factory
        return factory

    return deco


def _ensure_loaded() -> None:
    for mod in _KERNEL_MODULES:
        importlib.import_module(f"repro.kernels.{mod}")


def names() -> List[str]:
    _ensure_loaded()
    return sorted(_FACTORIES)


def get(name: str) -> KernelEntry:
    _ensure_loaded()
    if name not in _RESOLVED:
        if name not in _FACTORIES:
            raise KeyError(
                f"no kernel {name!r}; registered: {sorted(_FACTORIES)}")
        entry = _FACTORIES[name]()
        if entry.name != name:
            raise ValueError(
                f"entry name {entry.name!r} != registered name {name!r}")
        _RESOLVED[name] = entry
    return _RESOLVED[name]


def entries() -> List[KernelEntry]:
    return [get(n) for n in names()]


def _baseline_fallback_enabled() -> bool:
    return os.environ.get("REPRO_BASELINE_FALLBACK", "") not in ("", "0")


def dispatch(name: str, *args, ssr: Optional[bool] = None,
             baseline_fallback: Optional[bool] = None, **kwargs):
    """Run a kernel through the ``ssrcfg`` gate (paper §2.2.2).

    ``ssr=None`` consults :func:`region.ssr_enabled`; semantics are identical
    either way — only the execution engine changes.

    ``baseline_fallback`` is the last rung of the degradation ladder
    (tuned → default schedule → baseline): when the streamed variant fails
    with a *typed* dispatch error (injected fault, cache I/O,
    ``LoweringError``, compile failure) even after the lowering layer's own
    schedule degradation, re-run the call through the plain-XLA ``ref``
    variant — the paper's ``ssrcfg``-off path, always available because SSR
    is non-invasive.  Opt-in (``baseline_fallback=True`` or env
    ``REPRO_BASELINE_FALLBACK=1``) because it can mask a broken streamed
    engine in exchange for availability; genuine numerics/user errors
    (``TypeError``, shape ``ValueError``…) always propagate.
    """
    entry = get(name)
    use = ssr_enabled() if ssr is None else ssr
    fn = entry.ssr if use else entry.ref
    if baseline_fallback is None:
        baseline_fallback = _baseline_fallback_enabled()
    if not (use and baseline_fallback):
        return fn(*args, **kwargs)
    try:
        return fn(*args, **kwargs)
    except resilience.fallback_error_types() as e:
        resilience.record_fallback(
            seam=resilience.classify(e), site=f"registry:{name}", error=e,
            from_schedule="ssr", to_schedule="baseline")
        return entry.ref(*args, **kwargs)
