"""Fused producer→consumer kernels — stream chaining over the registry.

Every unfused composition in the suite pays the same tax: the producer
kernel stores its full result to HBM and the consumer streams it straight
back in.  Chaining (the natural next step after SSR — see the chaining ISA
extension in PAPERS.md) fuses the pair into ONE Pallas kernel whose
intermediate lives in a VMEM scratch block, eliminating one store and one
load per element.  Two fusion mechanisms are exercised:

* **nest reuse** (:class:`~repro.kernels.frontend.NestKernel`) —
  ``stencil1d+relu`` shares the producer's loop nest and applies the
  consumer inside the block body before it leaves VMEM (a map nest, so
  per-block epilogues are exact); ``gemv+relu`` shares the gemv nest and
  applies relu in ``finish`` (the contraction's k-tile partials cannot be
  relu'd mid-accumulation), which XLA fuses onto the drained output;
* **nest-level chaining** (:func:`repro.core.ssr_chain_call`) —
  ``sum_sq_diff`` (reduction-of-map) and ``axpy_dot`` go through the full
  compiler path: ``chain()`` unifies the producer's WRITE ref with the
  consumer's READ ref, ``lower_chain()`` emits the single fused grid, and
  the reduce epilogue uses the vectorised (rows, lanes) accumulator.

Each registry entry exposes ``ssr`` = the fused kernel, ``baseline`` = the
honest unfused two-kernel composition (same streamed engine, intermediate
through HBM), and ``ref`` = the jnp oracle, so the equivalence suite and
``kernel_bench`` compare fused-vs-unfused with zero extra wiring.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (Direction, LoopNest, MemRef, compiler, ssr_call,
                        ssr_chain_call)
from repro.core.lowering import DEFAULT_POLICY, DEFAULT_SCHEDULE

from .frontend import BLOCK_ELEMS, NestKernel
from .gemv import _body as _gemv_body
from .gemv import _nest as _gemv_nest
from .gemv import _prepare as _gemv_prepare
from .gemv import ssr_gemv
from .registry import KernelEntry, register_kernel
from .relu import relu_block, ssr_relu
from .stencil import _body_1d as _stencil_body
from .stencil import _nest_1d as _stencil_nest
from .stencil import _prepare_1d as _stencil_prepare
from .stencil import ssr_stencil1d


def _padded_blocks(n: int) -> Tuple[int, int]:
    """Padded 2-D (rows, lanes) layout of an n-element streamed vector."""
    steps = -(-n // BLOCK_ELEMS)
    return (steps * DEFAULT_POLICY.rows, DEFAULT_POLICY.lanes)


# --------------------------------------------------------------------------
# gemv + relu (nest-reuse fusion)
# --------------------------------------------------------------------------

_gemv_relu = NestKernel(
    "gemv_relu",
    prepare=_gemv_prepare,
    nest=_gemv_nest,
    body=_gemv_body,
    # relu rides finish, NOT the body: the body's return is a per-k-tile
    # partial of the contraction — relu'ing it mid-accumulation would be
    # wrong.  XLA fuses the epilogue onto the drained (m,) output, so the
    # unfused composition's padded HBM intermediate still disappears.
    finish=lambda out, _: jnp.maximum(out, 0.0))


def fused_gemv_relu(a: jax.Array, x: jax.Array, *, interpret=None):
    """relu(A·x) as one kernel: the row-panel product never leaves VMEM."""
    return _gemv_relu(a, x, interpret=interpret)


def unfused_gemv_relu(a: jax.Array, x: jax.Array, *, interpret=None):
    """The two-kernel composition: A·x round-trips through HBM.

    Pinned to the default schedule (like every fused/unfused pair): the
    HLO fusion audit compares the two programs buffer-for-buffer, so both
    sides must run identical block geometry — fusion is the only variable.
    """
    return ssr_relu(ssr_gemv(a, x, interpret=interpret),
                    interpret=interpret, schedule=DEFAULT_SCHEDULE)


# --------------------------------------------------------------------------
# stencil1d + relu (nest-reuse fusion)
# --------------------------------------------------------------------------


def _stencil_relu_body(static):
    producer = _stencil_body(static)

    def body(x_wide, w_blk):
        # map nest (no contraction): the consumer applies per block, in
        # VMEM, before the write stream drains it — exact fusion.
        return relu_block(producer(x_wide, w_blk))

    return body


_stencil_relu = NestKernel(
    "stencil1d_relu",
    prepare=_stencil_prepare,
    nest=_stencil_nest,
    body=_stencil_relu_body)


def fused_stencil1d_relu(x: jax.Array, w: jax.Array, *, interpret=None):
    """relu(stencil(x)) as one kernel."""
    return _stencil_relu(x, w, interpret=interpret)


def unfused_stencil1d_relu(x: jax.Array, w: jax.Array, *, interpret=None):
    # Pin the default block width: the fused kernel borrows the stencil's
    # default Launch geometry, and the HLO fusion audit compares the two
    # programs buffer-for-buffer — an autotuned width on the unfused side
    # would change the intermediate's block shape, not its fusion.
    return ssr_relu(ssr_stencil1d(x, w, interpret=interpret,
                                  schedule=DEFAULT_SCHEDULE),
                    interpret=interpret)


# --------------------------------------------------------------------------
# sum_sq_diff: reduction-of-map through the full chain() compiler path
# --------------------------------------------------------------------------


def _chain_nests(n: int, consumer_reads_w: bool) -> Tuple[LoopNest, LoopNest]:
    """Producer writes the dense intermediate T; consumer reads it back."""
    producer = LoopNest(
        bounds=(n,),
        refs=(MemRef("X", Direction.READ, (1,)),
              MemRef("Y", Direction.READ, (1,)),
              MemRef("T", Direction.WRITE, (1,))),
        compute_per_level=(2,))
    consumer_refs = [MemRef("T", Direction.READ, (1,))]
    if consumer_reads_w:
        consumer_refs.append(MemRef("W", Direction.READ, (1,)))
    consumer = LoopNest(bounds=(n,), refs=tuple(consumer_refs),
                        compute_per_level=(1,))
    return producer, consumer


def _map_nest(n: int, names: Tuple[str, ...],
              compute: int) -> LoopNest:
    return compiler.elementwise_nest(n, names, compute)


def _sq_diff_block(a, b):
    d = a - b
    return d * d


def _identity_block(t):
    return t


def fused_sum_sq_diff(x: jax.Array, y: jax.Array, *, interpret=None,
                      schedule=None):
    """Σ (x − y)² as one fused map→reduce kernel (vector accumulator).

    ``schedule=None`` pins the default geometry (the fused-vs-unfused
    audit's like-for-like requirement); pass an explicit schedule to tune.
    """
    n = x.shape[0]
    return ssr_chain_call(_chain_nests(n, consumer_reads_w=False),
                          (_sq_diff_block, _identity_block),
                          {"X": x, "Y": y}, mode="reduce",
                          schedule=schedule or DEFAULT_SCHEDULE,
                          interpret=interpret)


def unfused_sum_sq_diff(x: jax.Array, y: jax.Array, *, interpret=None):
    """Two streamed kernels: (x−y)² materialised to HBM, then reduced."""
    n = x.shape[0]
    t = ssr_call(_map_nest(n, ("X", "Y"), 2), _sq_diff_block,
                 {"X": x, "Y": y}, mode="map",
                 schedule=DEFAULT_SCHEDULE, interpret=interpret)
    return ssr_call(_map_nest(n, ("T",), 1), _identity_block, {"T": t},
                    mode="reduce", schedule=DEFAULT_SCHEDULE,
                    interpret=interpret)


def cluster_sum_sq_diff(x: jax.Array, y: jax.Array, *, cores: int,
                        interpret=None):
    """Σ (x − y)² on a C-core cluster: chaining × clustering composed.

    Each core runs the whole fused map→reduce chain on its tile — the
    (x−y)² intermediate stays in that core's VMEM scratch — and only the
    final partial crosses cores, via one ``psum`` (§5.3's shared-TCDM
    combine).  Zero padding is neutral: (0−0)² = 0.
    """
    from repro.parallel.cluster import cluster_chain_call, pad_to_cores

    (x, y), n_pad = pad_to_cores((x, y), cores)
    # schedule pinned to match the fused single-core contract (the
    # cores=1 degenerate must stay bit-identical to fused_sum_sq_diff)
    return cluster_chain_call(_chain_nests(n_pad, consumer_reads_w=False),
                              (_sq_diff_block, _identity_block),
                              {"X": x, "Y": y}, mode="reduce", cores=cores,
                              schedule=DEFAULT_SCHEDULE,
                              interpret=interpret)


# --------------------------------------------------------------------------
# axpy → dot: (α·x + y) · w through the chain() compiler path
# --------------------------------------------------------------------------


def _axpy_block(alpha: float) -> Callable:
    # Fresh lambda per call, but same code object + hashable closure: the
    # kernel cache keys on (code, closure), so this still hits.
    return lambda a, b: alpha * a + b


def _dot_block(t, w):
    return t * w


def fused_axpy_dot(x: jax.Array, y: jax.Array, w: jax.Array, *,
                   alpha: float = 1.0, interpret=None, schedule=None):
    """(α·x + y)·w fused: the axpy result never touches HBM.

    ``schedule=None`` pins the default geometry (see fused_sum_sq_diff).
    """
    n = x.shape[0]
    return ssr_chain_call(_chain_nests(n, consumer_reads_w=True),
                          (_axpy_block(alpha), _dot_block),
                          {"X": x, "Y": y, "W": w}, mode="reduce",
                          schedule=schedule or DEFAULT_SCHEDULE,
                          interpret=interpret)


def unfused_axpy_dot(x: jax.Array, y: jax.Array, w: jax.Array, *,
                     alpha: float = 1.0, interpret=None):
    n = x.shape[0]
    t = ssr_call(_map_nest(n, ("X", "Y"), 2), _axpy_block(alpha),
                 {"X": x, "Y": y}, mode="map",
                 schedule=DEFAULT_SCHEDULE, interpret=interpret)
    return ssr_call(_map_nest(n, ("T", "W"), 1), _dot_block,
                    {"T": t, "W": w}, mode="reduce",
                    schedule=DEFAULT_SCHEDULE, interpret=interpret)


def cluster_axpy_dot(x: jax.Array, y: jax.Array, w: jax.Array, *,
                     alpha: float = 1.0, cores: int = 1, interpret=None):
    """(α·x + y)·w on a C-core cluster, fused chain per core.

    Same composition as :func:`cluster_sum_sq_diff`: the axpy intermediate
    never leaves its core's VMEM, one ``psum`` finishes the dot.  Zero
    padding is neutral: (α·0 + 0)·0 = 0.
    """
    from repro.parallel.cluster import cluster_chain_call, pad_to_cores

    (x, y, w), n_pad = pad_to_cores((x, y, w), cores)
    return cluster_chain_call(_chain_nests(n_pad, consumer_reads_w=True),
                              (_axpy_block(alpha), _dot_block),
                              {"X": x, "Y": y, "W": w}, mode="reduce",
                              cores=cores, schedule=DEFAULT_SCHEDULE,
                              interpret=interpret)


# --------------------------------------------------------------------------
# Fused-case table: bench + HLO-elimination checks iterate this.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedCase:
    """One fused variant plus everything needed to audit the fusion.

    ``inter_type(*args)`` returns the (dtype, dims) of the padded 2-D
    buffer the *unfused* composition materialises for the intermediate —
    the buffer whose disappearance ``hlo_analysis`` asserts.  ``cluster``
    (optional) is the multi-core variant, forwarded to the registry entry.
    """

    name: str
    fused: Callable
    unfused: Callable
    ref: Callable
    example: Callable
    inter_type: Callable[..., Tuple[str, Tuple[int, ...]]]
    tol: Dict[str, float]
    cluster: Optional[Callable] = None


def _vector_inter(x, *rest, **kw) -> Tuple[str, Tuple[int, ...]]:
    return ("f32", _padded_blocks(x.shape[0]))


def _gemv_inter(a, x, **kw) -> Tuple[str, Tuple[int, ...]]:
    # the unfused relu stage pads the trimmed gemv result to whole blocks
    return ("f32", _padded_blocks(a.shape[0]))


def _stencil_inter(x, w, **kw) -> Tuple[str, Tuple[int, ...]]:
    return ("f32", _padded_blocks(x.shape[0] - (w.shape[0] - 1)))


def _mk_examples():
    def ex_gemv(rng, odd: bool = False):
        m, n = (60, 64) if odd else (64, 64)
        return ((jnp.asarray(rng.standard_normal((m, n)), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    def ex_stencil(rng, odd: bool = False):
        from .stencil import TAPS
        n = 500 if odd else 1024
        return ((jnp.asarray(rng.standard_normal(n + TAPS - 1), jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)),
                {})

    def ex_ssd(rng, odd: bool = False):
        n = 5000 if odd else 4096
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    def ex_axpy(rng, odd: bool = False):
        n = 3000 if odd else 4096
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)),
                {"alpha": 0.5})

    return ex_gemv, ex_stencil, ex_ssd, ex_axpy


def fused_cases() -> Tuple[FusedCase, ...]:
    from . import ref

    ex_gemv, ex_stencil, ex_ssd, ex_axpy = _mk_examples()
    loose = {"rtol": 1e-3, "atol": 1e-3}
    reduce_tol = {"rtol": 1e-2, "atol": 1e-2}
    return (
        FusedCase("gemv_relu", fused_gemv_relu, unfused_gemv_relu,
                  ref.gemv_relu_ref, ex_gemv, _gemv_inter, loose),
        FusedCase("stencil1d_relu", fused_stencil1d_relu,
                  unfused_stencil1d_relu, ref.stencil1d_relu_ref,
                  ex_stencil, _stencil_inter, loose),
        FusedCase("sum_sq_diff", fused_sum_sq_diff, unfused_sum_sq_diff,
                  ref.sum_sq_diff_ref, ex_ssd, _vector_inter, reduce_tol,
                  cluster=cluster_sum_sq_diff),
        FusedCase("axpy_dot", fused_axpy_dot, unfused_axpy_dot,
                  ref.axpy_dot_ref, ex_axpy, _vector_inter, reduce_tol,
                  cluster=cluster_axpy_dot),
    )


def _register(case: FusedCase) -> None:
    @register_kernel(case.name)
    def _entry() -> KernelEntry:
        return KernelEntry(name=case.name, ssr=case.fused,
                           baseline=case.unfused, ref=case.ref,
                           cluster=case.cluster,
                           example=case.example, tol=dict(case.tol),
                           problem=f"fused chain: {case.name}")


for _case in fused_cases():
    _register(_case)
