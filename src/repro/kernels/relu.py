"""ReLU over a vector — paper §4.2 (max(0, x) over 1024 values).

The simplest possible stream kernel: declared as a 1-D
:func:`~repro.core.compiler.elementwise_nest` and compiled through the
§3.2 pipeline — one read stream in, the map-mode dense write stream out,
pure elementwise body.  Generalised to any elementwise unary, since the
SSR structure is identical (§4.2 uses ReLU as the representative).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compiler

from .frontend import (ROWS, MonolithicKernel, NestKernel, pad_vector,
                       trim_vector)
from .registry import KernelEntry, register_kernel


def relu_block(x):
    """Pure block→block ReLU — shared with the fused (chained) variants."""
    return jnp.maximum(x, jnp.zeros((), x.dtype))


_relu = relu_block  # internal alias used by the prepare default


_ssr = NestKernel(
    "relu",
    prepare=lambda x, fn=_relu: ({"X": x}, (x.shape[0], fn, x.dtype), None),
    nest=lambda static: compiler.elementwise_nest(static[0]),
    body=lambda static: static[1],
    mode="map",
    # dtype-preserving: stream engine and baseline must agree bit-exactly,
    # including for integers above 2**24 that f32 cannot represent
    out_dtype=lambda static: static[2])


def _prepare_base(x, fn=_relu):
    return (pad_vector(x),), fn, x.shape[0]


def _baseline_body(fn):
    def body(x_ref, o_ref):
        nblk = x_ref.shape[0] // ROWS

        def step(i, _):
            blk = x_ref[pl.dslice(i * ROWS, ROWS), :]
            o_ref[pl.dslice(i * ROWS, ROWS), :] = fn(blk)
            return 0

        jax.lax.fori_loop(0, nblk, step, 0)

    return body


_base = MonolithicKernel(
    "relu", prepare=_prepare_base, body=_baseline_body,
    out_shape=lambda fn, x2d: jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
    finish=trim_vector)


def ssr_elementwise(x: jax.Array, fn: Callable, *, interpret=None,
                    schedule=None):
    """Streamed elementwise unary: one read stream, one write stream."""
    return _ssr(x, fn, interpret=interpret, schedule=schedule)


def ssr_relu(x: jax.Array, *, interpret=None, schedule=None) -> jax.Array:
    return _ssr(x, interpret=interpret, schedule=schedule)


def baseline_relu(x: jax.Array, *, interpret=None) -> jax.Array:
    return _base(x, interpret=interpret)


def cluster_relu(x: jax.Array, *, cores: int, interpret=None) -> jax.Array:
    """ReLU on a C-core cluster (paper §5.3): a pure map split C ways.

    Each core streams its tile through the §3.2 compiler path; output
    tiles concatenate along the split — *no* collective is emitted (the
    HLO locality audit asserts this), because an elementwise map shares
    nothing between cores.
    """
    from repro.parallel.cluster import cluster_call, pad_to_cores

    n = x.shape[0]
    (x,), n_pad = pad_to_cores((x,), cores)
    out = cluster_call(compiler.elementwise_nest(n_pad), relu_block,
                       {"X": x}, mode="map", cores=cores,
                       interpret=interpret)
    return out[:n]


@register_kernel("relu")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 1025 if odd else 1024
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),), {})

    return KernelEntry(name="relu", ssr=ssr_relu, baseline=baseline_relu,
                       ref=ref.relu_ref, cluster=cluster_relu,
                       example=example,
                       tol={"rtol": 0.0, "atol": 0.0},
                       problem="max(0,x), n=1024")
