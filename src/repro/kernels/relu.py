"""ReLU over a vector — paper §4.2 (max(0, x) over 1024 values).

The simplest possible stream kernel: one read stream in, one write stream
out, pure elementwise body.  Generalised to any elementwise unary, since the
SSR structure is identical (§4.2 uses ReLU as the representative).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, ssr_pallas

_ROWS = 8
_LANES = 128
BLOCK_ELEMS = _ROWS * _LANES


def _make_body(fn: Callable[[jax.Array], jax.Array]):
    def body(x_ref, o_ref):
        o_ref[...] = fn(x_ref[...])
    return body


@functools.partial(jax.jit, static_argnames=("fn", "interpret"))
def _dispatch(x2d, fn, interpret: bool = True):
    grid = (x2d.shape[0] // _ROWS,)
    call = ssr_pallas(
        _make_body(fn),
        grid=grid,
        in_streams=[BlockStream((_ROWS, _LANES), lambda i: (i, 0), name="x")],
        out_streams=[BlockStream((_ROWS, _LANES), lambda i: (i, 0),
                                 Direction.WRITE, name="y")],
        out_shapes=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)],
        interpret=interpret,
        dimension_semantics=("parallel",),
    )
    return call(x2d)


def _relu(x):
    return jnp.maximum(x, jnp.zeros((), x.dtype))


def ssr_elementwise(x: jax.Array, fn: Callable, *,
                    interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % BLOCK_ELEMS
    if pad:
        x = jnp.pad(x, (0, pad))
    rows = (n + pad) // _LANES
    return _dispatch(x.reshape(rows, _LANES), fn, interpret).reshape(-1)[:n]


def ssr_relu(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    return ssr_elementwise(x, _relu, interpret=interpret)


def _baseline_body(x_ref, o_ref):
    rows = x_ref.shape[0]
    nblk = rows // _ROWS

    def step(i, _):
        blk = x_ref[pl.dslice(i * _ROWS, _ROWS), :]
        o_ref[pl.dslice(i * _ROWS, _ROWS), :] = _relu(blk)
        return 0

    jax.lax.fori_loop(0, nblk, step, 0)


def baseline_relu(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % BLOCK_ELEMS
    if pad:
        x = jnp.pad(x, (0, pad))
    rows = (n + pad) // _LANES
    out = pl.pallas_call(
        _baseline_body,
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), x.dtype),
        interpret=interpret,
    )(x.reshape(rows, _LANES))
    return out.reshape(-1)[:n]
