"""Pallas TPU kernels: the paper's §4.2 suite + production matmul/attention.

Each kernel module pairs a streamed (SSR) variant with a baseline variant
and is validated against the pure-jnp oracle in ``ref.py`` (interpret mode
on CPU; Mosaic on real TPUs).
"""

from . import ops, ref  # noqa: F401
from .attention import ssr_flash_attention  # noqa: F401
from .bitonic import ssr_sort  # noqa: F401
from .fft import ssr_fft  # noqa: F401
from .gemm import baseline_matmul, ssr_matmul  # noqa: F401
from .gemv import baseline_gemv, ssr_gemv  # noqa: F401
from .reduction import baseline_dot, ssr_dot  # noqa: F401
from .relu import baseline_relu, ssr_relu  # noqa: F401
from .scan import baseline_scan, ssr_scan  # noqa: F401
from .stencil import baseline_stencil1d, ssr_stencil1d, ssr_stencil2d  # noqa: F401
