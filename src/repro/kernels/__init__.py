"""Pallas TPU kernels: the paper's §4.2 suite + production matmul/attention.

Each kernel module declares only its compute body and stream geometry on the
shared :mod:`frontend` (padding/reshape/dispatch/trim live there once) and
self-registers in :mod:`registry`, which exposes ``ssr``/``baseline``/``ref``
variants uniformly to benchmarks, tests, and the ``ssrcfg`` dispatch layer.
All kernels are validated against the pure-jnp oracles in ``ref.py``
(interpret mode on CPU; Mosaic on real TPUs).
"""

from . import frontend, ops, ref, registry  # noqa: F401
from .attention import ssr_flash_attention  # noqa: F401
from .bitonic import ssr_sort  # noqa: F401
from .chained import (  # noqa: F401
    fused_axpy_dot,
    fused_gemv_relu,
    fused_stencil1d_relu,
    fused_sum_sq_diff,
    fused_cases,
)
from .fft import ssr_fft  # noqa: F401
from .gemm import baseline_matmul, ssr_matmul  # noqa: F401
from .gemv import baseline_gemv, ssr_gemv  # noqa: F401
from .reduction import baseline_dot, ssr_dot  # noqa: F401
from .registry import entries, get, register_kernel  # noqa: F401
from .relu import baseline_relu, ssr_relu  # noqa: F401
from .scan import baseline_scan, ssr_scan  # noqa: F401
from .stencil import baseline_stencil1d, ssr_stencil1d, ssr_stencil2d  # noqa: F401
