"""Star-shaped stencils (discrete Laplace style, diameter 11) — paper §4.2.

1-D: the input is streamed through *two* lanes offset by one block — the
halo trick.  Each output tile needs ``taps − 1`` elements beyond its own
extent; lane 0 carries block i, lane 1 block i+1 (an affine index_map
``i ↦ i+1`` — exactly a second AGU with a shifted base pointer, paper §2.3).
The tap loop is fully unrolled in the body with *static* slices: zero address
arithmetic survives at run time, matching the SSR hot loop that contains only
fmadds.  Coefficients ride a constant (repeat-semantics) stream.

2-D: the 64×64 problem fits VMEM whole (the paper likewise sizes problems to
the TCDM, §4.2), so the kernel is a single-step streamed load of the padded
grid; the two arm loops unroll statically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, autotune, compiler
from repro.core.lowering import DEFAULT_SCHEDULE, Schedule

from .frontend import (LANES, Launch, MonolithicKernel, StreamKernel,
                       promote, trim_vector)
from .registry import KernelEntry, register_kernel

TAPS = 11


def _block_width(schedule: Schedule | None) -> int:
    """The 1-D stencil's tunable knob: elements per streamed block.

    The halo trick needs flat ``(1, W)`` blocks (a multi-row block would
    wrap the window across sublanes), so the schedule's ``lanes`` field is
    the block width — the autotuner sweeps it in multiples of the 128-wide
    hardware lane.  Default (128) matches the historical geometry.
    """
    w = (schedule or DEFAULT_SCHEDULE).lanes
    if w % LANES:
        raise ValueError(
            f"stencil block width {w} is not a multiple of the {LANES}-wide "
            "hardware lane")
    return w


def _check_taps(w):
    if w.shape[0] != TAPS:
        raise ValueError(f"stencil diameter fixed at {TAPS} (paper §4.2)")


# -- 1-D --------------------------------------------------------------------


def _prepare_1d(x, w, schedule=None):
    _check_taps(w)
    width = _block_width(schedule)
    n = x.shape[0] - (TAPS - 1)
    nblk = -(-n // width)
    # pad so that blocks [0..nblk] exist (halo lane reads block i+1)
    need = (nblk + 1) * width
    x = jnp.pad(x, (0, need - x.shape[0]))
    xp2d = x.reshape(nblk + 1, width)
    return (xp2d, xp2d, w.reshape(1, TAPS)), width, n


def window_block(lo, hi, w2d):
    """Pure tap loop over one (1, W) block + its halo block.

    Shared by the plain stream kernel and the fused (chained) variants —
    the fully unrolled fmadd-only hot loop, as a block→block function.
    The width comes from the blocks themselves, so the schedule-tuned
    geometry flows through without another parameter.
    """
    width = lo.shape[-1]
    window = jnp.concatenate([promote(lo), promote(hi)], axis=1)
    acc = jnp.zeros((1, width), jnp.float32)
    for j in range(TAPS):                      # static unroll: fmadds only
        acc = acc + promote(w2d[0, j]) * window[:, j:j + width]
    return acc


def _body_1d(static):
    def body(lo_ref, hi_ref, w_ref, o_ref):
        o_ref[...] = window_block(lo_ref[...], hi_ref[...], w_ref[...])

    return body


def _launch_1d(width, xp2d, _xp2d, w2d):
    nblk = xp2d.shape[0] - 1
    return Launch(
        grid=(nblk,),
        in_streams=(
            BlockStream((1, width), lambda i: (i, 0), name="x_lo"),
            BlockStream((1, width), lambda i: (i + 1, 0), name="x_hi"),
            BlockStream((1, TAPS), lambda i: (0, 0), name="w"),  # repeat
        ),
        out_streams=(BlockStream((1, width), lambda i: (i, 0),
                                 Direction.WRITE, name="y"),),
        out_shapes=(jax.ShapeDtypeStruct((nblk, width), jnp.float32),),
        dimension_semantics=("parallel",),
    )


_ssr_1d = StreamKernel(
    "stencil1d", prepare=_prepare_1d, launch=_launch_1d,
    body=_body_1d, finish=trim_vector,
    lowering_waiver=(
        "halo overlap: adjacent output tiles read overlapping input "
        "windows (coeffs (1, 1) admit no dense storage order), served by "
        "two base-shifted streams — the paper's second AGU trick"))


def ssr_stencil1d(x: jax.Array, w: jax.Array, *, interpret=None,
                  schedule: Schedule | None = None) -> jax.Array:
    """y[i] = Σ_j w[j]·x[i+j] for i in [0, n); x has length n + TAPS − 1.

    ``schedule`` tunes the block width (``schedule.lanes`` elements per
    grid step); semantics are identical for every legal width.
    ``schedule=None`` consults the autotuner's persistent cache under the
    same key the tuner commits (the §4.2 cost nest + operand signature),
    so tuned widths reach ``ops.stencil1d``/registry callers transparently
    — the waivered geometry opts back into tuning by hand.
    """
    if schedule is None:
        n = x.shape[0] - (TAPS - 1)
        hit = autotune.lookup(compiler.stencil_nest(n, TAPS),
                              {"x": x, "w": w}, mode="map",
                              out_dtype="float32")
        schedule = None if hit == DEFAULT_SCHEDULE else hit
    return _ssr_1d(x, w, interpret=interpret, schedule=schedule)


def _prepare_base_1d(x, w):
    _check_taps(w)
    n = x.shape[0] - (TAPS - 1)
    return (x.reshape(1, -1), promote(w).reshape(1, TAPS)), n, None


def _baseline_body_1d(n):
    def body(x_ref, w_ref, o_ref):
        def tap(j, acc):
            return acc + w_ref[0, j] * jax.lax.dynamic_slice(
                promote(x_ref[...]), (0, j), (1, n))

        o_ref[...] = jax.lax.fori_loop(
            0, TAPS, tap, jnp.zeros((1, n), jnp.float32))

    return body


_base_1d = MonolithicKernel(
    "stencil1d", prepare=_prepare_base_1d, body=_baseline_body_1d,
    out_shape=lambda n, *arrs: jax.ShapeDtypeStruct((1, n), jnp.float32),
    finish=lambda out, _: out.reshape(-1))


def baseline_stencil1d(x: jax.Array, w: jax.Array, *,
                       interpret=None) -> jax.Array:
    """Monolithic variant: explicit in-body dynamic-slice 'loads' per tap."""
    return _base_1d(x, w, interpret=interpret)


def cluster_stencil1d(x: jax.Array, w: jax.Array, *, cores: int,
                      interpret=None) -> jax.Array:
    """1-D stencil on a C-core cluster (paper §5.3): output-tile split.

    Each core owns a contiguous slab of output elements and needs its slab
    plus a ``TAPS − 1`` halo of input — the shared-TCDM neighbourhood the
    paper's cores read for free.  On a device mesh the halos are
    materialised up front: the input is gathered into C overlapping tiles
    (stacked on a new leading axis), each core runs the unchanged streamed
    stencil on its tile, and output slabs concatenate with *no* collective
    (per-element tap sums are identical to the single-core walk, so the
    split is numerically exact).
    """
    from repro.parallel.cluster import cluster_kernel

    if cores == 1:
        return ssr_stencil1d(x, w, interpret=interpret)
    _check_taps(w)
    n = x.shape[0] - (TAPS - 1)
    tile = -(-n // cores)
    need = cores * tile + TAPS - 1
    if need > x.shape[0]:
        x = jnp.pad(x, (0, need - x.shape[0]))
    starts = jnp.arange(cores)[:, None] * tile
    tiles = x[starts + jnp.arange(tile + TAPS - 1)[None, :]]

    out = cluster_kernel(
        lambda xt, wt: ssr_stencil1d(xt[0], wt, interpret=interpret)[None, :],
        (tiles, w), cores=cores, in_dims=(0, None), out_dim=0)
    return out.reshape(-1)[:n]


# -- 2-D --------------------------------------------------------------------


def _prepare_2d(x, wx, wy):
    _check_taps(wx)
    _check_taps(wy)
    return (x, wx.reshape(1, TAPS), wy.reshape(1, TAPS)), None, None


def _body_2d(static):
    def body(x_ref, wx_ref, wy_ref, o_ref):
        r = TAPS // 2
        h = o_ref.shape[0]
        wgrid = o_ref.shape[1]
        x = promote(x_ref[...])
        acc = jnp.zeros((h, wgrid), jnp.float32)
        for j in range(TAPS):                  # static unroll, both arms
            acc = acc + promote(wx_ref[0, j]) * x[r:r + h, j:j + wgrid]
            acc = acc + promote(wy_ref[0, j]) * x[j:j + h, r:r + wgrid]
        o_ref[...] = acc

    return body


def _launch_2d(static, xp, wx2d, wy2d):
    r = TAPS // 2
    h, wgrid = xp.shape[0] - 2 * r, xp.shape[1] - 2 * r
    return Launch(
        grid=(1,),
        in_streams=(
            BlockStream(xp.shape, lambda i: (0, 0), name="x"),
            BlockStream((1, TAPS), lambda i: (0, 0), name="wx"),
            BlockStream((1, TAPS), lambda i: (0, 0), name="wy"),
        ),
        out_streams=(BlockStream((h, wgrid), lambda i: (0, 0),
                                 Direction.WRITE, name="y"),),
        out_shapes=(jax.ShapeDtypeStruct((h, wgrid), jnp.float32),),
    )


_ssr_2d = StreamKernel(
    "stencil2d", prepare=_prepare_2d, launch=_launch_2d, body=_body_2d,
    lowering_waiver=(
        "2-D halos on both axes; the 64×64 problem is sized to VMEM "
        "(§4.2's TCDM discipline) so the whole padded grid rides one "
        "loop-invariant stream"))


def ssr_stencil2d(x: jax.Array, wx: jax.Array, wy: jax.Array, *,
                  interpret=None) -> jax.Array:
    """Star stencil over a padded grid ``x`` (pad r = TAPS//2 each side)."""
    return _ssr_2d(x, wx, wy, interpret=interpret)


@register_kernel("stencil1d")
def _entry_1d() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 500 if odd else 1024
        return ((jnp.asarray(rng.standard_normal(n + TAPS - 1), jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)),
                {})

    return KernelEntry(name="stencil1d", ssr=ssr_stencil1d,
                       baseline=baseline_stencil1d, ref=ref.stencil1d_ref,
                       cluster=cluster_stencil1d,
                       example=example, tol={"rtol": 1e-3, "atol": 1e-4},
                       problem="11-point star, n=1024")


@register_kernel("stencil2d")
def _entry_2d() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        hw = (42, 74) if odd else (74, 74)
        return ((jnp.asarray(rng.standard_normal(hw), jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)),
                {})

    return KernelEntry(name="stencil2d", ssr=ssr_stencil2d,
                       ref=ref.stencil2d_ref, example=example,
                       tol={"rtol": 1e-3, "atol": 1e-3},
                       problem="11-point star, 64×64")
