"""Star-shaped stencils (discrete Laplace style, diameter 11) — paper §4.2.

1-D: the input is streamed through *two* lanes offset by one block — the
halo trick.  Each output tile needs ``taps − 1`` elements beyond its own
extent; lane 0 carries block i, lane 1 block i+1 (an affine index_map
``i ↦ i+1`` — exactly a second AGU with a shifted base pointer, paper §2.3).
The tap loop is fully unrolled in the body with *static* slices: zero address
arithmetic survives at run time, matching the SSR hot loop that contains only
fmadds.  Coefficients ride a constant (repeat-semantics) stream.

2-D: the 64×64 problem fits VMEM whole (the paper likewise sizes problems to
the TCDM, §4.2), so the kernel is a single-step streamed load of the padded
grid; the two arm loops unroll statically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import BlockStream, Direction, ssr_pallas

_LANES = 128
TAPS = 11


def _body_1d(lo_ref, hi_ref, w_ref, o_ref):
    window = jnp.concatenate(
        [lo_ref[...].astype(jnp.float32), hi_ref[...].astype(jnp.float32)],
        axis=1)
    acc = jnp.zeros((1, _LANES), jnp.float32)
    for j in range(TAPS):                      # static unroll: fmadds only
        acc = acc + w_ref[0, j].astype(jnp.float32) * window[:, j:j + _LANES]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch_1d(xp2d, w2d, interpret: bool = True):
    nblk = xp2d.shape[0] - 1
    fn = ssr_pallas(
        _body_1d,
        grid=(nblk,),
        in_streams=[
            BlockStream((1, _LANES), lambda i: (i, 0), name="x_lo"),
            BlockStream((1, _LANES), lambda i: (i + 1, 0), name="x_hi"),
            BlockStream((1, TAPS), lambda i: (0, 0), name="w"),  # repeat
        ],
        out_streams=[BlockStream((1, _LANES), lambda i: (i, 0),
                                 Direction.WRITE, name="y")],
        out_shapes=[jax.ShapeDtypeStruct((nblk, _LANES), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("parallel",),
    )
    return fn(xp2d, xp2d, w2d)


def ssr_stencil1d(x: jax.Array, w: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """y[i] = Σ_j w[j]·x[i+j] for i in [0, n); x has length n + TAPS − 1."""
    if w.shape[0] != TAPS:
        raise ValueError(f"stencil diameter fixed at {TAPS} (paper §4.2)")
    n = x.shape[0] - (TAPS - 1)
    nblk = -(-n // _LANES)
    # pad so that blocks [0..nblk] exist (halo lane reads block i+1)
    need = (nblk + 1) * _LANES
    x = jnp.pad(x, (0, need - x.shape[0]))
    out = _dispatch_1d(x.reshape(nblk + 1, _LANES), w.reshape(1, TAPS),
                       interpret)
    return out.reshape(-1)[:n]


def _body_2d(x_ref, wx_ref, wy_ref, o_ref):
    r = TAPS // 2
    h = o_ref.shape[0]
    wgrid = o_ref.shape[1]
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.zeros((h, wgrid), jnp.float32)
    for j in range(TAPS):                      # static unroll, both arms
        acc = acc + wx_ref[0, j].astype(jnp.float32) * x[r:r + h, j:j + wgrid]
        acc = acc + wy_ref[0, j].astype(jnp.float32) * x[j:j + h, r:r + wgrid]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch_2d(xp, wx2d, wy2d, interpret: bool = True):
    r = TAPS // 2
    h, wgrid = xp.shape[0] - 2 * r, xp.shape[1] - 2 * r
    fn = ssr_pallas(
        _body_2d,
        grid=(1,),
        in_streams=[
            BlockStream(xp.shape, lambda i: (0, 0), name="x"),
            BlockStream((1, TAPS), lambda i: (0, 0), name="wx"),
            BlockStream((1, TAPS), lambda i: (0, 0), name="wy"),
        ],
        out_streams=[BlockStream((h, wgrid), lambda i: (0, 0),
                                 Direction.WRITE, name="y")],
        out_shapes=[jax.ShapeDtypeStruct((h, wgrid), jnp.float32)],
        interpret=interpret,
    )
    return fn(xp, wx2d, wy2d)


def ssr_stencil2d(x: jax.Array, wx: jax.Array, wy: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """Star stencil over a padded grid ``x`` (pad r = TAPS//2 each side)."""
    return _dispatch_2d(x, wx.reshape(1, TAPS), wy.reshape(1, TAPS),
                        interpret)


def _baseline_body_1d(x_ref, w_ref, o_ref):
    n = o_ref.shape[1]

    def tap(j, acc):
        return acc + w_ref[0, j] * jax.lax.dynamic_slice(
            x_ref[...].astype(jnp.float32), (0, j), (1, n))

    o_ref[...] = jax.lax.fori_loop(
        0, TAPS, tap, jnp.zeros((1, n), jnp.float32))


def baseline_stencil1d(x: jax.Array, w: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """Monolithic variant: explicit in-body dynamic-slice 'loads' per tap."""
    n = x.shape[0] - (TAPS - 1)
    out = pl.pallas_call(
        _baseline_body_1d,
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(x.reshape(1, -1), w.astype(jnp.float32).reshape(1, TAPS))
    return out.reshape(-1)
