"""Star-shaped stencils (discrete Laplace style, diameter 11) — paper §4.2.

Both stencils are *nest-lowered*: the kernel module declares only the loop
nest (a windowed READ ref — ``MemRef.window`` — plus invariant coefficient
streams) and the tap-loop body; ``lower_nest`` serves the halo by emitting
``2**k`` +1-shifted twin streams per windowed ref (k halo'd levels) and
stitching the widened block in-kernel (DESIGN.md §13) — the paper's §2.3
second-AGU trick at block granularity.  The tap loop is fully unrolled in
the body with *static* slices: zero address arithmetic survives at run
time, matching the SSR hot loop that contains only fmadds.

Migrating off the hand-written Launch (the old ``lowering_waiver``) buys
the full shared path: autotuned block geometry, ``buffer_depth``
pipelining, zero-overhead dispatch and the Eq. (1)–(3) cost model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import compiler
from repro.core.lowering import Schedule

from .frontend import MonolithicKernel, NestKernel, promote
from .registry import KernelEntry, register_kernel

TAPS = 11


def _check_taps(w):
    if w.shape[0] != TAPS:
        raise ValueError(f"stencil diameter fixed at {TAPS} (paper §4.2)")


# -- 1-D --------------------------------------------------------------------


def stencil1d_block(x_wide, w2d):
    """Pure tap loop over one widened ``(1, t + TAPS - 1)`` halo block.

    Shared with the fused (chained) variant — the fully unrolled
    fmadd-only hot loop as a block→block function.  The output width comes
    from the block itself, so the schedule-tuned geometry flows through
    without another parameter.
    """
    t = x_wide.shape[-1] - (TAPS - 1)
    acc = promote(w2d[0, 0]) * promote(x_wide[:, 0:t])
    for j in range(1, TAPS):                   # static unroll: fmadds only
        acc = acc + promote(w2d[0, j]) * promote(x_wide[:, j:j + t])
    return acc


def _prepare_1d(x, w):
    _check_taps(w)
    n = x.shape[0] - (TAPS - 1)
    return {"x": x, "w": w}, n, None


def _nest_1d(n):
    return compiler.stencil_nest(n, TAPS)


def _body_1d(n):
    def body(x_wide, w_blk):
        return stencil1d_block(x_wide, w_blk)

    return body


_ssr_1d = NestKernel("stencil1d", prepare=_prepare_1d, nest=_nest_1d,
                     body=_body_1d)


def ssr_stencil1d(x: jax.Array, w: jax.Array, *, interpret=None,
                  schedule: Schedule | None = None) -> jax.Array:
    """y[i] = Σ_j w[j]·x[i+j] for i in [0, n); x has length n + TAPS − 1.

    Fully nest-lowered: ``x`` is a windowed ref (halo ``TAPS``), served by
    a +1-shifted twin stream; ``w`` rides as an invariant coefficient
    block.  ``schedule=None`` resolves a tuned schedule from the
    autotuner's persistent cache (keyed on
    :func:`repro.core.compiler.stencil_nest`); an explicit schedule pins
    the geometry — semantics are identical for every legal schedule.
    """
    return _ssr_1d(x, w, interpret=interpret, schedule=schedule)


def _prepare_base_1d(x, w):
    _check_taps(w)
    n = x.shape[0] - (TAPS - 1)
    return (x.reshape(1, -1), promote(w).reshape(1, TAPS)), n, None


def _baseline_body_1d(n):
    def body(x_ref, w_ref, o_ref):
        def tap(j, acc):
            return acc + w_ref[0, j] * jax.lax.dynamic_slice(
                promote(x_ref[...]), (0, j), (1, n))

        o_ref[...] = jax.lax.fori_loop(
            0, TAPS, tap, jnp.zeros((1, n), jnp.float32))

    return body


_base_1d = MonolithicKernel(
    "stencil1d", prepare=_prepare_base_1d, body=_baseline_body_1d,
    out_shape=lambda n, *arrs: jax.ShapeDtypeStruct((1, n), jnp.float32),
    finish=lambda out, _: out.reshape(-1))


def baseline_stencil1d(x: jax.Array, w: jax.Array, *,
                       interpret=None) -> jax.Array:
    """Monolithic variant: explicit in-body dynamic-slice 'loads' per tap."""
    return _base_1d(x, w, interpret=interpret)


def cluster_stencil1d(x: jax.Array, w: jax.Array, *, cores: int,
                      interpret=None) -> jax.Array:
    """1-D stencil on a C-core cluster (paper §5.3): output-tile split.

    Each core owns a contiguous slab of output elements and needs its slab
    plus a ``TAPS − 1`` halo of input — the shared-TCDM neighbourhood the
    paper's cores read for free.  On a device mesh the halos are
    materialised up front: the input is gathered into C overlapping tiles
    (stacked on a new leading axis), each core runs the unchanged streamed
    stencil on its tile, and output slabs concatenate with *no* collective
    (per-element tap sums are identical to the single-core walk, so the
    split is numerically exact).
    """
    from repro.parallel.cluster import cluster_kernel

    if cores == 1:
        return ssr_stencil1d(x, w, interpret=interpret)
    _check_taps(w)
    n = x.shape[0] - (TAPS - 1)
    tile = -(-n // cores)
    need = cores * tile + TAPS - 1
    if need > x.shape[0]:
        x = jnp.pad(x, (0, need - x.shape[0]))
    starts = jnp.arange(cores)[:, None] * tile
    tiles = x[starts + jnp.arange(tile + TAPS - 1)[None, :]]

    out = cluster_kernel(
        lambda xt, wt: ssr_stencil1d(xt[0], wt, interpret=interpret)[None, :],
        (tiles, w), cores=cores, in_dims=(0, None), out_dim=0)
    return out.reshape(-1)[:n]


# -- 2-D --------------------------------------------------------------------


def _prepare_2d(x, wx, wy):
    _check_taps(wx)
    _check_taps(wy)
    r = TAPS // 2
    h, wd = x.shape[0] - 2 * r, x.shape[1] - 2 * r
    return {"x": x, "wx": wx, "wy": wy}, (h, wd), None


def _nest_2d(static):
    h, wd = static
    return compiler.stencil2d_nest(h, wd, TAPS)


def _body_2d(static):
    r = TAPS // 2

    def body(x_wide, wx_blk, wy_blk):
        h = x_wide.shape[0] - (TAPS - 1)
        wd = x_wide.shape[1] - (TAPS - 1)
        x = promote(x_wide)
        acc = jnp.zeros((h, wd), jnp.float32)
        for j in range(TAPS):                  # static unroll, both arms
            acc = acc + promote(wx_blk[0, j]) * x[r:r + h, j:j + wd]
            acc = acc + promote(wy_blk[0, j]) * x[j:j + h, r:r + wd]
        return acc

    return body


_ssr_2d = NestKernel("stencil2d", prepare=_prepare_2d, nest=_nest_2d,
                     body=_body_2d)


def ssr_stencil2d(x: jax.Array, wx: jax.Array, wy: jax.Array, *,
                  interpret=None,
                  schedule: Schedule | None = None) -> jax.Array:
    """Star stencil over a padded grid ``x`` (pad r = TAPS//2 each side).

    Nest-lowered with a ``(TAPS, TAPS)`` halo window on both levels: the
    lowering emits 4 shifted streams of the padded grid and stitches the
    widened block in-kernel (DESIGN.md §13).
    """
    return _ssr_2d(x, wx, wy, interpret=interpret, schedule=schedule)


@register_kernel("stencil1d")
def _entry_1d() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 500 if odd else 1024
        return ((jnp.asarray(rng.standard_normal(n + TAPS - 1), jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)),
                {})

    return KernelEntry(name="stencil1d", ssr=ssr_stencil1d,
                       baseline=baseline_stencil1d, ref=ref.stencil1d_ref,
                       cluster=cluster_stencil1d,
                       example=example, tol={"rtol": 1e-3, "atol": 1e-4},
                       problem="11-point star, n=1024")


@register_kernel("stencil2d")
def _entry_2d() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        hw = (42, 74) if odd else (74, 74)
        return ((jnp.asarray(rng.standard_normal(hw), jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32),
                 jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)),
                {})

    return KernelEntry(name="stencil2d", ssr=ssr_stencil2d,
                       ref=ref.stencil2d_ref, example=example,
                       tol={"rtol": 1e-3, "atol": 1e-3},
                       problem="11-point star, 64×64")
