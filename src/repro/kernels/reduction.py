"""Reduction (dot product) — the paper's running example (Fig. 4, §4.1).

SSR variant: both operands are read streams walked in lockstep by the AGU
(1-D unit stride); the "register" the body sees is an (8, 128) VMEM block.
The output is a revisited (1, 1) block accumulated across grid steps — the
accumulator register ``%x`` of Fig. 4.  The grid pipeline double-buffers the
next operand blocks while the current ones are consumed: the data mover's
run-ahead FIFO.

Baseline variant: one monolithic grid step with both vectors resident; the
body itself walks the blocks with an explicit ``fori_loop`` + dynamic loads —
the structural analogue of issuing ``p.flw`` pairs in the hot loop.  No
pipelining is possible (there is only one grid step), matching the baseline's
serialised load→compute issue.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction

from .frontend import (BLOCK_ELEMS, LANES, ROWS, Launch, MonolithicKernel,
                       StreamKernel, pad_vector, promote)
from .registry import KernelEntry, register_kernel


def _prepare(x, y):
    return (pad_vector(x), pad_vector(y)), None, None


def _ssr_body(static):
    def body(x_ref, y_ref, o_ref, acc_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # Vector accumulation: the whole (8, 128) vreg adds every step —
        # collapsing each block to a scalar here would serialise the VPU
        # behind one lane.  The scalar fold happens exactly once, below.
        acc_ref[...] += promote(x_ref[...]) * promote(y_ref[...])

        @pl.when(i == pl.num_programs(0) - 1)
        def _write():
            o_ref[...] = jnp.sum(acc_ref[...]).reshape(1, 1)

    return body


def _launch(static, x2d, y2d):
    return Launch(
        grid=(x2d.shape[0] // ROWS,),
        in_streams=(BlockStream((ROWS, LANES), lambda i: (i, 0), name="x"),
                    BlockStream((ROWS, LANES), lambda i: (i, 0), name="y")),
        out_streams=(BlockStream((1, 1), lambda i: (0, 0), Direction.WRITE,
                                 name="acc"),),
        out_shapes=(jax.ShapeDtypeStruct((1, 1), jnp.float32),),
        scratch_shapes=(pltpu.VMEM((ROWS, LANES), jnp.float32),),
        dimension_semantics=("arbitrary",),
    )


_ssr = StreamKernel("reduction", prepare=_prepare, launch=_launch,
                    body=_ssr_body, finish=lambda out, _: out[0, 0])


def _baseline_body(static):
    def body(x_ref, y_ref, o_ref):
        nblk = x_ref.shape[0] // ROWS

        def step(i, acc):
            # Explicit "loads": dynamic-slice fetch + compute, serialised.
            x = x_ref[pl.dslice(i * ROWS, ROWS), :]
            y = y_ref[pl.dslice(i * ROWS, ROWS), :]
            return acc + jnp.sum(promote(x) * promote(y))

        o_ref[...] = jax.lax.fori_loop(
            0, nblk, step, jnp.float32(0)).reshape(1, 1)

    return body


_base = MonolithicKernel(
    "reduction", prepare=_prepare, body=_baseline_body,
    out_shape=lambda static, *arrs: jax.ShapeDtypeStruct((1, 1), jnp.float32),
    finish=lambda out, _: out[0, 0])


def ssr_dot(x: jax.Array, y: jax.Array, *, interpret=None) -> jax.Array:
    """Streamed dot product. n is padded up to a whole number of blocks."""
    return _ssr(x, y, interpret=interpret)


def cluster_dot(x: jax.Array, y: jax.Array, *, cores: int,
                interpret=None) -> jax.Array:
    """Dot product on a C-core cluster (paper §5.3/Fig. 10).

    The Fig. 4 nest split C ways on its (only) loop level via the §3.2
    compiler path; per-core partials meet in one ``psum`` — the shared-TCDM
    combine.  Zero padding makes any n divisible and is reduce-neutral.
    """
    from repro.core import compiler
    from repro.parallel.cluster import cluster_call, pad_to_cores

    (x, y), n_pad = pad_to_cores((x, y), cores)
    return cluster_call(compiler.dot_product_nest(n_pad),
                        lambda a, b: promote(a) * promote(b),
                        {"A": x, "B": y}, mode="reduce", cores=cores,
                        interpret=interpret)


def baseline_dot(x: jax.Array, y: jax.Array, *, interpret=None) -> jax.Array:
    return _base(x, y, interpret=interpret)


@register_kernel("reduction")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 5000 if odd else 2048
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    return KernelEntry(name="reduction", ssr=ssr_dot, baseline=baseline_dot,
                       ref=ref.dot_ref, cluster=cluster_dot, example=example,
                       tol={"rtol": 1e-2, "atol": 1e-2},
                       problem="dot product, n=2048")
