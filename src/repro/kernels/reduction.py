"""Reduction (dot product) — the paper's running example (Fig. 4, §4.1).

SSR variant: declared as the Fig. 4 :func:`~repro.core.compiler.
dot_product_nest` and compiled through ``ssrify``/``lower_plan``/
``ssr_call`` — both operands become read streams walked in lockstep by the
AGU (1-D unit stride); the "register" the body sees is an (8, 128) VMEM
block, and the reduce epilogue is the accumulator register ``%x`` of Fig. 4
(vectorised: the whole vreg adds every step, folded to the scalar once on
the last).  The grid pipeline double-buffers the next operand blocks while
the current ones are consumed: the data mover's run-ahead FIFO.

Baseline variant: one monolithic grid step with both vectors resident; the
body itself walks the blocks with an explicit ``fori_loop`` + dynamic loads —
the structural analogue of issuing ``p.flw`` pairs in the hot loop.  No
pipelining is possible (there is only one grid step), matching the baseline's
serialised load→compute issue.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compiler

from .frontend import (ROWS, MonolithicKernel, NestKernel, pad_vector,
                       promote)
from .registry import KernelEntry, register_kernel


def _mul_body(static):
    # Block-shaped partial: ssr_call's reduce epilogue accumulates the
    # whole (rows, lanes) vreg every step and folds to the scalar once.
    def body(x_blk, y_blk):
        return promote(x_blk) * promote(y_blk)

    return body


_ssr = NestKernel(
    "reduction",
    prepare=lambda x, y: ({"A": x, "B": y}, x.shape[0], None),
    nest=compiler.dot_product_nest,
    body=_mul_body,
    mode="reduce")


def _prepare_base(x, y):
    return (pad_vector(x), pad_vector(y)), None, None


def _baseline_body(static):
    def body(x_ref, y_ref, o_ref):
        nblk = x_ref.shape[0] // ROWS

        def step(i, acc):
            # Explicit "loads": dynamic-slice fetch + compute, serialised.
            x = x_ref[pl.dslice(i * ROWS, ROWS), :]
            y = y_ref[pl.dslice(i * ROWS, ROWS), :]
            return acc + jnp.sum(promote(x) * promote(y))

        o_ref[...] = jax.lax.fori_loop(
            0, nblk, step, jnp.float32(0)).reshape(1, 1)

    return body


_base = MonolithicKernel(
    "reduction", prepare=_prepare_base, body=_baseline_body,
    out_shape=lambda static, *arrs: jax.ShapeDtypeStruct((1, 1), jnp.float32),
    finish=lambda out, _: out[0, 0])


def ssr_dot(x: jax.Array, y: jax.Array, *, interpret=None,
            schedule=None) -> jax.Array:
    """Streamed dot product. n is padded up to a whole number of blocks.

    ``schedule=None`` picks up the autotuner's cached winner (if any);
    an explicit :class:`~repro.core.Schedule` pins the block geometry.
    """
    return _ssr(x, y, interpret=interpret, schedule=schedule)


def cluster_dot(x: jax.Array, y: jax.Array, *, cores: int,
                interpret=None) -> jax.Array:
    """Dot product on a C-core cluster (paper §5.3/Fig. 10).

    The Fig. 4 nest split C ways on its (only) loop level via the §3.2
    compiler path; per-core partials meet in one ``psum`` — the shared-TCDM
    combine.  Zero padding makes any n divisible and is reduce-neutral.
    """
    from repro.core import compiler
    from repro.parallel.cluster import cluster_call, pad_to_cores

    (x, y), n_pad = pad_to_cores((x, y), cores)
    return cluster_call(compiler.dot_product_nest(n_pad),
                        lambda a, b: promote(a) * promote(b),
                        {"A": x, "B": y}, mode="reduce", cores=cores,
                        interpret=interpret)


def baseline_dot(x: jax.Array, y: jax.Array, *, interpret=None) -> jax.Array:
    return _base(x, y, interpret=interpret)


@register_kernel("reduction")
def _entry() -> KernelEntry:
    from . import ref

    def example(rng, odd: bool = False):
        n = 5000 if odd else 2048
        return ((jnp.asarray(rng.standard_normal(n), jnp.float32),
                 jnp.asarray(rng.standard_normal(n), jnp.float32)), {})

    return KernelEntry(name="reduction", ssr=ssr_dot, baseline=baseline_dot,
                       ref=ref.dot_ref, cluster=cluster_dot, example=example,
                       tol={"rtol": 1e-2, "atol": 1e-2},
                       problem="dot product, n=2048")
