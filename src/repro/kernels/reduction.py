"""Reduction (dot product) — the paper's running example (Fig. 4, §4.1).

SSR variant: both operands are read streams walked in lockstep by the AGU
(1-D unit stride); the "register" the body sees is an (8, 128) VMEM block.
The output is a revisited (1, 1) block accumulated across grid steps — the
accumulator register ``%x`` of Fig. 4.  The grid pipeline double-buffers the
next operand blocks while the current ones are consumed: the data mover's
run-ahead FIFO.

Baseline variant: one monolithic grid step with both vectors resident; the
body itself walks the blocks with an explicit ``fori_loop`` + dynamic loads —
the structural analogue of issuing ``p.flw`` pairs in the hot loop.  No
pipelining is possible (there is only one grid step), matching the baseline's
serialised load→compute issue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import BlockStream, Direction, ssr_pallas

_BLOCK_ROWS = 8
_LANES = 128
BLOCK_ELEMS = _BLOCK_ROWS * _LANES


def _ssr_body(x_ref, y_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(x * y).reshape(1, 1)

    @pl.when(i == pl.num_programs(0) - 1)
    def _write():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch_ssr(x2d: jax.Array, y2d: jax.Array, interpret: bool = True):
    rows = x2d.shape[0]
    grid = (rows // _BLOCK_ROWS,)
    fn = ssr_pallas(
        _ssr_body,
        grid=grid,
        in_streams=[
            BlockStream((_BLOCK_ROWS, _LANES), lambda i: (i, 0), name="x"),
            BlockStream((_BLOCK_ROWS, _LANES), lambda i: (i, 0), name="y"),
        ],
        out_streams=[
            BlockStream((1, 1), lambda i: (0, 0), Direction.WRITE, name="acc"),
        ],
        out_shapes=[jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("arbitrary",),
    )
    return fn(x2d, y2d)[0, 0]


def ssr_dot(x: jax.Array, y: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Streamed dot product. n is padded up to a whole number of blocks."""
    n = x.shape[0]
    pad = (-n) % BLOCK_ELEMS
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    rows = (n + pad) // _LANES
    return _dispatch_ssr(x.reshape(rows, _LANES), y.reshape(rows, _LANES),
                         interpret)


def _baseline_body(x_ref, y_ref, o_ref):
    rows = x_ref.shape[0]
    nblk = rows // _BLOCK_ROWS

    def step(i, acc):
        # Explicit "loads": dynamic-slice fetch + compute, serialised.
        x = x_ref[pl.dslice(i * _BLOCK_ROWS, _BLOCK_ROWS), :]
        y = y_ref[pl.dslice(i * _BLOCK_ROWS, _BLOCK_ROWS), :]
        return acc + jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))

    o_ref[...] = jax.lax.fori_loop(0, nblk, step, jnp.float32(0)).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dispatch_base(x2d, y2d, interpret: bool = True):
    out = pl.pallas_call(
        _baseline_body,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2d, y2d)
    return out[0, 0]


def baseline_dot(x: jax.Array, y: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % BLOCK_ELEMS
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    rows = (n + pad) // _LANES
    return _dispatch_base(x.reshape(rows, _LANES), y.reshape(rows, _LANES),
                          interpret)
