"""Pure-jnp oracles for every kernel in the suite (§4.2 of the paper).

Each Pallas kernel's test sweeps shapes/dtypes and asserts allclose against
the function here.  These are also the semantics the ``ssrcfg``-off path uses
in models, so "SSR on == SSR off" is checked against the same ground truth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dot_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reduction (dot product): the paper's running example."""
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def scan_ref(x: jax.Array) -> jax.Array:
    """All prefix sums (inclusive)."""
    return jnp.cumsum(x.astype(jnp.float32))


def relu_ref(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, jnp.zeros((), dtype=x.dtype))


def stencil1d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """11-point star stencil: y[i] = Σ_j w[j]·x[i+j], valid region only.

    ``x`` is the padded input of length n + taps − 1; output length n.
    """
    taps = w.shape[0]
    n = x.shape[0] - taps + 1
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((n,), jnp.float32)
    for j in range(taps):
        acc = acc + w[j].astype(jnp.float32) * xf[j:j + n]
    return acc


def stencil2d_ref(x: jax.Array, wx: jax.Array, wy: jax.Array) -> jax.Array:
    """Star-shaped 2-D stencil (cross of two 1-D arms, diameter = len(w)).

    ``x`` padded by r = taps//2 on all sides; arms share the centre point, so
    the centre coefficient is wx[r] + wy[r].
    """
    taps = wx.shape[0]
    r = taps // 2
    h = x.shape[0] - 2 * r
    wgrid = x.shape[1] - 2 * r
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((h, wgrid), jnp.float32)
    for j in range(taps):
        acc = acc + wx[j].astype(jnp.float32) * xf[r:r + h, j:j + wgrid]
        acc = acc + wy[j].astype(jnp.float32) * xf[j:j + h, r:r + wgrid]
    return acc


def gemv_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), x.astype(jnp.float32))


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def fft_ref(re: jax.Array, im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Forward DFT (no normalisation), split real/imag."""
    z = jnp.fft.fft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64))
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def sort_ref(x: jax.Array) -> jax.Array:
    return jnp.sort(x)


# -- fused (chained) oracles -------------------------------------------------


def gemv_relu_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    return relu_ref(gemv_ref(a, x))


def stencil1d_relu_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return relu_ref(stencil1d_ref(x, w))


def sum_sq_diff_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reduction-of-map: Σ (x − y)² — the fused map→reduce chain."""
    d = x.astype(jnp.float32) - y.astype(jnp.float32)
    return jnp.sum(d * d)


def axpy_dot_ref(x: jax.Array, y: jax.Array, w: jax.Array, *,
                 alpha: float = 1.0) -> jax.Array:
    """axpy→dot chain: (α·x + y) · w."""
    t = alpha * x.astype(jnp.float32) + y.astype(jnp.float32)
    return jnp.sum(t * w.astype(jnp.float32))


def layernorm_ref(x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """Per-row layernorm (no affine): (x − μ)·rsqrt(var + ε)."""
    xf = x.astype(jnp.float32)
    c = xf - jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(c * c, axis=-1, keepdims=True)
    return c * jax.lax.rsqrt(var + eps)


def softmax_xent_ref(z: jax.Array, p: jax.Array) -> jax.Array:
    """Σ_rows softmax cross-entropy, targets ``p`` summing to 1 per row."""
    zf = z.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(zf, axis=-1)
    return jnp.sum(lse - jnp.sum(pf * zf, axis=-1))


def mlp_block_ref(x: jax.Array, w1: jax.Array, b1: jax.Array,
                  w2: jax.Array, b2: jax.Array) -> jax.Array:
    """2-layer MLP block with residual on the activation: HW₂+b₂+H."""
    xf = x.astype(jnp.float32)
    h = jnp.maximum(jnp.dot(xf, w1.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
                    + b1.astype(jnp.float32), 0.0)
    y = jnp.dot(h, w2.astype(jnp.float32),
                preferred_element_type=jnp.float32) + b2.astype(jnp.float32)
    return y + h


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """Single-head attention oracle: softmax(q·kᵀ·scale + mask)·v.

    ``window``: sliding-window (h2o-danube style) — query i attends to keys
    in (i − window, i].  Computed in f32 regardless of input dtype.
    """
    sq, d = q.shape
    sk = k.shape[0]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("qd,kd->qk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (decode-friendly)
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kj <= qi)
    if window is not None:
        mask = mask & (kj > qi - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("qk,kd->qd", p, v.astype(jnp.float32))
