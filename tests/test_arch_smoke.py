"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finiteness (brief requirement f)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import shapes as shp
from repro.data import pipeline
from repro.launch import steps
from repro.models import decode_step, forward, init_caches, init_params
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
class TestSmoke:
    def test_train_step(self, arch):
        cfg = configs.get_smoke(arch)
        dcfg = pipeline.DataConfig(global_batch=2, seq_len=32)
        batch = pipeline.make_batch(cfg, dcfg, step=0)
        opt_cfg = adamw.AdamWConfig(learning_rate=1e-3)
        state = steps.init_train_step_state = steps.init_train_state(
            KEY, cfg, opt_cfg)
        train = steps.make_train_step(cfg, opt_cfg, microbatches=1)
        params, opt, metrics = train(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), (arch, loss)
        assert np.isfinite(float(metrics["grad_norm"]))
        # params actually changed
        delta = sum(
            float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(state["params"])))
        assert delta > 0

    def test_forward_shapes(self, arch):
        cfg = configs.get_smoke(arch)
        dcfg = pipeline.DataConfig(global_batch=2, seq_len=16)
        batch = pipeline.make_batch(cfg, dcfg, step=1)
        logits, _, _ = forward(params=init_params(KEY, cfg), cfg=cfg,
                               tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"))
        b = 2
        s = 16 + (cfg.frontend_len if cfg.frontend == "vision" else 0)
        if cfg.frontend == "audio":
            s = 16
        assert logits.shape == (b, s, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_decode_if_causal(self, arch):
        cfg = configs.get_smoke(arch)
        if not cfg.has_decode:
            pytest.skip("encoder-only: no decode step (documented skip)")
        params = init_params(KEY, cfg)
        caches = init_caches(cfg, 2, 32, jnp.dtype(cfg.compute_dtype))
        toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
        logits, caches = decode_step(params, cfg, toks, caches,
                                     jnp.zeros((2,), jnp.int32))
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())


class TestShapeCells:
    def test_cell_accounting(self):
        """40 cells total: 33 runnable + 7 documented skips (DESIGN §4)."""
        runnable, skipped = 0, 0
        for arch in configs.ARCH_IDS:
            cfg = configs.get(arch)
            for cell, reason in shp.cells_for(cfg):
                if reason is None:
                    runnable += 1
                else:
                    skipped += 1
        assert runnable + skipped == 40
        assert runnable == 33
        assert skipped == 7

    def test_skip_reasons(self):
        hubert = configs.get("hubert_xlarge")
        assert shp.skip_reason(hubert, shp.get_shape("decode_32k"))
        assert shp.skip_reason(hubert, shp.get_shape("long_500k"))
        yi = configs.get("yi_6b")
        assert shp.skip_reason(yi, shp.get_shape("long_500k"))
        assert shp.skip_reason(yi, shp.get_shape("train_4k")) is None
        # sub-quadratic archs run long_500k
        for a in ("xlstm_125m", "jamba_v01_52b", "h2o_danube_18b",
                  "deepseek_v3_671b"):
            assert shp.skip_reason(configs.get(a),
                                   shp.get_shape("long_500k")) is None

    def test_param_counts_match_published(self):
        expected = {
            "yi_6b": 6.1e9, "llama3_405b": 405.9e9,
            "deepseek_v3_671b": 671e9, "dbrx_132b": 131.6e9,
            "jamba_v01_52b": 51.6e9, "qwen3_14b": 14.8e9,
            "h2o_danube_18b": 1.83e9, "hubert_xlarge": 0.95e9,
        }
        for arch, want in expected.items():
            got = configs.get(arch).param_count()
            assert abs(got - want) / want < 0.02, (arch, got, want)

    def test_active_params(self):
        # DeepSeek-V3: 37B active of 671B; Jamba: 12B active of 52B
        ds = configs.get("deepseek_v3_671b")
        assert abs(ds.active_param_count() - 37.5e9) / 37.5e9 < 0.05
        jb = configs.get("jamba_v01_52b")
        assert abs(jb.active_param_count() - 12.1e9) / 12.1e9 < 0.05
