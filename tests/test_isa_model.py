"""Paper-faithfulness tests: every closed-form number in §4.1 must match."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa


class TestFig4:
    def test_dot_product_counts(self):
        # Fig. 4: N=1000 → 3001 baseline, 1012 SSR instructions executed
        base, ssr = isa.fig4_dot_product(1000)
        assert base == 3001
        assert ssr == 1012

    def test_speedup_approaches_3x(self):
        base, ssr = isa.fig4_dot_product(100000)
        assert abs(base / ssr - 3.0) < 0.01


class TestTable2:
    def test_rows_exact(self):
        rows = {(r.kernel, r.arith): r for r in isa.table2()}
        expect = {
            ("Standard RV32", "int32"): (6, 3, 2.0),
            ("+ Hardware Loops", "int32"): (5, 1, 5.0),
            ("+ Post-Increment", "int32"): (6, 2, 3.0),
            ("Standard RV32", "fp32"): (6, 3, 2.0),
            ("+ Hardware Loops", "fp32"): (11, 3, 11 / 3),
            ("+ Post-Increment", "fp32"): (9, 3, 3.0),
        }
        for key, (nb, ns, s) in expect.items():
            r = rows[key]
            assert r.base.n == nb
            assert r.ssr.n == ns
            assert r.speedup == pytest.approx(s)

    def test_utilizations(self):
        rows = {(r.kernel, r.arith): r for r in isa.table2()}
        assert rows[("Standard RV32", "int32")].base.eta == pytest.approx(1 / 6)
        assert rows[("Standard RV32", "int32")].ssr.eta == pytest.approx(1 / 3)
        assert rows[("+ Hardware Loops", "int32")].ssr.eta == 1.0
        assert rows[("+ Post-Increment", "fp32")].base.eta == pytest.approx(1 / 3)
        # paper rounds 11 → 27 %
        assert rows[("+ Hardware Loops", "fp32")].base.eta == pytest.approx(
            3 / 11)

    def test_speedup_band(self):
        # abstract claim: SSR brings 2× to 5× at the ISA level
        for r in isa.table2():
            assert 2.0 <= r.speedup <= 5.0


class TestBreakeven:
    def test_min_sides(self):
        # paper: >5, >4, >1, >1 overall iterations for 1D..4D ⇒ minimal
        # integer sides 6, 3, 2, 2
        assert [isa.min_side_length(d) for d in (1, 2, 3, 4)] == [6, 3, 2, 2]

    @given(
        L=st.lists(st.integers(1, 50), min_size=1, max_size=4),
        I=st.data(),
        s=st.integers(1, 4),
    )
    @settings(max_examples=200, deadline=None)
    def test_eq3_iff_profitable(self, L, I, s):
        """Eq. (3) ⟺ N_ssr ≤ N_base, independent of I and s (paper §4.1.1)."""
        Ivals = I.draw(st.lists(st.integers(0, 9), min_size=len(L),
                                max_size=len(L)))
        lhs = isa.n_ssr(L, Ivals, s) <= isa.n_base(L, Ivals, s)
        assert lhs == isa.ssr_profitable(L)


class TestUtilizationLimits:
    def test_eq5_eq6(self):
        # Eq. (5): lim N/(2+3N) = 33 %; Eq. (6): lim N/(7+N) = 100 %
        assert isa.utilization_limit_dot(10**9, ssr=False) == pytest.approx(
            1 / 3, abs=1e-6)
        assert isa.utilization_limit_dot(10**9, ssr=True) == pytest.approx(
            1.0, abs=1e-6)

    def test_paper_eta_points(self):
        # §5.6.1: 93 % at N=100, 99.3 % at N=1000
        assert round(isa.utilization_limit_dot(100, True), 2) == 0.93
        assert round(isa.utilization_limit_dot(1000, True), 3) == 0.993

    def test_fig6_monotonic_in_l(self):
        for d in (1, 2, 3, 4):
            etas = [isa.utilization_reduction(l, d) for l in (2, 4, 8, 16, 32)]
            assert etas == sorted(etas)
        # long loops → near-full utilization (Fig. 6 asymptote)
        assert isa.utilization_reduction(1024, 1) > 0.95
        assert isa.utilization_reduction(64, 2) > 0.95

    def test_fig6_deeper_needs_longer(self):
        # at equal TOTAL iterations, deeper nests pay more config overhead
        total = 4096
        assert isa.utilization_reduction(4096, 1) \
            > isa.utilization_reduction(8, 4)  # 8^4 = 4096 iterations too

    def test_utilization_classes(self):
        assert isa.utilization_class(1, False) == pytest.approx(1 / 3)
        assert isa.utilization_class(2, False) == 0.5
        assert isa.utilization_class(1, True) == 1.0


class TestKernelSuite:
    def test_speedups_in_paper_band(self):
        # Fig. 7: between 2.0× and 3.7×, "generally at or above 2×" —
        # FFT sits right at the 2× low end (1.996 with setup overhead).
        for k in isa.kernel_suite():
            assert 1.95 <= k.speedup <= 3.7, (k.name, k.speedup)
        at_or_above_2 = sum(1 for k in isa.kernel_suite()
                            if k.speedup >= 2.0)
        assert at_or_above_2 >= len(isa.kernel_suite()) - 1

    def test_utilization_reaches_near_100(self):
        # Fig. 8: with SSR, hot-loop utilization approaches 100 %
        for k in isa.kernel_suite():
            assert k.eta_ssr > 0.95, (k.name, k.eta_ssr)

    def test_baseline_utilization_around_33(self):
        # Fig. 8: without SSRs "utilization is generally around 33 %"
        etas = [k.eta_base for k in isa.kernel_suite()]
        assert sum(1 for e in etas if abs(e - 1 / 3) < 0.01) >= 5
        assert all(e <= 0.51 for e in etas)


class TestCluster:
    def test_fig11_two_cores_match_six(self):
        # §5.4: a 2-core SSR cluster matches a 6-core non-SSR cluster
        assert isa.equivalent_cores(6) == 2

    def test_single_core_speedup_3x_drops_with_cores(self):
        # §5.4: 3× on one core, ~2.2× at six cores (Amdahl)
        s1 = isa.cluster_time(1, False) / isa.cluster_time(1, True)
        s6 = isa.cluster_time(6, False) / isa.cluster_time(6, True)
        assert s1 == pytest.approx(3.0, rel=0.01)
        assert 2.0 < s6 < 2.5
