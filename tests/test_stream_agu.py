"""Property tests for the StreamSpec / AGU address model."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (Direction, StreamSpec, address_sequence,
                        affine_coefficients, block_grid, contiguous,
                        gather_stream, scatter_stream, validate_no_race)


@st.composite
def stream_specs(draw):
    ndim = draw(st.integers(1, 4))
    bounds = tuple(draw(st.lists(st.integers(1, 6), min_size=ndim,
                                 max_size=ndim)))
    strides = tuple(draw(st.lists(st.integers(-8, 8), min_size=ndim,
                                  max_size=ndim)))
    base = draw(st.integers(0, 64))
    repeat = draw(st.integers(1, 3))
    return StreamSpec(bounds=bounds, strides=strides, base=base,
                      repeat=repeat)


class TestAddressModel:
    @given(spec=stream_specs())
    @settings(max_examples=100, deadline=None)
    def test_vectorised_agu_matches_oracle(self, spec):
        """The mixed-radix AGU equals the nested-loop enumeration."""
        want = np.array(list(spec.addresses()), dtype=np.int32)
        got = np.asarray(address_sequence(spec))
        np.testing.assert_array_equal(want, got)

    @given(spec=stream_specs())
    @settings(max_examples=50, deadline=None)
    def test_transaction_counts(self, spec):
        assert spec.num_transactions == spec.num_iterations * spec.repeat
        assert len(list(spec.addresses())) == spec.num_transactions

    @given(spec=stream_specs())
    @settings(max_examples=50, deadline=None)
    def test_address_range_bounds_all_addresses(self, spec):
        lo, hi = spec.address_range()
        addrs = np.asarray(address_sequence(spec))
        assert addrs.min() >= lo
        assert addrs.max() <= hi

    def test_gather_matches_manual(self):
        data = jnp.arange(64, dtype=jnp.float32)
        spec = StreamSpec(bounds=(4, 4), strides=(8, 2), base=1)
        got = np.asarray(gather_stream(data, spec))
        want = [1 + 8 * i + 2 * j for i in range(4) for j in range(4)]
        np.testing.assert_array_equal(got, np.array(want, dtype=np.float32))

    def test_repeat_register(self):
        data = jnp.arange(8, dtype=jnp.float32)
        spec = StreamSpec(bounds=(4,), strides=(2,), repeat=3)
        got = np.asarray(gather_stream(data, spec))
        np.testing.assert_array_equal(got, np.repeat([0, 2, 4, 6], 3))

    def test_scatter_writes_in_order(self):
        spec = StreamSpec(bounds=(4,), strides=(1,), base=2,
                          direction=Direction.WRITE)
        out = np.asarray(scatter_stream(8, jnp.arange(4.0), spec))
        np.testing.assert_array_equal(out, [0, 0, 0, 1, 2, 3, 0, 0])


class TestDeepNests:
    """Explicit 3-/4-deep iteration order and repeat semantics (§3.1).

    The hypothesis sweep above covers these shapes statistically; the
    multi-level lowering leans on the exact order, so it is pinned here
    against hand-unrolled loop nests.
    """

    def test_3deep_iteration_order(self):
        spec = StreamSpec(bounds=(2, 3, 4), strides=(100, 10, 1), base=7)
        want = [7 + 100 * i + 10 * j + k
                for i in range(2) for j in range(3) for k in range(4)]
        assert list(spec.addresses()) == want
        np.testing.assert_array_equal(np.asarray(address_sequence(spec)),
                                      want)

    def test_4deep_iteration_order(self):
        spec = StreamSpec(bounds=(2, 2, 3, 4), strides=(1000, 100, 10, 1))
        want = [1000 * h + 100 * i + 10 * j + k
                for h in range(2) for i in range(2)
                for j in range(3) for k in range(4)]
        assert list(spec.addresses()) == want
        np.testing.assert_array_equal(np.asarray(address_sequence(spec)),
                                      want)

    def test_3deep_zero_stride_revisits(self):
        # a GEMM-A-like walk: invariant over the middle loop — the same
        # address block re-emitted per middle iteration (repeat register
        # generalised to a loop level)
        spec = StreamSpec(bounds=(2, 3, 4), strides=(4, 0, 1))
        want = [4 * i + k for i in range(2) for _j in range(3)
                for k in range(4)]
        assert list(spec.addresses()) == want
        assert spec.num_memory_accesses == 24  # FIFO reuse is per-repeat

    def test_repeat_reemits_each_datum(self):
        spec = StreamSpec(bounds=(2, 3), strides=(3, 1), repeat=2)
        base = [3 * i + j for i in range(2) for j in range(3)]
        want = [a for a in base for _ in range(2)]
        assert list(spec.addresses()) == want
        assert spec.num_transactions == 12   # what the core sees
        assert spec.num_memory_accesses == 6  # what memory serves

    def test_five_deep_spec_rejected_matching_max_dims(self):
        from repro.core import MAX_DIMS

        assert MAX_DIMS == 4
        with pytest.raises(ValueError, match="1..4 loop dims"):
            StreamSpec(bounds=(2,) * 5, strides=(1,) * 5)

    def test_five_deep_nest_rejected_matching_max_dims(self):
        from repro.core import Direction, LoopNest, MemRef

        with pytest.raises(ValueError, match="AGU dims"):
            LoopNest(bounds=(2,) * 5,
                     refs=(MemRef("x", Direction.READ, (1,) * 5),),
                     compute_per_level=(1,) * 5)


class TestValidation:
    def test_max_dims(self):
        with pytest.raises(ValueError):
            StreamSpec(bounds=(2, 2, 2, 2, 2), strides=(1, 1, 1, 1, 1))

    def test_write_repeat_rejected(self):
        with pytest.raises(ValueError):
            StreamSpec(bounds=(4,), strides=(1,), repeat=2,
                       direction=Direction.WRITE)

    def test_race_detection(self):
        r = contiguous(16)
        w = StreamSpec(bounds=(4,), strides=(1,), base=8,
                       direction=Direction.WRITE)
        with pytest.raises(ValueError, match="SSR race"):
            validate_no_race([r], [w])
        w_far = StreamSpec(bounds=(4,), strides=(1,), base=100,
                           direction=Direction.WRITE)
        validate_no_race([r], [w_far])  # disjoint: fine


class TestBlockGrid:
    def test_exact_tiling(self):
        spec = StreamSpec(bounds=(4, 32, 128), strides=(4096, 128, 1))
        assert block_grid(spec, (8, 128)) == (4, 4, 1)

    def test_rejects_non_tiling(self):
        spec = StreamSpec(bounds=(10,), strides=(1,))
        with pytest.raises(ValueError):
            block_grid(spec, (3,))


class TestAffineProbe:
    def test_affine_map_recovered(self):
        f = lambda i, j, k: (2 * i + 1, 3 * k)
        got = affine_coefficients(f, (4, 5, 6))
        assert got is not None
        f0, coeffs = got
        np.testing.assert_array_equal(f0, [1, 0])
        np.testing.assert_array_equal(coeffs[0], [2, 0])
        np.testing.assert_array_equal(coeffs[2], [0, 3])

    def test_non_affine_rejected(self):
        f = lambda i, j: (i * j, 0)  # bilinear, not affine
        assert affine_coefficients(f, (4, 4)) is None

    @given(
        c0=st.integers(-3, 3), c1=st.integers(-3, 3),
        off=st.integers(-4, 4),
        grid=st.tuples(st.integers(2, 5), st.integers(2, 5)),
    )
    @settings(max_examples=50, deadline=None)
    def test_affine_always_accepted(self, c0, c1, off, grid):
        f = lambda i, j: (c0 * i + c1 * j + off,)
        got = affine_coefficients(f, grid)
        assert got is not None
        f0, coeffs = got
        assert f0[0] == off
        assert coeffs[0][0] == c0
        assert coeffs[1][0] == c1
