"""Property-based differential tests of the CSR indirection-stream kernels.

Random CSR patterns — empty rows, single-element rows, all-zero matrices,
densities across {0.01, 0.1, 0.5}, ragged row populations, non-square
shapes — drive SpMV/SpMM through the compiled gather path and compare
against the densified ``jnp.dot`` oracle to ≤ 1e-5.  Malformed CSR must
fail loudly with the pinned ``ValueError`` messages (they are API surface).
The dispatch tests pin the zero-overhead contract (a repeated call moves
no build/trace counters) and the schedule-cache transparency contract (a
tuned schedule committed under the kernel's own lookup key is picked up
with no call-site changes, and never changes the numbers).
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import DEFAULT_SCHEDULE, autotune, compiler
from repro.core.lowering import plan_stats
from repro.core.nest_analysis import auto_lanes
from repro.kernels import frontend, ops
from repro.kernels import sparse as sp

#: The differential-agreement bound of the whole suite (ISSUE acceptance):
#: streamed gather vs densified ``jnp.dot``, both in f32.
TOL = 1e-5

#: The densities the strategies sweep — sparse enough for empty rows to be
#: common, dense enough to exercise multi-element rows.
DENSITIES = (0.01, 0.1, 0.5)


def _assert_close(got, want, tol=TOL):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (got.shape, want.shape)
    if got.size:
        assert float(np.max(np.abs(got - want))) <= tol


# --------------------------------------------------------------------------
# CSR strategies
# --------------------------------------------------------------------------


@st.composite
def csr_patterns(draw, max_m=9, max_n=12):
    """A random valid CSR triple + its column count.

    Row populations are drawn independently per row (ragged by
    construction), biased by a density drawn from :data:`DENSITIES`; a row
    budget of zero yields empty rows, and density 0.01 on these small
    shapes yields entirely zero matrices — the edge cases ride in the
    distribution instead of being bolted on.
    """
    m = draw(st.integers(min_value=1, max_value=max_m))
    n = draw(st.integers(min_value=1, max_value=max_n))
    density = draw(st.sampled_from(list(DENSITIES)))
    data, indices, indptr = [], [], [0]
    for _ in range(m):
        cap = max(0, round(n * density))
        if cap and draw(st.booleans()):
            cap = max(1, cap - 1)  # jitter the row budget: ragged rows
        cols = sorted(draw(st.lists(
            st.integers(min_value=0, max_value=n - 1),
            min_size=0, max_size=cap, unique=True)))
        for c in cols:
            indices.append(c)
            data.append(draw(st.floats(min_value=-2.0, max_value=2.0)))
        indptr.append(len(indices))
    return (np.asarray(data, np.float32), np.asarray(indices, np.int64),
            np.asarray(indptr, np.int64), n)


def _dense_ref_spmv(data, indices, indptr, x):
    dense = sp.csr_to_dense(data, indices, indptr, x.shape[0])
    return jnp.dot(jnp.asarray(dense), jnp.asarray(x, jnp.float32))


def _dense_ref_spmm(data, indices, indptr, x):
    dense = sp.csr_to_dense(data, indices, indptr, x.shape[0])
    return jnp.dot(jnp.asarray(dense), jnp.asarray(x, jnp.float32))


# --------------------------------------------------------------------------
# Differential properties: streamed gather vs densified oracle
# --------------------------------------------------------------------------


class TestSpmvDifferential:
    @settings(max_examples=30, deadline=None)
    @given(csr=csr_patterns())
    def test_matches_densified_dot(self, csr):
        data, indices, indptr, n = csr
        rng = np.random.default_rng(n * 1000 + data.size)
        x = rng.standard_normal(n).astype(np.float32)
        _assert_close(sp.ssr_spmv(data, indices, indptr, x),
                      _dense_ref_spmv(data, indices, indptr, x))

    @settings(max_examples=15, deadline=None)
    @given(csr=csr_patterns())
    def test_baseline_matches_densified_dot(self, csr):
        data, indices, indptr, n = csr
        rng = np.random.default_rng(n * 77 + data.size)
        x = rng.standard_normal(n).astype(np.float32)
        _assert_close(sp.baseline_spmv(data, indices, indptr, x),
                      _dense_ref_spmv(data, indices, indptr, x))


class TestSpmmDifferential:
    @settings(max_examples=30, deadline=None)
    @given(csr=csr_patterns(), c=st.integers(min_value=1, max_value=5))
    def test_matches_densified_dot(self, csr, c):
        data, indices, indptr, n = csr
        rng = np.random.default_rng(n * 1000 + c)
        x = rng.standard_normal((n, c)).astype(np.float32)
        _assert_close(sp.ssr_spmm(data, indices, indptr, x),
                      _dense_ref_spmm(data, indices, indptr, x))

    @settings(max_examples=15, deadline=None)
    @given(csr=csr_patterns(), c=st.integers(min_value=1, max_value=5))
    def test_baseline_matches_densified_dot(self, csr, c):
        data, indices, indptr, n = csr
        rng = np.random.default_rng(n * 77 + c)
        x = rng.standard_normal((n, c)).astype(np.float32)
        _assert_close(sp.baseline_spmm(data, indices, indptr, x),
                      _dense_ref_spmm(data, indices, indptr, x))


class TestDeterministicEdgeCases:
    """The named edge shapes, pinned so a strategy change can't lose them."""

    def _roundtrip(self, data, indices, indptr, n):
        x = np.linspace(-1, 1, n, dtype=np.float32)
        X = np.linspace(-1, 1, 3 * n, dtype=np.float32).reshape(n, 3)
        _assert_close(sp.ssr_spmv(data, indices, indptr, x),
                      _dense_ref_spmv(data, indices, indptr, x))
        _assert_close(sp.ssr_spmm(data, indices, indptr, X),
                      _dense_ref_spmm(data, indices, indptr, X))

    def test_all_zero_matrix(self):
        self._roundtrip(np.zeros(0, np.float32), np.zeros(0, np.int64),
                        np.zeros(5, np.int64), 7)

    def test_every_row_single_element(self):
        self._roundtrip(np.asarray([1.5, -2.0, 0.25], np.float32),
                        np.asarray([4, 0, 2], np.int64),
                        np.asarray([0, 1, 2, 3], np.int64), 6)

    def test_mixed_empty_and_full_rows(self):
        self._roundtrip(np.asarray([1.0, 2.0, 3.0, 4.0], np.float32),
                        np.asarray([0, 1, 2, 3], np.int64),
                        np.asarray([0, 0, 4, 4], np.int64), 4)

    def test_one_by_one(self):
        self._roundtrip(np.asarray([3.0], np.float32),
                        np.asarray([0], np.int64),
                        np.asarray([0, 1], np.int64), 1)

    def test_tall_and_wide(self):
        rng = np.random.default_rng(11)
        for m, n in ((17, 3), (3, 17)):
            data, indices, indptr = sp.random_csr(rng, m, n, 0.3)
            self._roundtrip(data, indices, indptr, n)

    def test_sparse_gemv_alias(self):
        rng = np.random.default_rng(5)
        data, indices, indptr = sp.random_csr(rng, 8, 10, 0.2)
        x = rng.standard_normal(10).astype(np.float32)
        _assert_close(sp.ssr_sparse_gemv(data, indices, indptr, x),
                      sp.ssr_spmv(data, indices, indptr, x), tol=0.0)

    def test_ops_facades_agree_across_ssrcfg(self):
        rng = np.random.default_rng(6)
        data, indices, indptr = sp.random_csr(rng, 9, 11, 0.2)
        x = rng.standard_normal(11).astype(np.float32)
        X = rng.standard_normal((11, 4)).astype(np.float32)
        _assert_close(ops.spmv(data, indices, indptr, x, ssr=True),
                      ops.spmv(data, indices, indptr, x, ssr=False))
        _assert_close(ops.spmm(data, indices, indptr, X, ssr=True),
                      ops.spmm(data, indices, indptr, X, ssr=False))
        _assert_close(ops.sparse_gemv(data, indices, indptr, x, ssr=True),
                      ops.spmv(data, indices, indptr, x, ssr=True), tol=0.0)


# --------------------------------------------------------------------------
# Malformed CSR: loud, pinned failures
# --------------------------------------------------------------------------

_GOOD = (np.asarray([1.0, 2.0, 3.0], np.float32),
         np.asarray([0, 2, 1], np.int64),
         np.asarray([0, 2, 3], np.int64), 4)


class TestInvalidCsr:
    def _x(self):
        return np.ones(_GOOD[3], np.float32)

    def test_good_baseline_is_valid(self):
        sp.validate_csr(*_GOOD)

    def test_short_indptr(self):
        with pytest.raises(ValueError,
                           match="indptr must be 1-D with at least two"):
            sp.validate_csr(_GOOD[0], _GOOD[1], np.asarray([0]), 4)

    def test_two_dimensional_indptr(self):
        with pytest.raises(ValueError,
                           match="indptr must be 1-D with at least two"):
            sp.validate_csr(_GOOD[0], _GOOD[1], np.zeros((2, 2), np.int64), 4)

    def test_data_indices_length_mismatch(self):
        with pytest.raises(ValueError,
                           match="data and indices must be 1-D of equal"):
            sp.validate_csr(_GOOD[0][:2], _GOOD[1], _GOOD[2], 4)

    def test_non_monotone_indptr(self):
        with pytest.raises(ValueError, match="indptr must be non-decreasing"):
            sp.validate_csr(_GOOD[0], _GOOD[1],
                            np.asarray([0, 3, 2]), 4)
        # non-monotone must win over the endpoint check even when the
        # endpoints happen to be right
        with pytest.raises(ValueError, match="indptr must be non-decreasing"):
            sp.ssr_spmv(_GOOD[0], _GOOD[1], np.asarray([0, 3, 1, 3]),
                        self._x())

    def test_bad_indptr_endpoints(self):
        with pytest.raises(ValueError,
                           match="indptr must start at 0 and end at nnz"):
            sp.validate_csr(_GOOD[0], _GOOD[1], np.asarray([1, 2, 3]), 4)
        with pytest.raises(ValueError,
                           match="indptr must start at 0 and end at nnz"):
            sp.validate_csr(_GOOD[0], _GOOD[1], np.asarray([0, 2, 5]), 4)

    def test_column_index_out_of_range(self):
        with pytest.raises(ValueError, match="column index out of range"):
            sp.validate_csr(_GOOD[0], np.asarray([0, 9, 1]), _GOOD[2], 4)
        with pytest.raises(ValueError, match="column index out of range"):
            sp.validate_csr(_GOOD[0], np.asarray([0, -1, 1]), _GOOD[2], 4)

    def test_unsorted_within_row(self):
        with pytest.raises(
                ValueError,
                match="column indices must be strictly increasing within"):
            sp.validate_csr(np.asarray([1.0, 2.0, 3.0]),
                            np.asarray([2, 0, 1]),
                            np.asarray([0, 3, 3]), 4)

    def test_duplicate_within_row(self):
        with pytest.raises(
                ValueError,
                match="column indices must be strictly increasing within"):
            sp.validate_csr(np.asarray([1.0, 2.0]),
                            np.asarray([1, 1]),
                            np.asarray([0, 2]), 4)

    def test_descending_across_row_boundary_is_fine(self):
        # row 0 ends at col 3, row 1 starts at col 0: legal CSR
        sp.validate_csr(np.asarray([1.0, 2.0]),
                        np.asarray([3, 0]),
                        np.asarray([0, 1, 2]), 4)

    def test_all_entry_points_validate(self):
        bad_indptr = np.asarray([0, 3, 2])
        for fn in (sp.ssr_spmv, sp.baseline_spmv, sp.ref_spmv):
            with pytest.raises(ValueError,
                               match="indptr must be non-decreasing"):
                fn(_GOOD[0], _GOOD[1], bad_indptr, self._x())
        X = np.ones((_GOOD[3], 2), np.float32)
        for fn in (sp.ssr_spmm, sp.baseline_spmm, sp.ref_spmm):
            with pytest.raises(ValueError,
                               match="indptr must be non-decreasing"):
                fn(_GOOD[0], _GOOD[1], bad_indptr, X)

    def test_spmm_rejects_vector_operand(self):
        with pytest.raises(ValueError, match="dense \\(n, c\\) operand"):
            sp.ssr_spmm(_GOOD[0], _GOOD[1], _GOOD[2], self._x())

    @settings(max_examples=10, deadline=None)
    @given(csr=csr_patterns())
    def test_generated_patterns_are_valid(self, csr):
        data, indices, indptr, n = csr
        _, _, _, m = sp.validate_csr(data, indices, indptr, n)
        assert m == indptr.size - 1


# --------------------------------------------------------------------------
# Cost model: the eliminated index-handling instructions (Eq. (1)–(3) ext.)
# --------------------------------------------------------------------------


class TestIndirectionCostModel:
    def test_spmv_eliminates_two_instrs_per_nnz_slot(self):
        m, k = 16, 6
        nest = compiler.spmv_nest(m, k)
        stats = plan_stats(nest, num_lanes=auto_lanes(nest))
        assert stats.ssrified
        # one index load + one pointer-arith op per (row, slot) visit
        assert stats.eliminated_idx_instrs == 2 * m * k
        assert stats.n_base > stats.n_ssr

    def test_spmm_eliminates_per_column_revisit(self):
        m, c, k = 8, 4, 5
        nest = compiler.spmm_nest(m, c, k, 128)
        stats = plan_stats(nest, num_lanes=auto_lanes(nest))
        assert stats.ssrified
        # the gather's depth tracks its index stream (innermost), so the
        # per-nnz index handling is re-paid for every dense column c
        assert stats.eliminated_idx_instrs == 2 * m * c * k
        assert stats.n_base > stats.n_ssr

    def test_dense_nests_eliminate_nothing(self):
        nest = compiler.gemm_nest(8, 8, 8)
        stats = plan_stats(nest, num_lanes=auto_lanes(nest))
        assert stats.ssrified
        assert stats.eliminated_idx_instrs == 0


# --------------------------------------------------------------------------
# Dispatch contracts: zero overhead + transparent schedule-cache pickup
# --------------------------------------------------------------------------


class TestDispatch:
    def test_repeated_call_is_pure_cache_hit(self):
        rng = np.random.default_rng(3)
        data, indices, indptr = sp.random_csr(rng, 10, 12, 0.2)
        x = rng.standard_normal(12).astype(np.float32)
        first = sp.ssr_spmv(data, indices, indptr, x)
        snap = dict(frontend.DISPATCH_STATS)
        again = sp.ssr_spmv(data, indices, indptr, x)
        assert frontend.DISPATCH_STATS["builds"] == snap["builds"]
        assert frontend.DISPATCH_STATS["traces"] == snap["traces"]
        assert frontend.DISPATCH_STATS["calls"] == snap["calls"] + 1
        _assert_close(again, first, tol=0.0)

    def test_spmm_repeated_call_is_pure_cache_hit(self):
        rng = np.random.default_rng(4)
        data, indices, indptr = sp.random_csr(rng, 8, 9, 0.25)
        X = rng.standard_normal((9, 3)).astype(np.float32)
        first = sp.ssr_spmm(data, indices, indptr, X)
        snap = dict(frontend.DISPATCH_STATS)
        again = sp.ssr_spmm(data, indices, indptr, X)
        assert frontend.DISPATCH_STATS["builds"] == snap["builds"]
        assert frontend.DISPATCH_STATS["traces"] == snap["traces"]
        _assert_close(again, first, tol=0.0)

    def test_tuned_schedule_resolved_transparently(self, tmp_path,
                                                   monkeypatch):
        """A winner committed under the kernel's own lookup key is what the
        next call runs — no call-site changes — and the numbers match."""
        monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path))
        rng = np.random.default_rng(9)
        data, indices, indptr = sp.random_csr(rng, 12, 14, 0.3)
        x = rng.standard_normal(14).astype(np.float32)
        want = _dense_ref_spmv(data, indices, indptr, x)

        vals, cidx, m, k = sp.csr_to_ell(data, indices, indptr, 14)
        args = (jnp.asarray(vals), jnp.asarray(cidx),
                jnp.asarray(x, jnp.float32))
        params = {"m": m, "k": k}
        assert sp._ssr_spmv.schedule_for(*args, **params) == DEFAULT_SCHEDULE

        operands, static, _final = sp._ssr_spmv._prepare(*args, **params)
        nest = sp._ssr_spmv._nest(static)
        variant = dataclasses.replace(DEFAULT_SCHEDULE, rows=4)
        ok, why = autotune.schedule_is_legal(nest, variant,
                                             operands=dict(operands))
        assert ok, why
        key = autotune.cache_key(nest, dict(operands),
                                 mode=sp._ssr_spmv._mode,
                                 out_dtype="float32")
        autotune.global_cache().put(key, variant, meta={"test": True})

        assert sp._ssr_spmv.schedule_for(*args, **params) == variant
        _assert_close(sp.ssr_spmv(data, indices, indptr, x), want)

    def test_gather_tables_charge_the_vmem_budget(self):
        """Autotune legality: a huge gather table makes schedules illegal."""
        nest = compiler.spmv_nest(8, 4)
        ok, _ = autotune.schedule_is_legal(nest, DEFAULT_SCHEDULE)
        assert ok
        ok, why = autotune.schedule_is_legal(
            nest, DEFAULT_SCHEDULE,
            operands={"x": ((1 << 26,), "float32")})
        assert not ok and "VMEM" in why

    def test_ell_width_is_a_cache_key_fact(self):
        """Two same-shape CSRs with different max row population must not
        share a pipeline: k is a static param, so the call keys differ."""
        x = np.ones(6, np.float32)
        a = (np.asarray([1.0, 2.0], np.float32), np.asarray([0, 1]),
             np.asarray([0, 2, 2]))     # k = 2
        b = (np.asarray([1.0, 2.0], np.float32), np.asarray([0, 0]),
             np.asarray([0, 1, 2]))     # k = 1
        _assert_close(sp.ssr_spmv(*a, x), _dense_ref_spmv(*a, x))
        _assert_close(sp.ssr_spmv(*b, x), _dense_ref_spmv(*b, x))


# --------------------------------------------------------------------------
# Bench artifacts: schema-v5 sparse rows + the run-history sparse summary
# --------------------------------------------------------------------------


def _sparse_pair(kern, agree, speedup, nnz=100, density=0.1, idx=200):
    from benchmarks.kernel_bench import _row
    return [_row(f"sparse/{kern}", "sparse", "agreement", agree,
                 "max_abs_diff", nnz=nnz, density=density),
            _row(f"sparse/{kern}", "sparse", "model", speedup,
                 "model_speedup", nnz=nnz, density=density,
                 n_base=100, n_ssr=50, eliminated_idx_instrs=idx)]


class TestSparseBenchValidators:
    def test_accepts_good_pairs(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0) for k in kb.SPARSE_GATED), [])
        kb.validate_sparse_rows(rows)

    def test_rejects_disagreement(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0)
                    for k in kb.SPARSE_GATED[1:]), [])
        rows += _sparse_pair(kb.SPARSE_GATED[0], 1e-3, 3.0)
        with pytest.raises(ValueError, match="disagreement"):
            kb.validate_sparse_rows(rows)

    def test_rejects_unprofitable_model(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0)
                    for k in kb.SPARSE_GATED[1:]), [])
        rows += _sparse_pair(kb.SPARSE_GATED[0], 1e-7, 0.9)
        with pytest.raises(ValueError, match="model speedup"):
            kb.validate_sparse_rows(rows)

    def test_rejects_zero_eliminated_instrs(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0)
                    for k in kb.SPARSE_GATED[1:]), [])
        rows += _sparse_pair(kb.SPARSE_GATED[0], 1e-7, 3.0, idx=0)
        with pytest.raises(ValueError, match="eliminated_idx_instrs"):
            kb.validate_sparse_rows(rows)

    def test_requires_nnz_density_provenance(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0) for k in kb.SPARSE_GATED), [])
        del rows[0]["nnz"]
        with pytest.raises(ValueError, match="integer nnz"):
            kb.validate_sparse_rows(rows)
        rows = sum((_sparse_pair(k, 1e-7, 3.0, density=1.5)
                    for k in kb.SPARSE_GATED), [])
        with pytest.raises(ValueError, match="density outside"):
            kb.validate_sparse_rows(rows)

    def test_requires_all_gated_kernels(self):
        from benchmarks import kernel_bench as kb
        rows = _sparse_pair(kb.SPARSE_GATED[0], 1e-7, 3.0)
        with pytest.raises(ValueError, match="no sparse gate rows"):
            kb.validate_sparse_rows(rows)

    def test_history_line_carries_sparse_summary(self, tmp_path):
        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0, nnz=42, density=0.25,
                                 idx=84) for k in kb.SPARSE_GATED), [])
        path = str(tmp_path / "hist.jsonl")
        entry = kb.append_bench_history(rows, path, quick=True)
        assert entry["schema"] == kb.BENCH_SCHEMA == 6
        assert set(entry["sparse"]) == set(kb.SPARSE_GATED)
        for info in entry["sparse"].values():
            assert info == {"nnz": 42, "density": 0.25,
                            "eliminated_idx_instrs": 84}
        assert kb.validate_bench_history(path) == 1

    def test_history_rejects_mistyped_sparse_summary(self, tmp_path):
        import json

        from benchmarks import kernel_bench as kb
        rows = sum((_sparse_pair(k, 1e-7, 3.0) for k in kb.SPARSE_GATED), [])
        path = str(tmp_path / "hist.jsonl")
        kb.append_bench_history(rows, path, quick=True)
        with open(path) as f:
            entry = json.loads(f.readline())
        entry["sparse"]["spmv"] = {"density": 0.1}   # nnz missing
        with open(path, "w") as f:
            f.write(json.dumps(entry) + "\n")
        with pytest.raises(ValueError, match="missing integer nnz"):
            kb.validate_bench_history(path)

    def test_history_without_sparse_field_stays_valid(self, tmp_path):
        """Pre-v5 lines legitimately lack the sparse summary."""
        import json

        from benchmarks import kernel_bench as kb
        path = str(tmp_path / "hist.jsonl")
        old = {"schema": 4, "date": "2026-01-01T00:00:00Z",
               "git_sha": "abc1234", "quick": False, "rows": 3,
               "groups": ["dag"], "speedups": {}, "dag_cuts": {}}
        with open(path, "w") as f:
            f.write(json.dumps(old) + "\n")
        assert kb.validate_bench_history(path) == 1
