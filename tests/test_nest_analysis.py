"""Tests for the shared nest-analysis module (core/nest_analysis.py).

One derivation per question: depth, classification, contraction detection,
lane inference, dense storage orders — the facts ``ssrify``, ``chain``,
``cluster_cost`` and the lowering all consume.
"""

import pytest

from repro.core import Direction, LoopNest, MemRef, compiler
from repro.core import nest_analysis as na


def _gemm():
    return compiler.gemm_nest(8, 6, 4)


class TestClassification:
    def test_reads_writes_split(self):
        nest = _gemm()
        assert [r.name for r in na.reads(nest)] == ["A", "B"]
        assert [w.name for w in na.writes(nest)] == ["C"]

    def test_output_ref_single(self):
        assert na.output_ref(_gemm()).name == "C"
        assert na.output_ref(compiler.dot_product_nest(16)) is None

    def test_output_ref_rejects_multiple_writes(self):
        nest = LoopNest(
            bounds=(8,),
            refs=(MemRef("u", Direction.WRITE, (1,)),
                  MemRef("v", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        with pytest.raises(ValueError, match="2 write refs"):
            na.output_ref(nest)

    def test_ref_depth_and_varying_levels(self):
        nest = _gemm()
        a, b, c = nest.refs
        assert na.ref_depth(a, nest) == 2 and na.varying_levels(a) == (0, 2)
        assert na.ref_depth(b, nest) == 2 and na.varying_levels(b) == (1, 2)
        assert na.ref_depth(c, nest) == 1 and na.varying_levels(c) == (0, 1)


class TestContraction:
    def test_gemm_write_contracts_over_k(self):
        nest = _gemm()
        assert na.contraction_axes(na.output_ref(nest), nest) == (2,)

    def test_read_repeat_levels(self):
        # A is invariant over n (level 1) — the repeat register level
        nest = _gemm()
        assert na.contraction_axes(nest.refs[0], nest) == (1,)

    def test_bound_one_levels_are_not_contractions(self):
        nest = compiler.gemm_nest(4, 3, 1)
        assert na.contraction_axes(na.output_ref(nest), nest) == ()


class TestLanes:
    def test_auto_lanes_counts_affine_refs(self):
        assert na.auto_lanes(_gemm()) == 3
        assert na.auto_lanes(compiler.dot_product_nest(64)) == 2
        assert na.auto_lanes(_gemm(), num_lanes=2) == 2

    def test_auto_lanes_floor_is_one(self):
        nest = LoopNest(bounds=(8,),
                        refs=(MemRef("idx", Direction.READ, None),),
                        compute_per_level=(1,))
        assert na.auto_lanes(nest) == 1


class TestInstrCounts:
    def test_residuals_fold_at_their_depth(self):
        nest = _gemm()
        counts = na.instr_counts(nest, residual=[nest.refs[2]])  # C, depth 1
        # C's store is NOT in compute_per_level (it is the WRITE ref), so
        # folding it as a residual restores the explicit-store accounting
        assert counts == [0, 1, 1]

    def test_matches_ssrify_accounting(self):
        # ssrify's Eq. (1)/(2) folding is this function — Fig. 4 exact
        plan = compiler.ssrify(compiler.dot_product_nest(1000))
        assert plan.n_ssr == 1012 and plan.n_base == 3001

    def test_nest_compute(self):
        assert na.nest_compute(compiler.dot_product_nest(100)) == 100
        assert na.nest_compute(_gemm()) == 8 * 6 * 4  # fmadds only


class TestStorageOrder:
    def test_gemm_orders_permute_loop_order(self):
        nest = _gemm()
        a, b, c = nest.refs
        assert na.storage_order(a, nest) == (0, 2)   # A stored (m, k)
        assert na.storage_order(b, nest) == (2, 1)   # B stored (k, n)!
        assert na.storage_order(c, nest) == (0, 1)   # C stored (m, n)
        assert na.logical_shape(b, nest) == (4, 6)

    def test_invariant_ref_has_empty_order(self):
        nest = LoopNest(bounds=(8,),
                        refs=(MemRef("c", Direction.READ, (0,)),),
                        compute_per_level=(1,))
        assert na.storage_order(nest.refs[0], nest) == ()

    def test_overlapping_walk_has_no_dense_order(self):
        # stencil window: x[i + j] — coeffs (1, 1) admit no dense layout
        nest = LoopNest(bounds=(16, 11),
                        refs=(MemRef("x", Direction.READ, (1, 1)),),
                        compute_per_level=(0, 1))
        assert na.storage_order(nest.refs[0], nest) is None

    def test_bound_one_tie_breaks_to_fast_side(self):
        # GEMM with n == 1: B's coefficients (0, 1, 1) tie — the dense
        # order is (k, n), and a naive coefficient sort would pick (n, k)
        # and wrongly reject the layout
        nest = compiler.gemm_nest(8, 1, 4)
        b = nest.refs[1]
        assert na.storage_order(b, nest) == (2, 1)
        assert na.logical_shape(b, nest) == (4, 1)

    def test_strided_non_dense_rejected(self):
        nest = LoopNest(bounds=(4, 8),
                        refs=(MemRef("a", Direction.READ, (16, 1)),),
                        compute_per_level=(0, 1))
        assert na.storage_order(nest.refs[0], nest) is None


class TestCompilerSharesAnalysis:
    """The three former private re-derivations now alias this module."""

    def test_aliases(self):
        assert compiler._ref_depth is na.ref_depth
        assert compiler._auto_lanes is na.auto_lanes
        assert compiler._nest_compute is na.nest_compute
